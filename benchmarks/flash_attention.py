"""Flash attention vs the einsum paths it replaces.

Two comparisons, both at serving-scale shapes (long KV histories —
the regime the kernels exist for; at toy lengths the per-tile dispatch
overhead of the tiled path dominates and the single big einsum wins):

  * ``decode``  — the tiled flash-decode (per-tile dots at the cache's
    storage dtype, deterministic rank-order split combine) against the
    FIXED einsum fallback (single big dot, fp32 accumulation via
    ``preferred_element_type``).  Note the baseline is the repaired
    einsum, not the old full-cache-upcast bug — the speedup reported
    here is purely the tiling win, on top of the bugfix both paths
    share.
  * ``prefill`` — the chunked online-softmax scan against a naive
    attention that materializes the full [B, H, Sq, Skv] logit matrix
    at fp32.

The invariant row ``flash_beats_einsum`` (decode rows only) must hold:
this standalone entry point fails hard on it; the bench gate's single
pass reports a miss as WARN (host-noise policy, same as
``sched_beats_fixed``).

Run directly for a human-readable report:

    PYTHONPATH=src python benchmarks/flash_attention.py
"""
import os
import sys
import time

import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SEED = 0
N_CALLS = 7     # median-of-N inside each timed pass
N_PASSES = 3    # interleaved passes per variant; min-of-medians gates

# decode: [b, kv_len, n_kv_heads, group, head_dim] — long-history lanes
DECODE_SHAPES = [
    (4, 4096, 8, 4, 64),
    (8, 2048, 4, 4, 128),
]
# prefill: [b, seq, n_heads, head_dim]
PREFILL_SHAPE = (2, 1024, 8, 64)


def _median_us(fn, args, n=N_CALLS):
    import jax
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return 1e6 * float(np.median(ts))


def _interleaved(fa, fb, args):
    """Warm both, then N_PASSES interleaved median-of-N_CALLS sweeps per
    variant; returns (min_median_a_us, min_median_b_us) so a host-
    contention spike during one pass can't flip the comparison."""
    import jax
    jax.block_until_ready(fa(*args))
    jax.block_until_ready(fb(*args))
    a_runs, b_runs = [], []
    for _ in range(N_PASSES):
        a_runs.append(_median_us(fa, args))
        b_runs.append(_median_us(fb, args))
    return min(a_runs), min(b_runs)


def _naive_prefill(q, k, v):
    """Full-logit-matrix causal attention: the O(S^2) fp32 score tensor
    the chunked scan exists to avoid materializing."""
    import jax
    import jax.numpy as jnp
    hd = q.shape[-1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32)
    s = s * jnp.float32(hd) ** -0.5
    sq = q.shape[1]
    mask = jnp.tril(jnp.ones((sq, sq), bool))
    s = jnp.where(mask[None, None], s, jnp.float32(-1e30))
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def rows():
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    from repro.models import attention as A

    out = []
    key = jax.random.PRNGKey(SEED)

    for b, kv_len, n_kv, g, hd in DECODE_SHAPES:
        ks = jax.random.split(key, 3)
        k_cache = jax.random.normal(ks[0], (b, kv_len, n_kv, hd),
                                    jnp.bfloat16)
        v_cache = jax.random.normal(ks[1], (b, kv_len, n_kv, hd),
                                    jnp.bfloat16)
        q = jax.random.normal(ks[2], (b, 1, n_kv, g, hd), jnp.bfloat16)
        pos = jnp.int32(kv_len - 1)

        flash = jax.jit(lambda q, k, v, p: kops.flash_decode(q, k, v, p))
        einsum = jax.jit(
            lambda q, k, v, p: A.decode_attention_einsum(q, k, v, p))
        f_us, e_us = _interleaved(flash, einsum, (q, k_cache, v_cache, pos))
        out.append((
            f"flash_attention/decode_b{b}_L{kv_len}_h{n_kv}x{g}_d{hd}",
            f_us,
            f"flash_us={f_us:.1f};einsum_us={e_us:.1f};"
            f"speedup={e_us / f_us:.2f};"
            f"flash_beats_einsum={f_us < e_us}"))

    b, sq, n_h, hd = PREFILL_SHAPE
    ks = jax.random.split(jax.random.fold_in(key, 1), 3)
    q = jax.random.normal(ks[0], (b, sq, n_h, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, sq, n_h, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, sq, n_h, hd), jnp.bfloat16)
    flash_p = jax.jit(lambda q, k, v: A.flash_attention(
        q, k, v, q_chunk=256, kv_chunk=256))
    naive_p = jax.jit(_naive_prefill)
    f_us, n_us = _interleaved(flash_p, naive_p, (q, k, v))
    out.append((
        f"flash_attention/prefill_b{b}_S{sq}_h{n_h}_d{hd}",
        f_us,
        f"flash_us={f_us:.1f};naive_us={n_us:.1f};"
        f"ratio_vs_naive={f_us / n_us:.2f}"))
    return out


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    print("name,us_per_call,derived")
    ok = True
    for name, us, derived in rows():
        print(f"{name},{us:.2f},{derived}")
        if "flash_beats_einsum=False" in derived:
            ok = False
    print("ALL_OK" if ok else "FLASH_SLOWER_THAN_EINSUM")
    sys.exit(0 if ok else 1)
