"""Paper Tables II/III: full-array MaxEVA configurations vs CHARM.

Reproduces throughput / power / energy efficiency for the six reported
design points per precision, and checks the headline claims:
  fp32: +20.8% throughput, +20.4% energy efficiency over CHARM
  int8: 2.19x throughput over CHARM
"""
from repro.core.planner import ArrayConfig, pnr_feasible, solve_aie_array
from repro.core import perf_model as pm

CONFIGS = [(13, 4, 6), (10, 3, 10), (11, 4, 7), (11, 3, 9), (12, 4, 6),
           (12, 3, 8)]


def rows():
    out = []
    # optimizer ranking: MAC-maximal 10x4x8 fails PnR; 13x4x6 best feasible
    top = solve_aie_array(top=6)
    ranking = "|".join(
        f"{c.x}x{c.y}x{c.z}({'ok' if pnr_feasible(c) else 'pnr-fail'})"
        for c in top[:4])
    out.append(("table2/xyz_optimizer_ranking", 0.0, ranking))

    for prec, unit in (("fp32", "GFLOPs"), ("int8", "TOPs")):
        for xyz in CONFIGS:
            d = pm.evaluate_design(ArrayConfig(*xyz), prec)
            paper = pm.PAPER_THROUGHPUT[(prec, *xyz)]
            err = 100 * (d.throughput / paper - 1)
            out.append((
                f"table{'2' if prec == 'fp32' else '3'}/"
                f"{prec}_{xyz[0]}x{xyz[1]}x{xyz[2]}", 0.0,
                f"tput={d.throughput:.2f}{unit};paper={paper};"
                f"err={err:+.2f}%;power={d.total_power_w:.2f}W;"
                f"eff={d.energy_eff:.3f}"))

    best_f = pm.evaluate_design(ArrayConfig(13, 4, 6), "fp32")
    best_i = pm.evaluate_design(ArrayConfig(13, 4, 6), "int8")
    out.append(("table2/claim_fp32_vs_charm", 0.0,
                f"gain={best_f.throughput / pm.CHARM['fp32']['throughput_gflops']:.4f}"
                f";paper=1.208"))
    out.append(("table2/claim_energy_vs_charm", 0.0,
                f"gain={best_f.energy_eff / pm.CHARM['fp32']['energy_eff']:.4f}"
                f";paper=1.204"))
    out.append(("table3/claim_int8_vs_charm", 0.0,
                f"gain={best_i.throughput / pm.CHARM['int8']['throughput_tops']:.4f}"
                f";paper=2.19"))
    out.append(("table2/claim_mlp_vs_charm", 0.0,
                f"gain={pm.CHARM['mlp_fp32']['maxeva_gflops'] / pm.CHARM['mlp_fp32']['charm_gflops']:.4f}"
                f";paper=1.29"))
    return out
