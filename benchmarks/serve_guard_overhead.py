"""Health-guard overhead on the serving decode step.

The hardened ``ServeEngine`` folds its per-lane health probes (finite
check, absmax, int8 saturation fraction) into the SAME jitted dispatch
as the token pick, so a guarded decode step costs one decode call + one
fused pick call — exactly like an unguarded step.  This benchmark proves
the two halves of that claim:

  * STRUCTURAL (noise-free, hard-gated): the traced decode-step HLO is
    byte-identical between a guards-on and a guards-off engine
    (``decode_hlo_unchanged``) — the guards live outside the model trace,
    so every PR 2-4 HLO invariant is untouched by construction.
  * TIMING: ``overhead_pct`` = (guarded pick - plain pick) / (decode step
    + plain pick), medians of interleaved samples.  The invariant row
    asserts it stays under 2% (``guard_overhead_lt_2pct``); the gate's
    single pass reports a miss as WARN (host noise policy, same as
    ``fused_le_unfused``) while this standalone entry point fails hard.

Run directly for a human-readable report:

    PYTHONPATH=src python benchmarks/serve_guard_overhead.py
"""
import os
import sys
import time

import jax
import jax.numpy as jnp

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ARCH = "internlm2-1.8b"
BATCH = 4
PROMPT = 16
DECODE_HEADROOM = 8


def _median_us(fn, iters=30):
    jax.block_until_ready(fn())  # compile + warm
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append((time.perf_counter() - t0) * 1e6)
    return sorted(samples)[len(samples) // 2]


def rows():
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.models.lm import Model
    from repro.serve.engine import ServeConfig, ServeEngine

    mesh = make_mesh(1, 1)
    cfg = get_config(ARCH, smoke=True)
    model = Model(cfg, mesh)
    params = model.init_params(0)

    eng_on = ServeEngine(model, params, ServeConfig(max_new_tokens=4))
    eng_off = ServeEngine(model, params, ServeConfig(max_new_tokens=4,
                                                     guards=False))

    batch = {"tokens": (jnp.arange(BATCH * PROMPT, dtype=jnp.int32)
                        .reshape(BATCH, PROMPT) % cfg.vocab)}
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=PROMPT + DECODE_HEADROOM)
    )(params, batch)
    jax.block_until_ready(logits)

    # structural proof first: identical decode-step HLO with guards on/off
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    pos = jnp.asarray(PROMPT, jnp.int32)
    hlo_on = eng_on._decode.lower(params, cache, tok, pos).compile() \
        .as_text()
    hlo_off = eng_off._decode.lower(params, cache, tok, pos).compile() \
        .as_text()
    hlo_unchanged = hlo_on == hlo_off

    # timing: decode step, plain eager pick, guarded fused pick —
    # interleaved would bias the jit caches, so each gets its own warm
    # median; the overhead ratio divides out shared host speed
    decode = jax.jit(model.decode_step)  # non-donating timing clone
    key = jax.random.PRNGKey(0)
    calib = jnp.ones((BATCH,), jnp.float32)
    decode_us = _median_us(lambda: decode(params, cache, tok, pos)[0])
    plain_us = _median_us(lambda: eng_off._pick(logits, key))
    guarded_us = _median_us(
        lambda: eng_on._pick_guarded(logits, key, calib)[0])

    overhead = max(0.0, guarded_us - plain_us) / (decode_us + plain_us)
    return [(
        f"serve_guard/{ARCH}", decode_us + guarded_us,
        f"decode_us={decode_us:.1f};pick_plain_us={plain_us:.1f};"
        f"pick_guarded_us={guarded_us:.1f};"
        f"overhead_pct={100.0 * overhead:.3f};"
        f"guard_overhead_lt_2pct={overhead < 0.02};"
        f"decode_hlo_unchanged={hlo_unchanged}")]


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    print("name,us_per_call,derived")
    ok = True
    for name, us, derived in rows():
        print(f"{name},{us:.2f},{derived}")
        if "guard_overhead_lt_2pct=True" not in derived:
            ok = False
        if "decode_hlo_unchanged=True" not in derived:
            ok = False
    print("ALL_OK" if ok else "GUARD_OVERHEAD_EXCEEDED")
    sys.exit(0 if ok else 1)
