"""Int8 vs fp32 decode-step wall clock (the end-to-end int8 serving path).

One smoke-size model per row, single-shard mesh on this host (XLA CPU
stand-in; the Pallas int8 kernel compiles natively on TPU).  For each arch
the decode step is jitted twice — once on the fp32/bf16 params, once on
the column-quantized ``QuantizedWeight`` params — and timed median-of-N
with every region closed by ``block_until_ready`` (host timing is noisy;
the median is the robust per-call estimate).

The derived column carries the correctness invariants alongside the
timing: ``bounces`` is ``hlo_analysis.int8_bounce_count`` on the traced
int8 decode (MUST be 0 — no fp32 dequant/requant between GEMMs) and
``model_hbm_speedup`` is the perf model's byte-ratio prediction for the
arch's projection GEMMs (the number that materializes on real
bandwidth-bound hardware; host CPU wall-clock is reported, not gated,
because XLA CPU has no int8 fast path).

Run directly for a human-readable report:

    PYTHONPATH=src python benchmarks/int8_decode.py
"""
import os
import sys
import time

import jax
import jax.numpy as jnp

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ARCHS = ("internlm2-1.8b", "gemma2-27b")
PROMPT = 16
DECODE_HEADROOM = 8


def _time_us(fn, *args, iters=15):
    jax.block_until_ready(fn(*args))  # compile + warm
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return sorted(samples)[len(samples) // 2]


def _decode_setup(model, params):
    """(decode_fn, args) for a prefilled cache + one decode step."""
    cfg = model.cfg
    batch = {"tokens": (jnp.arange(2 * PROMPT, dtype=jnp.int32)
                        .reshape(2, PROMPT) % cfg.vocab)}
    _, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=PROMPT + DECODE_HEADROOM)
    )(params, batch)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.asarray(PROMPT, jnp.int32)
    fn = jax.jit(model.decode_step)
    return fn, (params, cache, tok, pos)


def rows():
    from repro.configs import get_config
    from repro.core.perf_model import int8_serving_savings
    from repro.launch.hlo_analysis import int8_bounce_count
    from repro.launch.mesh import make_mesh
    from repro.models.lm import Model

    mesh = make_mesh(1, 1)
    out = []
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        model = Model(cfg, mesh)
        params = model.init_params(0)
        qparams = model.quantize_params_for_serving(params)

        fn_fp, args_fp = _decode_setup(model, params)
        fn_q, args_q = _decode_setup(model, qparams)
        # interleaved timing so host contention hits both paths equally
        us_fp = _time_us(fn_fp, *args_fp)
        us_q = _time_us(fn_q, *args_q)

        hlo = fn_q.lower(*args_q).compile().as_text()
        bounces = int8_bounce_count(hlo)
        sav = int8_serving_savings(2, cfg.d_model, cfg.q_dim
                                   + 2 * cfg.kv_dim)
        out.append((
            f"int8_decode/{arch}", us_q,
            f"fp32_us={us_fp:.1f};speedup={us_fp / max(us_q, 1e-9):.2f}x;"
            f"bounces={bounces};"
            f"model_hbm_speedup={sav['hbm_speedup']:.2f}x"))
    return out


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    print("name,us_per_call,derived")
    ok = True
    for name, us, derived in rows():
        print(f"{name},{us:.2f},{derived}")
        if "bounces=0" not in derived:
            ok = False
    print("ALL_OK" if ok else "INT8_DECODE_HAS_FP32_BOUNCE")
    sys.exit(0 if ok else 1)
