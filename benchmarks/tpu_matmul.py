"""Wall-clock microbench of the planned matmul path on this host (XLA CPU
stand-in; the Pallas path compiles natively on TPU).  us_per_call is real;
'derived' reports the planner's block choice for each GEMM."""
import time

import jax
import jax.numpy as jnp

from repro.core.planner import plan_tpu_block
from repro.kernels import ops

SHAPES = [(512, 512, 512), (1024, 1024, 1024), (2048, 2048, 2048),
          (4096, 512, 4096)]


def _time_us(fn, *args, iters=9):
    """Median-of-N with every timed region closed by block_until_ready:
    async dispatch means an unblocked loop times queue depth, not work,
    and on this oversubscribed host the mean is dominated by contention
    bursts — the median is the robust per-call estimate."""
    jax.block_until_ready(fn(*args))  # compile + warm
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return sorted(samples)[len(samples) // 2]


def rows():
    out = []
    for m, k, n in SHAPES:
        a = jnp.ones((m, k), jnp.bfloat16)
        b = jnp.ones((k, n), jnp.bfloat16)
        f = jax.jit(lambda a, b: ops.matmul(a, b, mode="xla"))
        us = _time_us(f, a, b)
        blk = plan_tpu_block(m, k, n, "bf16")
        gflops = 2 * m * k * n / (us * 1e-6) / 1e9
        out.append((f"tpu_matmul/{m}x{k}x{n}", us,
                    f"host_gflops={gflops:.1f};planned_block="
                    f"{blk.bm}x{blk.bk}x{blk.bn};vmem_kb="
                    f"{blk.vmem_bytes // 1024}"))
    return out
