"""Wall-clock microbench of the planned matmul path on this host (XLA CPU
stand-in; the Pallas path compiles natively on TPU).  us_per_call is real;
'derived' reports the planner's block choice for each GEMM."""
import time

import jax
import jax.numpy as jnp

from repro.core.planner import plan_tpu_block
from repro.kernels import ops

SHAPES = [(512, 512, 512), (1024, 1024, 1024), (2048, 2048, 2048),
          (4096, 512, 4096)]


def _time_us(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def rows():
    out = []
    for m, k, n in SHAPES:
        a = jnp.ones((m, k), jnp.bfloat16)
        b = jnp.ones((k, n), jnp.bfloat16)
        f = jax.jit(lambda a, b: ops.matmul(a, b, mode="xla"))
        us = _time_us(f, a, b)
        blk = plan_tpu_block(m, k, n, "bf16")
        gflops = 2 * m * k * n / (us * 1e-6) / 1e9
        out.append((f"tpu_matmul/{m}x{k}x{n}", us,
                    f"host_gflops={gflops:.1f};planned_block="
                    f"{blk.bm}x{blk.bk}x{blk.bn};vmem_kb="
                    f"{blk.vmem_bytes // 1024}"))
    return out
