"""Fused-epilogue and collective-matmul benchmark.

Two measurements, both on this host (XLA CPU stand-in; the Pallas path
compiles natively on TPU):

1. fused_epilogue/*: wall-clock of the GEMM with its epilogue (bias +
   gelu + bf16 cast) fused into ONE jitted dispatch vs. the unfused
   sequence (a jitted GEMM whose fp32 accumulator round-trips through
   device memory, then a separately jitted elementwise epilogue).  The
   derived column reports the perf_model's predicted HBM-byte savings.

2. ring_overlap/* + bidir_ring/* + gather_overlap/*: the overlapped
   collective matmuls ('ring' and the bidirectional 'bidir_ring') vs the
   barrier reduce_scatter on an 8-device CPU mesh, plus the ksharded
   Z>1 cells whose barrier all-gather of A became a chunked ppermute
   gather — all run in a subprocess so this process keeps a single
   device.  The subprocess also asserts every schedule agrees
   BIT-FOR-BIT at fp32 with reduce_scatter (the determinism guarantee of
   the shared chunk-GEMM structure); the bidir derived column reports
   the perf model's per-link byte ratio (~0.5 vs 'ring').

Run directly for a human-readable report:

    PYTHONPATH=src python benchmarks/fused_epilogue.py
"""
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# tier-1 shapes (matches tpu_matmul.py)
SHAPES = [(512, 512, 512), (1024, 1024, 1024), (2048, 2048, 2048),
          (4096, 512, 4096)]


def _time_us_interleaved(fns, args, iters=20, max_rounds=None):
    """Per-fn (min, samples) over interleaved rounds, every timed region
    closed by block_until_ready, so background load on a shared host hits
    all candidates equally.  The min gates fused-vs-unfused comparisons
    (on an oversubscribed container the upper half of the distribution is
    contention, not work); callers pool the raw samples across passes and
    report the median-of-N alongside as the typical-call estimate.
    Sampling is adaptive — it stops early once no candidate's min has
    improved for ``iters`` consecutive rounds."""
    for fn in fns:
        jax.block_until_ready(fn(*args))  # compile + warm
    samples = [[] for _ in fns]
    best = [float("inf")] * len(fns)
    stale = 0
    for _ in range(max_rounds or 3 * iters):
        improved = False
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            dt = (time.perf_counter() - t0) * 1e6
            samples[i].append(dt)
            if dt < best[i] * 0.999:
                improved = True
            best[i] = min(best[i], dt)
        stale = 0 if improved else stale + 1
        if stale >= iters:
            break
    return list(zip(best, samples))


def fused_vs_unfused_rows(passes=2):
    from repro.core.perf_model import fused_epilogue_savings
    from repro.kernels import ops
    from repro.kernels.epilogue import Epilogue, apply_epilogue

    ep = Epilogue(bias=True, activation="gelu", out_dtype=jnp.bfloat16)
    timed = []
    for m, k, n in SHAPES:
        key = jax.random.PRNGKey(m + n)
        ka, kb, kc = jax.random.split(key, 3)
        a = jax.random.normal(ka, (m, k), jnp.float32)
        b = jax.random.normal(kb, (k, n), jnp.float32)
        bias = jax.random.normal(kc, (n,), jnp.float32)

        fused = jax.jit(lambda a, b, bias: ops.matmul(
            a, b, mode="xla", epilogue=ep, bias=bias))

        # unfused: the GEMM and the epilogue are SEPARATE dispatches, so
        # the fp32 accumulator is materialized between them
        gemm = jax.jit(lambda a, b: ops.matmul(a, b, mode="xla"))
        tail = jax.jit(lambda acc, bias: apply_epilogue(acc, ep, bias=bias))

        def unfused(a, b, bias):
            return tail(gemm(a, b), bias)

        timed.append((m, k, n, fused, unfused, (a, b, bias)))

    # several temporally separated passes over all shapes, min across
    # passes: contention bursts on a shared host can outlast one shape's
    # whole measurement window, but rarely recur on the same shape twice
    best, pooled = {}, {}
    for _ in range(passes):
        for m, k, n, fused, unfused, args in timed:
            iters = 12 if m * k * n <= 2 ** 30 else 10
            (us_f, s_f), (us_u, s_u) = _time_us_interleaved(
                [fused, unfused], args, iters=iters)
            bf, bu = best.get((m, k, n), (float("inf"), float("inf")))
            best[(m, k, n)] = (min(bf, us_f), min(bu, us_u))
            pf, pu = pooled.setdefault((m, k, n), ([], []))
            pf.extend(s_f)
            pu.extend(s_u)

    out = []
    for m, k, n, *_ in timed:
        us_f, us_u = best[(m, k, n)]
        # true median-of-N over ALL samples from every pass
        md_f, md_u = (sorted(s)[len(s) // 2]
                      for s in pooled[(m, k, n)])
        sav = fused_epilogue_savings(m, n, ep)
        # 2% margin = the noise floor of min-of-N on this shared host;
        # the fused path does strictly less memory work (the modeled
        # bytes_saved below), so a "loss" inside the margin is noise
        out.append((
            f"fused_epilogue/{m}x{k}x{n}", us_f,
            f"unfused_us={us_u:.1f};speedup={us_u / max(us_f, 1e-9):.2f}x;"
            f"median_us={md_f:.1f};median_unfused_us={md_u:.1f};"
            f"model_bytes_saved={int(sav['bytes_saved'])};"
            f"fused_le_unfused={us_f <= us_u * 1.02}"))
    return out


def v2_epilogue_rows(passes=2):
    """The v2 algebra's two fusions, fused vs unfused (same interleaved
    min-of-N + pooled-median policy as ``fused_vs_unfused_rows``):

    * gated_mlp_block/*: the gated MLP's up half — raw gate GEMM + up
      GEMM with the two-operand ``silu(g) * u`` store-phase epilogue in
      one jitted dispatch chain, vs both GEMMs plus a separately jitted
      elementwise gate multiply (the extra output read + product write).
    * rmsnorm_fused/*: the down projection with residual + next-norm
      folded into the store phase (two outputs, one dispatch), vs GEMM
      then a separately jitted residual add + rmsnorm (the residual
      stream's extra HBM round trip).

    Shapes: the 1024^3 cell plus the memory-bound 4096x512x4096 cell
    (shallow K, large M*N — the epilogue's byte traffic is a first-order
    fraction of the row, which is what this row measures).  The
    compute-bound 2048^3 cell is deliberately excluded: there the
    epilogue is ~1% of runtime and on this CPU stand-in the cell
    reproducibly times threading artifacts of the in-jit reduction, not
    the fusion (fused_le_unfused flips on noise well outside the 2%
    margin).
    """
    from repro.core.perf_model import fused_epilogue_savings
    from repro.kernels import ops
    from repro.kernels.epilogue import Epilogue, apply_epilogue
    from repro.models.layers import rmsnorm

    gate_ep = Epilogue(gate="silu", out_dtype=jnp.bfloat16)
    norm_ep = Epilogue(residual=True, norm="rmsnorm",
                       out_dtype=jnp.bfloat16)
    timed = []
    for m, k, n in (SHAPES[1], SHAPES[3]):
        key = jax.random.PRNGKey(m + n + 1)
        ka, kb, kg, kd, kr, ks = jax.random.split(key, 6)
        a = jax.random.normal(ka, (m, k), jnp.float32)
        wu = jax.random.normal(kb, (k, n), jnp.float32)
        wg = jax.random.normal(kg, (k, n), jnp.float32)
        wd = jax.random.normal(kd, (n, k), jnp.float32)
        res = jax.random.normal(kr, (m, k), jnp.float32)
        nsc = jax.random.normal(ks, (k,), jnp.float32) * 0.1

        fused_gate = jax.jit(lambda a, wg, wu: ops.matmul(
            a, wu, mode="xla", epilogue=gate_ep,
            operand2=ops.matmul(a, wg, mode="xla")))
        gemm = jax.jit(lambda a, w: ops.matmul(a, w, mode="xla"))
        gate_tail = jax.jit(lambda g, u: apply_epilogue(
            u, gate_ep, operand2=g))

        def unfused_gate(a, wg, wu):
            return gate_tail(gemm(a, wg), gemm(a, wu))

        fused_norm = jax.jit(lambda h, wd, res, nsc: ops.matmul(
            h, wd, mode="xla", epilogue=norm_ep, residual=res,
            norm_scale=nsc))
        norm_tail = jax.jit(lambda acc, res, nsc: (
            lambda v: (v, rmsnorm(v, nsc)))(
                (acc + res).astype(jnp.bfloat16)))

        def unfused_norm(h, wd, res, nsc):
            return norm_tail(gemm(h, wd), res, nsc)

        u = jax.random.normal(kb, (m, n), jnp.float32)
        timed.append((m, k, n,
                      (fused_gate, unfused_gate, (a, wg, wu)),
                      (fused_norm, unfused_norm, (u, wd, res, nsc))))

    best, pooled = {}, {}
    for _ in range(passes):
        for m, k, n, gate_cell, norm_cell in timed:
            for tag, (fused, unfused, args) in (("gated_mlp_block",
                                                 gate_cell),
                                                ("rmsnorm_fused",
                                                 norm_cell)):
                (us_f, s_f), (us_u, s_u) = _time_us_interleaved(
                    [fused, unfused], args, iters=10)
                kk = (tag, m, k, n)
                bf, bu = best.get(kk, (float("inf"), float("inf")))
                best[kk] = (min(bf, us_f), min(bu, us_u))
                pf, pu = pooled.setdefault(kk, ([], []))
                pf.extend(s_f)
                pu.extend(s_u)

    out = []
    for (tag, m, k, n), (us_f, us_u) in best.items():
        md_f, md_u = (sorted(s)[len(s) // 2] for s in pooled[(tag, m, k, n)])
        ep = gate_ep if tag == "gated_mlp_block" else norm_ep
        sav = fused_epilogue_savings(m, n if tag == "gated_mlp_block"
                                     else k, ep)
        out.append((
            f"{tag}/{m}x{k}x{n}", us_f,
            f"unfused_us={us_u:.1f};speedup={us_u / max(us_f, 1e-9):.2f}x;"
            f"median_us={md_f:.1f};median_unfused_us={md_u:.1f};"
            f"model_bytes_saved={int(sav['bytes_saved'])};"
            f"fused_le_unfused={us_f <= us_u * 1.02}"))
    return out


_RING_SUBPROC = r"""
import time
import jax, jax.numpy as jnp, numpy as np
from repro.core.maxeva_matmul import XYZConfig, shard_weight_xyz, xyz_matmul
from repro.core.perf_model import collective_overlap_savings
from repro.core.sharding import use_mesh
from repro.launch.mesh import make_mesh

mesh = make_mesh(2, 4)
MODEL = 4

def time_interleaved(fns, x, iters=7):
    # interleaved min-of-N (noisy shared host)
    times = {name: float("inf") for name in fns}
    for _ in range(iters):
        for name, f in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(f(x))
            times[name] = min(times[name], (time.perf_counter() - t0) * 1e6)
    return times

def bench(m, k, n, y):
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (8, m // 8, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) / np.sqrt(k)
    w_xyz = shard_weight_xyz(w, MODEL, y)
    fns, gfns = {}, {}
    for sched in ("reduce_scatter", "ring", "bidir_ring"):
        cfg = XYZConfig(y=y, schedule=sched)
        fns[sched] = jax.jit(
            lambda xx, cfg=cfg: xyz_matmul(xx, w_xyz, mesh=mesh, cfg=cfg))
    if y == 2:
        # Z = 2: the ksharded overlapped-gather path (chunked ppermute
        # gather of A interleaved with the K-piece GEMMs)
        for sched in ("reduce_scatter", "bidir_ring"):
            cfg = XYZConfig(y=y, schedule=sched, x_layout="ksharded")
            gfns[sched] = jax.jit(
                lambda xx, cfg=cfg: xyz_matmul(xx, w_xyz, mesh=mesh,
                                               cfg=cfg))
    outs, gouts = {}, {}
    with use_mesh(mesh):
        for name, f in fns.items():
            outs[name] = np.asarray(f(x))   # compile + warm
        for name, f in gfns.items():
            gouts[name] = np.asarray(f(x))
        times = time_interleaved(fns, x)
        gtimes = time_interleaved(gfns, x) if gfns else {}
    # the cross-schedule BITWISE determinism invariant, proven on every
    # bench-gate run (not only in the test suite)
    for sched in ("ring", "bidir_ring"):
        bitwise = np.array_equal(outs[sched], outs["reduce_scatter"])
        assert bitwise, (
            f"{sched} != reduce_scatter bitwise at fp32 ({m}x{k}x{n} y={y})")
    sav = collective_overlap_savings(m // 2, n // (MODEL // y), y)
    print(f"ROW,ring_overlap/{m}x{k}x{n}/y{y},{times['ring']:.2f},"
          f"rs_us={times['reduce_scatter']:.2f};bitwise_fp32=True")
    print(f"ROW,bidir_ring/{m}x{k}x{n}/y{y},{times['bidir_ring']:.2f},"
          f"rs_us={times['reduce_scatter']:.2f};"
          f"ring_us={times['ring']:.2f};bitwise_fp32=True;"
          f"model_link_ratio={sav['bidir_link_ratio']:.2f}")
    if gfns:
        bitwise = np.array_equal(gouts["bidir_ring"],
                                 gouts["reduce_scatter"])
        assert bitwise, (
            f"overlapped-gather bidir_ring != reduce_scatter bitwise "
            f"({m}x{k}x{n} y={y})")
        print(f"ROW,gather_overlap/{m}x{k}x{n}/y{y},"
              f"{gtimes['bidir_ring']:.2f},"
              f"rs_us={gtimes['reduce_scatter']:.2f};bitwise_fp32=True")

for (m, k, n) in [(512, 512, 512), (1024, 1024, 1024), (2048, 2048, 2048),
                  (4096, 512, 4096)]:
    for y in (2, 4):
        bench(m, k, n, y)
print("RING_OK")
"""


def ring_overlap_rows():
    """Collective-matmul rows ('ring', 'bidir_ring', ksharded
    'gather_overlap') from an 8-device subprocess.  The subprocess
    ASSERTS the cross-schedule bitwise-fp32 determinism invariant for
    every row — scripts/bench_gate.py runs this on every CI pass, so the
    invariant is proven on every run, not just under pytest."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run([sys.executable, "-c", _RING_SUBPROC],
                       capture_output=True, text=True, timeout=1800,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "RING_OK" in r.stdout
    out = []
    for line in r.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",", 3)
            out.append((name, float(us), derived))
    return out


def rows():
    return (fused_vs_unfused_rows(passes=3) + v2_epilogue_rows(passes=3)
            + ring_overlap_rows())


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    print("name,us_per_call,derived")
    ok = True
    for name, us, derived in rows():
        print(f"{name},{us:.2f},{derived}")
        if "fused_le_unfused=False" in derived:
            ok = False
    print("ALL_OK" if ok else "FUSED_SLOWER_THAN_UNFUSED")
    sys.exit(0 if ok else 1)
