"""Paper Fig. 8: throughput vs (square) matrix size for the 13x4x6 design,
under the zero-padding tiling model."""
from repro.core.planner import ArrayConfig
from repro.core import perf_model as pm

SIZES = [256, 512, 1024, 2048, 4096, 8192, 16384]


def rows():
    out = []
    cfg = ArrayConfig(13, 4, 6)
    for prec, unit in (("fp32", "GFLOPs"), ("int8", "TOPs")):
        peak = pm.design_throughput(cfg, prec)
        pts = []
        for s in SIZES:
            t = pm.throughput_vs_size(s, cfg, prec)
            pts.append(f"{s}:{t:.1f}")
        out.append((f"fig8/{prec}_sweep", 0.0, "|".join(pts)))
        t2k = pm.throughput_vs_size(2048, cfg, prec)
        out.append((f"fig8/{prec}_2k_frac_of_peak", 0.0,
                    f"{t2k / peak:.4f}"))
    return out
