# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
# Timing policy (noisy shared host): every timed region is closed by
# jax.block_until_ready (async dispatch otherwise measures queue depth,
# not work); per-module estimators are median-of-N samples, except the
# fused-vs-unfused gate which keeps interleaved min-of-N (and reports the
# median alongside in the derived column).
import sys


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import table1_kernels, table23_array, fig8_sizes, \
        tpu_matmul, roofline_report, fused_epilogue, int8_decode

    print("name,us_per_call,derived")
    for mod in (table1_kernels, table23_array, fig8_sizes, tpu_matmul,
                roofline_report, fused_epilogue, int8_decode):
        for name, us, derived in mod.rows():
            print(f"{name},{us:.2f},{derived}")


if __name__ == '__main__':
    main()
