"""Mixed-traffic serving throughput: continuous batching vs fixed batch.

Drives ONE seeded mixed workload — Poisson inter-arrivals, bimodal
prompt lengths (chat-short vs doc-long), per-request token budgets —
through both serving paths of the same engine:

  * ``sched``  — the paged continuous-batching scheduler: requests admit
    into recycled lanes as capacity frees, prompts prefill in chunks
    interleaved with decode, every request stops at ITS budget;
  * ``fixed``  — the retained fixed-batch loop serving the same traffic
    the only way its API allows: FCFS groups of ``n_lanes``, prompts
    right-padded to the group max, every group decoded to the LONGEST
    budget in the workload (the per-request budget is inexpressible).

Throughput counts USEFUL tokens only (each request's own budget) — the
padding and over-decoding the fixed loop burns on mixed traffic is
precisely what continuous batching reclaims, and the reported
``speedup`` is that reclaimed fraction.  Per-request completion
latencies (p50/p95 from drive start) ride in the derived column.

The invariant row ``sched_beats_fixed`` must hold: this standalone entry
point fails hard on it; the bench gate's single pass reports a miss as
WARN (host-noise policy, same as ``fused_le_unfused``).

Run directly for a human-readable report:

    PYTHONPATH=src python benchmarks/serve_throughput.py
"""
import os
import sys
import time
import warnings

import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ARCH = "internlm2-1.8b"
N_REQUESTS = 16
N_LANES = 4
PAGE = 8
CHUNK = 16
MAX_SEQ = 80   # holds the worst case: a 48-token prompt + 28 budget
SEED = 0


def _workload(vocab):
    """Seeded mixed traffic: Poisson arrival steps, bimodal prompts
    (short chat turns vs long documents), varied per-request budgets."""
    from repro.serve.api import Request, SamplingParams
    rng = np.random.default_rng(SEED)
    arrivals = np.cumsum(rng.poisson(1.5, N_REQUESTS))
    reqs = []
    for i in range(N_REQUESTS):
        n = (int(rng.integers(8, 13)) if rng.random() < 0.5
             else int(rng.integers(40, 49)))
        # decode-dominated budgets (the production serving regime: output
        # lengths past a handful of tokens), with enough spread that the
        # fixed loop's decode-to-the-longest waste is visible
        budget = int(rng.integers(4, 29))
        toks = rng.integers(0, vocab, (n,)).astype(np.int32)
        reqs.append((int(arrivals[i]),
                     Request(id=i, tokens=toks,
                             sampling=SamplingParams(
                                 max_new_tokens=budget))))
    return reqs


def _drive_sched(engine, reqs):
    """Offer the trace as a burst backlog in arrival order (simulating
    wall-clock arrival gaps on a sub-second smoke drive would measure
    sleep time, not serving throughput — the Poisson draw still fixes
    the queue order and which requests contend).  Returns (wall_s,
    useful_tokens, per-request completion latencies from drive start)."""
    sched = engine.scheduler
    t0 = time.perf_counter()
    done_at = {}
    for _, r in reqs:
        sched.submit(r)
    while sched.has_work:
        for o in sched.step():
            done_at[o.id] = time.perf_counter() - t0
    wall = time.perf_counter() - t0
    useful = sum(r.sampling.max_new_tokens for _, r in reqs)
    return wall, useful, [done_at[r.id] for _, r in reqs]


def _drive_fixed(engine, reqs):
    """FCFS groups of N_LANES, prompts right-padded to the group max,
    each group decoded to the engine-global budget (the longest in the
    workload — the fixed API cannot stop lanes individually)."""
    t0 = time.perf_counter()
    lat = []
    for g in range(0, len(reqs), N_LANES):
        group = [r for _, r in reqs[g:g + N_LANES]]
        width = max(len(r.tokens) for r in group)
        arr = np.zeros((len(group), width), np.int32)
        for i, r in enumerate(group):
            arr[i, :len(r.tokens)] = r.tokens
        engine.generate_with_status_fixed({"tokens": arr})
        lat.extend([time.perf_counter() - t0] * len(group))
    wall = time.perf_counter() - t0
    useful = sum(r.sampling.max_new_tokens for _, r in reqs)
    return wall, useful, lat


def rows():
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.models.lm import Model
    from repro.serve.engine import ServeConfig, ServeEngine

    import dataclasses
    mesh = make_mesh(1, 1)
    # the smoke config (d_model=64) is dispatch-overhead-bound on a CPU
    # host, which hides exactly the compute the scheduler reclaims from
    # the fixed loop (prompt padding, over-decoded budgets); widen it to
    # a small-but-compute-visible model for a meaningful comparison
    cfg = dataclasses.replace(get_config(ARCH, smoke=True),
                              d_model=256, d_ff=1024, head_dim=64)
    model = Model(cfg, mesh)
    params = model.init_params(0)
    reqs = _workload(cfg.vocab)
    longest = max(r.sampling.max_new_tokens for _, r in reqs)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        engine = ServeEngine(model, params, ServeConfig(
            max_new_tokens=longest, n_lanes=N_LANES, page_size=PAGE,
            prefill_chunk=CHUNK, max_seq_len=MAX_SEQ))

    # warm pass for each path (jit compiles), then 3 INTERLEAVED timed
    # passes per path — best wall each, so a host-contention spike during
    # one pass can't flip the comparison (same policy as fused_epilogue's
    # interleaved fused-vs-unfused sweep)
    _drive_sched(engine, reqs)
    _drive_fixed(engine, reqs)
    s_runs, f_runs = [], []
    for _ in range(3):
        s_runs.append(_drive_sched(engine, reqs))
        f_runs.append(_drive_fixed(engine, reqs))
    s_wall, s_useful, s_lat = min(s_runs, key=lambda r: r[0])
    f_wall, f_useful, f_lat = min(f_runs, key=lambda r: r[0])

    s_tok_s = s_useful / s_wall
    f_tok_s = f_useful / f_wall
    p50, p95 = np.percentile(s_lat, [50, 95])
    fp50, fp95 = np.percentile(f_lat, [50, 95])
    return [(
        f"serve_throughput/{ARCH}", 1e6 * s_wall / s_useful,
        f"sched_tok_s={s_tok_s:.1f};fixed_tok_s={f_tok_s:.1f};"
        f"speedup={s_tok_s / f_tok_s:.2f};"
        f"sched_p50_ms={1e3 * p50:.1f};sched_p95_ms={1e3 * p95:.1f};"
        f"fixed_p50_ms={1e3 * fp50:.1f};fixed_p95_ms={1e3 * fp95:.1f};"
        f"n_requests={N_REQUESTS};useful_tokens={s_useful};"
        f"sched_beats_fixed={s_tok_s > f_tok_s}")]


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    print("name,us_per_call,derived")
    ok = True
    for name, us, derived in rows():
        print(f"{name},{us:.2f},{derived}")
        if "sched_beats_fixed=True" not in derived:
            ok = False
    print("ALL_OK" if ok else "SCHED_SLOWER_THAN_FIXED")
    sys.exit(0 if ok else 1)
