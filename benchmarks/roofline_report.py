"""Roofline report: aggregates the dry-run JSONs into per-cell terms.

Emits one row per (arch x shape) single-pod cell with the three roofline
terms, the dominant bottleneck, and the useful-FLOPs ratio."""
import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def records(mesh="single"):
    out = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json"))):
        d = json.load(open(f))
        if d.get("ok") and not d.get("skipped"):
            out.append(d)
    return out


def rows():
    out = []
    for d in records():
        r = d["roofline"]
        mem = d["memory"].get("total_per_device_bytes", 0) / 2 ** 30
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = (r["model_flops"] / 197e12) / bound if bound else 0.0
        out.append((
            f"roofline/{d['arch']}_{d['shape']}", 0.0,
            f"compute_s={r['compute_s']:.3f};memory_s={r['memory_s']:.3f};"
            f"collective_s={r['collective_s']:.3f};dom={r['dominant']};"
            f"useful={r['useful_flops_ratio']:.3f};memGB={mem:.1f};"
            f"roofline_frac={frac:.4f}"))
    if not out:
        out.append(("roofline/no_dryrun_data", 0.0,
                    "run repro.launch.dryrun first"))
    return out
