"""Paper Table I: single AIE kernel results (latency, throughput,
efficiency) — reproduced from the analytical kernel model — plus a
wall-clock microbench of our Pallas-kernel path on the same tile sizes."""
import time

import jax
import jax.numpy as jnp

from repro.core.planner import solve_aie_kernel_tiles
from repro.core import perf_model as pm


def _time_us(fn, *args, iters=15):
    """Median-of-N, each sample individually closed by block_until_ready
    (an unblocked loop measures dispatch-queue depth, and the mean soaks
    up this host's contention bursts)."""
    jax.block_until_ready(fn(*args))  # compile + warm
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return sorted(samples)[len(samples) // 2]


def rows():
    out = []
    for prec in ("int8", "fp32"):
        t = pm.kernel_tile(prec)
        cyc = pm.matmul_kernel_cycles(t, prec)
        eff = pm.matmul_kernel_efficiency(t, prec)
        # wall-clock of our kernel path at the AIE tile size (XLA on CPU)
        from repro.kernels import ops
        dt = jnp.int8 if prec == "int8" else jnp.float32
        a = jnp.ones((t.m, t.k), dt)
        b = jnp.ones((t.k, t.n), dt)
        us = _time_us(jax.jit(lambda a, b: ops.matmul(a, b, mode="xla")),
                      a, b)
        out.append((f"table1/matmul_{prec}_{t.m}x{t.k}x{t.n}", us,
                    f"latency_cyc={cyc};eff={eff:.4f};paper_cyc="
                    f"{1075 if prec == 'int8' else 4329}"))
        acyc = pm.add_kernel_cycles(32, 32, prec)
        aeff = pm.add_kernel_efficiency(32, 32, prec)
        out.append((f"table1/add_{prec}_32x32", 0.0,
                    f"latency_cyc={acyc};eff={aeff:.4f};paper_cyc="
                    f"{164 if prec == 'int8' else 167}"))
        # the optimizer's solution set (int8 must be unique 32x128x32)
        tiles = solve_aie_kernel_tiles(prec)
        out.append((f"table1/optimizer_solutions_{prec}", 0.0,
                    "|".join(f"{x.m}x{x.k}x{x.n}" for x in tiles[:4])))
    return out
