"""End-to-end training driver: train a small LM for a few hundred steps
with the full production substrate (data pipeline, AdamW, checkpointing,
fault-tolerant trainer).

Default is a CPU-sized model so the example completes in minutes; pass
--layers/--d-model to scale to ~100M+ on real hardware (the code path is
identical; use repro.launch.train for full-config production runs).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import logging

import jax

from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokenSource, TokenPipeline
from repro.launch.mesh import make_mesh
from repro.models.lm import Model
from repro.optim import AdamWConfig
from repro.optim.schedule import warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    mesh = make_mesh(jax.device_count(), 1)

    cfg = dataclasses.replace(
        get_config("internlm2-1.8b", smoke=True),
        n_layers=args.layers, d_model=args.d_model,
        n_heads=max(4, args.d_model // 32), n_kv_heads=2,
        head_dim=16, d_ff=args.d_model * 4, vocab=2048)
    model = Model(cfg, mesh)
    print(f"model: {model.n_params():,} params on {mesh.shape}")

    opt = AdamWConfig(lr=args.lr,
                      schedule=warmup_cosine(args.lr, 20, args.steps))
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=100,
                         ckpt_dir=args.ckpt_dir, log_every=20)
    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq)
    src = SyntheticTokenSource(cfg.vocab)

    trainer = Trainer(model, opt, tcfg,
                      lambda s: TokenPipeline(src, dcfg, mesh, cfg,
                                              start_step=s))
    trainer.run(0)
    losses = [m["loss"] for m in trainer.metrics]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({len(trainer.watchdog.events)} stragglers flagged)")
    assert losses[-1] < losses[0], "training did not improve"


if __name__ == "__main__":
    main()
