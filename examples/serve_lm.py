"""Batched serving example: prefill + KV-cache greedy decode.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-12b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models.lm import Model
from repro.serve.engine import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    mesh = make_mesh(jax.device_count(), 1)
    cfg = get_config(args.arch, smoke=True)  # reduced config on CPU
    model = Model(cfg, mesh)
    params = model.init_params(0)

    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(0), (args.batch, args.prompt_len), 0, cfg.vocab,
        jnp.int32)}
    if cfg.prefix_tokens:
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(1),
            (args.batch, cfg.prefix_tokens, cfg.d_model), jnp.float32)
    if cfg.encdec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.enc_frames, cfg.d_model), jnp.float32)

    eng = ServeEngine(model, params, ServeConfig(max_new_tokens=args.max_new))
    t0 = time.time()
    out = eng.generate(batch)
    dt = time.time() - t0
    print(f"[{cfg.name}] generated {out.shape[0]}x{out.shape[1]} tokens in "
          f"{dt:.2f}s ({out.size / dt:.1f} tok/s)")
    print(out)


if __name__ == "__main__":
    main()
