"""Planner demo: reproduce the paper's evaluation tables from the
analytical model, then show the TPU-mode plans for every assigned arch's
dominant GEMMs.

    PYTHONPATH=src python examples/planner_demo.py
"""
from repro.configs import ARCH_IDS, get_config
from repro.core.planner import ArrayConfig, plan_tpu_matmul
from repro.core import perf_model as pm


def main():
    print("== Table II (fp32) ==")
    print(f"{'cfg':>10} {'ours':>10} {'paper':>10} {'err':>8}")
    for xyz in [(13, 4, 6), (10, 3, 10), (11, 4, 7), (11, 3, 9),
                (12, 4, 6), (12, 3, 8)]:
        d = pm.evaluate_design(ArrayConfig(*xyz), "fp32")
        paper = pm.PAPER_THROUGHPUT[("fp32", *xyz)]
        print(f"{xyz[0]}x{xyz[1]}x{xyz[2]:>2} {d.throughput:>9.1f}G "
              f"{paper:>9.1f}G {100 * (d.throughput / paper - 1):>+7.2f}%")

    print("\n== Fig 8 (fp32, 13x4x6) ==")
    for s in (256, 1024, 2048, 8192):
        t = pm.throughput_vs_size(s, ArrayConfig(13, 4, 6), "fp32")
        print(f"  {s:>6}^3: {t:8.1f} GFLOPs")

    print("\n== TPU plans: FFN up-projection per assigned arch ==")
    axes = {"data": 16, "model": 16}
    for a in ARCH_IDS:
        cfg = get_config(a)
        if cfg.d_ff == 0:
            continue
        p = plan_tpu_matmul(4096 * 16, cfg.d_model, cfg.d_ff, "bf16", axes)
        print(f"  {a:>24}: Y={p.shard.y_shards} Z={p.shard.z_shards} "
              f"block={p.block.bm}x{p.block.bk}x{p.block.bn} "
              f"sched={p.shard.schedule}")


if __name__ == "__main__":
    main()
