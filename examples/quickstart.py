"""Quickstart: the MaxEVA pipeline end to end on this host.

1. Solve the paper's AIE optimization (eq. 1-9) and print the design points
   it reports (Table I / II headline configs).
2. Plan a TPU GEMM with the same constraint structure.
3. Run the planned matmul through the kernel path and check it against the
   reference oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import (ArrayConfig, plan_tpu_matmul, pnr_feasible,
                                solve_aie_array, solve_aie_kernel_tiles)
from repro.core import perf_model as pm
from repro.kernels import matmul, ref


def main():
    print("== 1. Paper-faithful AIE optimization (VC1902) ==")
    for prec in ("int8", "fp32"):
        tiles = solve_aie_kernel_tiles(prec)
        print(f"  {prec}: single-kernel optima "
              f"{[t.as_tuple() for t in tiles[:4]]}")
    top = solve_aie_array(top=4)
    for c in top:
        flag = "ok" if pnr_feasible(c) else "PnR-infeasible"
        print(f"  XYZ {c.x}x{c.y}x{c.z}: {c.matmul_kernels} MatMul kernels,"
              f" {c.total_cores} cores [{flag}]")
    best = pm.evaluate_design(ArrayConfig(13, 4, 6), "fp32")
    print(f"  13x4x6 fp32: {best.throughput:.1f} GFLOPs "
          f"(paper: 5442.11), {best.energy_eff:.1f} GFLOPs/W")

    print("\n== 2. TPU-mode plan for a transformer FFN GEMM ==")
    plan = plan_tpu_matmul(16384, 4096, 14336, "bf16",
                           {"data": 16, "model": 16})
    print(f"  shard: X={plan.shard.x_shards} Y={plan.shard.y_shards} "
          f"Z={plan.shard.z_shards} schedule={plan.shard.schedule}")
    print(f"  Pallas block: {plan.block.bm}x{plan.block.bk}x{plan.block.bn}"
          f" ({plan.block.vmem_bytes // 1024} KiB VMEM)")

    print("\n== 3. Planned matmul vs oracle ==")
    a = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (512, 384), jnp.float32)
    got = matmul(a, b, block=(64, 64, 64), mode="interpret")  # Pallas body
    want = ref.matmul_ref(a, b)
    err = float(jnp.max(jnp.abs(got - want)))
    print(f"  max |pallas - oracle| = {err:.2e}")
    assert err < 5e-4  # fp32 accumulation over K=512
    print("  OK")


if __name__ == "__main__":
    main()
