from repro.serve.api import Request, RequestOutput, SamplingParams
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.kv_cache import PageAllocator, PagedKVCache
from repro.serve.scheduler import PagedScheduler

__all__ = [
    "ServeEngine",
    "ServeConfig",
    "Request",
    "RequestOutput",
    "SamplingParams",
    "PageAllocator",
    "PagedKVCache",
    "PagedScheduler",
]
