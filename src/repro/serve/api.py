"""Typed request-level serving API.

The PR 1-7 engine exposed one batch-shaped call — ``generate(batch)``
with engine-global sampling settings.  Real traffic is per-request:
prompts of different lengths arrive at different times, each with its
own sampling knobs and token budget.  This module is the contract for
that surface:

  * ``SamplingParams`` — per-request sampling (previously engine-global
    ``ServeConfig`` fields), validated as loudly as the engine config;
  * ``Request``        — one prompt plus its sampling params;
  * ``RequestOutput``  — the generated tokens plus the PR 5 structured
    status/fault_step, per request instead of per batch lane.

``ServeEngine.submit()/step()/collect()`` consumes and produces these;
``generate()``/``generate_with_status()`` remain as thin fixed-batch
shims over the same scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from repro.robust.guards import STATUS_OK


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling: greedy or temperature sampling, the token
    budget, and the stop token.  Defaults match the historical
    ``ServeConfig`` defaults; ``ServeConfig.sampling_defaults()`` builds
    the engine-default instance for requests that do not carry one."""

    greedy: bool = True
    temperature: float = 1.0
    max_new_tokens: int = 32
    eos_id: Optional[int] = None

    def __post_init__(self):
        # the SAME messages ServeConfig.__post_init__ has always raised —
        # a per-request typo fails as loudly as an engine-config typo
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")
        if not (self.temperature >= 0.0):  # also rejects NaN
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if self.eos_id is not None and self.eos_id < 0:
            raise ValueError(f"eos_id must be >= 0, got {self.eos_id}")


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: an id the caller correlates outputs by, the
    prompt token ids, and optional per-request sampling (``None`` = the
    engine's ``ServeConfig`` defaults).  ``seed`` roots the request's
    private sampling-key stream — the step-``t`` key is
    ``fold_in(PRNGKey(seed), t)``, independent of which lane the request
    lands on or what its neighbors do, so sampled tokens are reproducible
    under arbitrary scheduler churn."""

    id: Union[int, str]
    tokens: np.ndarray
    sampling: Optional[SamplingParams] = None
    seed: int = 0

    def __post_init__(self):
        toks = np.asarray(self.tokens)
        if toks.ndim != 1 or toks.size == 0:
            raise ValueError(
                f"Request.tokens must be a non-empty 1-D id array, got "
                f"shape {toks.shape}")
        if not np.issubdtype(toks.dtype, np.integer):
            raise ValueError(
                f"Request.tokens must be integer ids, got {toks.dtype}")
        object.__setattr__(self, "tokens", toks.astype(np.int32))


@dataclasses.dataclass
class RequestOutput:
    """Structured per-request outcome (the per-lane ``GenerateResult``
    fields, re-keyed by request).

    ``tokens``     [n] generated ids — real tokens only, no pad filler
                   (a quarantined request's array simply ends at its
                   fault step).
    ``status``     one of ``repro.robust.guards.STATUSES``.
    ``fault_step`` step at which the request left ``ok``; -1 if it never
                   did (including ``shed`` — rejected before any step).
    ``n_steps``    decode steps executed for this request.
    ``prompt_len`` prompt tokens consumed (0 for shed requests).
    """

    id: Union[int, str]
    tokens: np.ndarray
    status: str = STATUS_OK
    fault_step: int = -1
    n_steps: int = 0
    prompt_len: int = 0

    @property
    def ok(self) -> bool:
        return self.status == STATUS_OK
