"""Paged KV cache: a free-list page allocator plus the lane page table.

The device side is dumb on purpose — per attention layer, one K and one
V pool of ``n_pages + 1`` fixed-size pages (the extra row is the trash
page idle writes land on), built by ``Model.paged_cache_defs`` and
threaded through the decode/prefill-chunk jits as a donated buffer.  All
policy lives HERE on the host: which physical pages a request owns, and
the [n_lanes, pages_per_lane] int32 table the device reads them through.

Pages are handed out low-id-first from a LIFO free list, so a retired
request's pages are immediately recycled by the next admission; the
logical order within a lane is always ascending positions, which is what
lets ``paged_attention`` treat logical page index as global position.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np


class PageAllocator:
    """Free-list allocator over ``n_pages`` fixed-size pages.

    ``alloc(n)`` returns ``n`` page ids or ``None`` when the pool cannot
    satisfy the request right now (the scheduler's signal to queue or
    shed — never an exception: page exhaustion is a load condition, not
    a bug).  ``free`` returns pages to the list LIFO, so a hot pool keeps
    reusing the same recently-touched pages."""

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {n_pages}")
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, -1, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n < 1:
            raise ValueError(f"alloc needs n >= 1, got {n}")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if not (0 <= p < self.n_pages):
                raise ValueError(f"freeing unknown page {p}")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
        self._free.extend(reversed(pages))


class PagedKVCache:
    """Host-side owner of the device page pools and the lane page table.

    ``pages_per_lane`` bounds one request's footprint (the page-table
    width — a jit-shape constant); ``n_pages`` bounds the whole pool.
    ``admit(lane, total_len)`` maps a lane for a request of
    ``total_len = prompt + max_new`` positions, ``release(lane)`` recycles
    its pages.  ``table_device()`` lazily re-uploads the table only when
    an admission/retirement dirtied it — steady-state decode re-serves
    the cached device array.
    """

    def __init__(self, model, n_lanes: int, n_pages: int, page_size: int,
                 pages_per_lane: int):
        from repro.models import param as pm
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if pages_per_lane < 1:
            raise ValueError(
                f"pages_per_lane must be >= 1, got {pages_per_lane}")
        self.n_lanes = n_lanes
        self.n_pages = n_pages
        self.page_size = page_size
        self.pages_per_lane = pages_per_lane
        self.pools: Dict[str, Any] = pm.initialize(
            model.paged_cache_defs(n_pages, page_size), 0)
        self.allocator = PageAllocator(n_pages)
        self.table = np.full((n_lanes, pages_per_lane), -1, np.int32)
        self.lane_pages: List[Optional[List[int]]] = [None] * n_lanes
        self._table_dev = None

    def pages_needed(self, total_len: int) -> int:
        if total_len < 1:
            # ceil-div would return 0 and alloc(0) raises: a zero-length
            # request owns no positions, so it can never be mapped —
            # callers must shed it structurally (see ``fits_ever``)
            raise ValueError(
                f"total_len must be >= 1, got {total_len}")
        return -(-total_len // self.page_size)

    def fits_ever(self, total_len: int) -> bool:
        """Could this request EVER be admitted (empty pool, any lane)?
        False means shed it now — queueing would deadlock.  Zero-length
        requests (empty prompt AND zero budget) can never be admitted."""
        if total_len < 1:
            return False
        need = self.pages_needed(total_len)
        return need <= min(self.pages_per_lane, self.n_pages)

    def admit(self, lane: int, total_len: int) -> bool:
        """Map ``lane`` for a ``total_len``-position request.  False =
        transient page exhaustion (caller keeps the request queued).

        Requests the pool can NEVER hold (zero-length, or wider than the
        page table) are the caller's job to shed via ``fits_ever``;
        reaching admit with one is a bug, and the check runs BEFORE any
        allocator call so a failed admission never strands pages (the
        old order allocated first and died writing the table row,
        leaking the whole allocation)."""
        assert self.lane_pages[lane] is None, f"lane {lane} already mapped"
        if not self.fits_ever(total_len):
            raise ValueError(
                f"admit of unservable request (total_len={total_len}, "
                f"pages_per_lane={self.pages_per_lane}) — shed it via "
                f"fits_ever before admitting")
        pages = self.allocator.alloc(self.pages_needed(total_len))
        if pages is None:
            return False
        self.lane_pages[lane] = pages
        self.table[lane] = -1
        self.table[lane, :len(pages)] = pages
        self._table_dev = None
        return True

    def release(self, lane: int) -> None:
        pages = self.lane_pages[lane]
        if pages is None:
            return
        self.allocator.free(pages)
        self.lane_pages[lane] = None
        self.table[lane] = -1
        self._table_dev = None

    def table_device(self) -> jnp.ndarray:
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self.table)
        return self._table_dev
