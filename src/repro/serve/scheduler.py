"""Continuous-batching request scheduler over the paged KV cache.

One scheduler owns a fixed set of decode LANES (the jit batch width) and
a page pool; requests flow queue -> lane -> retired while the compiled
programs never change shape:

  * admission  — a queued request takes the lowest free lane and
    allocates ``ceil((prompt + max_new) / page_size)`` pages from the
    free list; transient page exhaustion keeps it queued, an impossible
    fit (longer than a lane can ever hold) sheds it with a structured
    status.  Retired requests free their pages for immediate reuse.
  * chunked prefill — at most ONE fixed-size prompt chunk per lane per
    iteration, every prefilling lane batched into a single [L, chunk]
    dispatch, so a long prompt is spread across iterations and never
    stalls the in-flight decodes it shares the device with.  The last
    chunk's logits seed the request's first token pick.
  * decode     — every lane with at least one picked token steps in a
    single [L]-wide dispatch; idle lanes ride along with position -1
    (their cache writes land on the trash page, their logits rows are
    ignored).  A lane's math is bitwise independent of its neighbors,
    which is what keeps a request's tokens identical whether it runs
    alone or amid churn.  The attention inside the dispatch is the
    paged flash-decode kernel — per-tile dots at the pools' storage
    dtype, rank-order split combine — whose masking gives unmapped
    pages and idle lanes exact-zero contributions, so the isolation
    invariant holds at the kernel level, not by host bookkeeping.
  * pick       — one fused guarded dispatch picks every fresh lane's
    token with per-request sampling params (greedy mask, temperature,
    fold_in(request seed, step) keys) and the PR 5 health probes; the
    per-request quarantine/degrade/timeout/shed statuses come out of the
    same host bookkeeping that owned them per-lane before.

The host loop is ordered to OVERLAP with the device: admissions (a few
microseconds of allocator bookkeeping) run first so a lane freed last
iteration refills before this iteration's dispatches, then the chunk and
decode steps go out back-to-back, fault/deadline bookkeeping and output
assembly run while the device works, and only the token pick's host
transfer synchronizes.  ``FaultPlan`` hooks ride at the same boundaries
as the fixed-batch loop (``maybe_stall_lanes`` / ``perturb_logits_lanes``
— per-lane step vectors instead of one global step).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.robust.guards import (
    STATUS_DEGRADED,
    STATUS_NONFINITE,
    STATUS_OK,
    STATUS_SHED,
    STATUS_TIMEOUT,
    NumericalHealthError,
)
from repro.serve.api import Request, RequestOutput, SamplingParams
from repro.serve.kv_cache import PagedKVCache


@dataclasses.dataclass
class _Lane:
    """One admitted request's host-side state."""

    req: Request
    sp: SamplingParams
    seq: int                          # admission order (prefill FIFO)
    key_base: np.ndarray              # uint32[2] PRNGKey(req.seed)
    n_prefilled: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)
    status: str = STATUS_OK
    fault_step: int = -1
    degraded: bool = False
    calib: float = 1.0
    calibrated: bool = False
    deadline: Optional[float] = None

    @property
    def prefilled(self) -> bool:
        return self.n_prefilled >= len(self.req.tokens)


class PagedScheduler:
    """Fixed-lane continuous-batching loop; see the module docstring.

    Built by ``ServeEngine`` (which owns the jitted programs); exposed
    knobs are the jit-shape constants: lane count, page geometry, and the
    prefill chunk size."""

    def __init__(self, engine, *, n_lanes: int, pages_per_lane: int,
                 n_pages: int, page_size: int, chunk: int):
        if n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.engine = engine
        self.n_lanes = n_lanes
        self.chunk = chunk
        self.kv = PagedKVCache(engine.model, n_lanes, n_pages, page_size,
                               pages_per_lane)
        self.lanes: List[Optional[_Lane]] = [None] * n_lanes
        self.queue: deque = deque()
        self.timed_out = False
        self._logits = None               # [L, Vp] device pick buffer
        self._last_tok = np.zeros((n_lanes,), np.int32)
        self._stall_fired: set = set()
        self._seq = 0
        # lane-constant pick args (keys, sampling modes, calibration) are
        # device-cached and only re-uploaded when lane membership or a
        # lane's calibration/degradation changes — the per-iteration
        # upload is just the step vector
        self._lane_gen = 0
        self._pick_gen = -1
        self._pick_const = None
        self._degr_dev = None

    # -- surface ---------------------------------------------------------------

    @property
    def n_active(self) -> int:
        return sum(1 for a in self.lanes if a is not None)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or self.n_active > 0

    def reset_fault_state(self) -> None:
        """Per-drain fault bookkeeping (stall once-per-drain tracking and
        the timeout flag) — cleared by the shim between generate calls so
        a reused scheduler replays a FaultPlan from scratch."""
        self._stall_fired.clear()
        self.timed_out = False

    def submit(self, req: Request) -> None:
        sp = req.sampling if req.sampling is not None \
            else self.engine.scfg.sampling_defaults()
        self.queue.append((req, sp))

    def run_to_completion(self, fault_plan=None) -> List[RequestOutput]:
        outs: List[RequestOutput] = []
        idle = 0
        while self.has_work:
            before = self.n_active
            outs.extend(self.step(fault_plan))
            if self.queue and before == 0 and self.n_active == 0:
                idle += 1
                if idle > 2:
                    raise RuntimeError(
                        "scheduler stalled: queue non-empty but nothing "
                        "admits (page pool smaller than one request?)")
            else:
                idle = 0
        return outs

    # -- one iteration ---------------------------------------------------------

    def step(self, fault_plan=None) -> List[RequestOutput]:
        """Advance every phase one tick; returns requests finished NOW."""
        eng = self.engine
        scfg = eng.scfg
        plan = fault_plan if (fault_plan is not None
                              and fault_plan.enabled) else None
        finished: List[RequestOutput] = []
        L = self.n_lanes
        fresh = np.zeros((L,), bool)

        # 1. admissions first, so a request admitted into a lane freed
        # LAST iteration rides this iteration's chunk dispatch instead of
        # waiting one more tick (page-allocator bookkeeping is a few
        # microseconds of host work)
        self._admit(finished)

        # 2. chunked prefill: ONE chunk per prefilling lane, ALL such
        # lanes batched into a single [L, C] dispatch (idle lanes ride
        # with positions -1 — trash-page writes, masked attention, a
        # gathered logits row the host ignores).  A long prompt spreads
        # over iterations instead of stalling in-flight decodes, while
        # same-time admissions stay in lockstep (what makes the
        # generate(batch) shim bitwise-match the fixed loop).
        pre = [l for l, a in enumerate(self.lanes)
               if a is not None and not a.prefilled]
        completed = np.zeros((L,), bool)
        chunk_rows = None
        if pre:
            tc = np.zeros((L, self.chunk), np.int32)
            pc = np.full((L, self.chunk), -1, np.int32)
            last = np.full((L,), -1, np.int32)
            for l in pre:
                a = self.lanes[l]
                start = a.n_prefilled
                n = min(self.chunk, len(a.req.tokens) - start)
                tc[l, :n] = a.req.tokens[start:start + n]
                pc[l, :n] = np.arange(start, start + n, dtype=np.int32)
                last[l] = n - 1
                a.n_prefilled += n
                if a.prefilled:
                    completed[l] = True   # row seeds the first pick below
            chunk_rows, self.kv.pools = eng._prefill_chunk(
                eng.params, self.kv.pools, jnp.asarray(tc),
                jnp.asarray(pc), self.kv.table_device(),
                jnp.asarray(last))

        # 3. decode: one [L]-wide step for every lane holding tokens
        dec = [l for l, a in enumerate(self.lanes)
               if a is not None and a.prefilled and a.tokens]
        fp_logits = None
        if dec:
            pos_np = np.full((L,), -1, np.int32)
            for l in dec:
                a = self.lanes[l]
                pos_np[l] = len(a.req.tokens) + len(a.tokens) - 1
            tok_dev = jnp.asarray(self._last_tok[:, None])
            pos_dev = jnp.asarray(pos_np)
            pt_dev = self.kv.table_device()
            if (eng._decode_paged_fp is not None
                    and any(self.lanes[l].degraded for l in dec)):
                # dispatched BEFORE the donating step: it reads the pool
                # buffers that step consumes
                fp_logits, _ = eng._decode_paged_fp(
                    eng._fp_params, self.kv.pools, tok_dev, pos_dev,
                    pt_dev)
            self._logits, self.kv.pools = eng._decode_paged(
                eng.params, self.kv.pools, tok_dev, pos_dev, pt_dev)
            fresh[dec] = True

        # 4. inject completed lanes' final-chunk logits rows into the
        # pick buffer — one masked dispatch for every lane that finished
        # its prompt this iteration
        if completed.any():
            if self._logits is None:
                self._logits = chunk_rows
            else:
                self._logits = eng._inject_rows(
                    self._logits, chunk_rows, jnp.asarray(completed))
            fresh |= completed

        # 5. faults + per-request deadlines (stall first, like the fixed
        # loop: a stalled host is exactly what the budget must convert)
        steps = np.full((L,), -1, np.int64)
        for l, a in enumerate(self.lanes):
            if a is not None and fresh[l]:
                steps[l] = len(a.tokens)
        if plan is not None:
            plan.maybe_stall_lanes(steps, self._stall_fired)
        now = time.monotonic()
        for l, a in enumerate(self.lanes):
            if a is not None and a.deadline is not None \
                    and now > a.deadline:
                a.status = STATUS_TIMEOUT
                a.fault_step = len(a.tokens)
                self.timed_out = True
                fresh[l] = False
                steps[l] = -1
                self._retire(l, finished)
        if not fresh.any():
            return finished
        if plan is not None:
            self._logits = plan.perturb_logits_lanes(steps, self._logits)

        # 6. one fused pick + health probe over all lanes.  The
        # lane-constant args (keys, sampling modes, calibration) come
        # from the generation-counted device cache; only the step vector
        # uploads every iteration.  Non-fresh lanes carry step -1 — their
        # fold_in keys differ from a live lane's but their picks are
        # never read.
        if self._pick_gen != self._lane_gen:
            kb = np.zeros((L, 2), np.uint32)
            greedy = np.ones((L,), bool)
            temp = np.ones((L,), np.float32)
            calib = np.ones((L,), np.float32)
            degr = np.zeros((L,), bool)
            for l, a in enumerate(self.lanes):
                if a is None:
                    continue
                kb[l] = a.key_base
                greedy[l] = a.sp.greedy
                temp[l] = a.sp.temperature
                calib[l] = a.calib
                degr[l] = a.degraded
            self._pick_const = (jnp.asarray(kb), jnp.asarray(greedy),
                                jnp.asarray(temp), jnp.asarray(calib))
            self._degr_dev = jnp.asarray(degr)
            self._pick_gen = self._lane_gen
        kb_d, greedy_d, temp_d, calib_d = self._pick_const
        steps_d = jnp.asarray(steps.astype(np.int32))
        pick_args = (kb_d, steps_d, greedy_d, temp_d, calib_d)
        tok_j, fin_j, absmax_j, sat_j = eng._pick_paged(
            self._logits, *pick_args)
        if fp_logits is not None:
            # degraded lanes pick from the fp32 fallback logits; the same
            # keys keep healthy lanes bitwise unchanged
            tok_fp, _, _, _ = eng._pick_paged(fp_logits, *pick_args)
            tok_j = jnp.where(self._degr_dev, tok_fp, tok_j)
        tok_np = np.asarray(tok_j)
        fin_np = np.asarray(fin_j)
        absmax_np = np.asarray(absmax_j)
        sat_np = np.asarray(sat_j)

        # 7. guards + commit + retire
        guards_on = scfg.guards and scfg.on_nonfinite != "off"
        sat_on = scfg.guards and scfg.int8
        if guards_on and scfg.on_nonfinite == "raise":
            bad = [l for l in range(L) if fresh[l] and not fin_np[l]]
            if bad:
                t = len(self.lanes[bad[0]].tokens)
                raise NumericalHealthError(
                    f"non-finite logits at decode step {t} in lanes {bad}")
        for l in range(L):
            a = self.lanes[l]
            if a is None or not fresh[l]:
                continue
            t = len(a.tokens)
            if guards_on and not fin_np[l]:
                a.status = STATUS_NONFINITE
                a.fault_step = t
                self._retire(l, finished)
                continue
            if sat_on:
                if not a.calibrated:
                    # the request's first decode logits calibrate its probe
                    a.calib = float(np.maximum(absmax_np[l],
                                               np.float32(1e-6)))
                    a.calibrated = True
                    self._lane_gen += 1
                elif (fin_np[l] and not a.degraded
                        and sat_np[l] > scfg.saturation_threshold):
                    a.degraded = True
                    self._lane_gen += 1
                    if a.status == STATUS_OK:
                        a.status = STATUS_DEGRADED
                        a.fault_step = t
            tk = int(tok_np[l])
            a.tokens.append(tk)
            self._last_tok[l] = tk
            if (a.sp.eos_id is not None and tk == a.sp.eos_id) \
                    or len(a.tokens) >= a.sp.max_new_tokens:
                self._retire(l, finished)
        return finished

    # -- internals -------------------------------------------------------------

    def _admit(self, finished: List[RequestOutput]) -> None:
        while self.queue:
            free = [l for l, a in enumerate(self.lanes) if a is None]
            if not free:
                return
            req, sp = self.queue[0]
            total = len(req.tokens) + sp.max_new_tokens
            if not self.kv.fits_ever(total):
                # could NEVER fit a lane: structured shed, not a crash.
                # Covers over-wide requests AND zero-length ones (empty
                # prompt + zero budget, total == 0): fits_ever is the
                # single gate, so ceil-div/alloc(0) never see them —
                # reaching admit with an unservable total is a bug it
                # raises on rather than leaking pages over
                self.queue.popleft()
                finished.append(RequestOutput(
                    id=req.id, tokens=np.zeros((0,), np.int32),
                    status=STATUS_SHED, fault_step=-1, n_steps=0,
                    prompt_len=0))
                continue
            l = free[0]
            if not self.kv.admit(l, total):
                return  # transient page exhaustion: stay queued
            self.queue.popleft()
            a = _Lane(req=req, sp=sp, seq=self._seq,
                      key_base=self.engine._request_key(req.seed))
            self._seq += 1
            scfg = self.engine.scfg
            if scfg.request_timeout_s is not None:
                a.deadline = time.monotonic() + scfg.request_timeout_s
            self.lanes[l] = a
            self._lane_gen += 1

    def _retire(self, lane: int, finished: List[RequestOutput]) -> None:
        a = self.lanes[lane]
        self.kv.release(lane)
        self.lanes[lane] = None
        self._lane_gen += 1
        finished.append(RequestOutput(
            id=a.req.id, tokens=np.asarray(a.tokens, np.int32),
            status=a.status, fault_step=a.fault_step,
            n_steps=len(a.tokens), prompt_len=len(a.req.tokens)))
