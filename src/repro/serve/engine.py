"""Batched serving engine: prefill + decode over a fixed-shape batch slot
("continuous batching lite": fixed batch lanes, per-lane completion),
hardened with per-lane numerical-health guards.

The step functions are jit'd once per (batch, max_len); logits come back
vocab-sharded over the model axis and are argmax'd shard-locally then
combined — no full-vocab gather ever materializes on one device.

Robustness contract (see ``docs/robustness.md`` for the fault model):

  * one poisoned lane never takes down the batch: a NaN/Inf logit
    quarantines THAT lane to a structured ``quarantined_nonfinite``
    status while its peers keep decoding bitwise-unchanged;
  * int8 decode degrades instead of corrupting: a fixed-scale saturation
    probe (calibrated on the first decode logits) flags lanes whose
    activation range drifted past the int8 envelope, and with
    ``fp32_fallback`` their remaining tokens come from the retained
    full-precision weights;
  * a wall-clock budget (``request_timeout_s``) converts a hung host
    step into per-lane ``timeout`` statuses with partial tokens;
  * admission control (``max_lanes``) sheds surplus lanes at the door
    with a ``shed`` status instead of overcommitting the batch slot.

The guards ride INSIDE the jitted token pick (one fused dispatch per
step either way), so the traced ``decode_step`` HLO is byte-identical
with guards on/off and all PR 2-4 HLO invariants (single packed-QKV
GEMM dispatch, zero int8 bounces, schedule determinism) are untouched.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import Model
from repro.robust.guards import (
    STATUS_DEGRADED,
    STATUS_NONFINITE,
    STATUS_OK,
    STATUS_SHED,
    STATUS_TIMEOUT,
    GenerateResult,
    NumericalHealthError,
)

_ON_NONFINITE = ("quarantine", "raise", "off")


def _decode_jit(model: Model):
    """The production decode-step program: KV cache donated (argnums 1).

    Single construction site, used by both ``ServeEngine.__init__`` and
    the contract auditor (``ServeEngine.decode_step_lowered``) — the
    served program and the audited program cannot drift apart."""
    return jax.jit(model.decode_step, donate_argnums=(1,))


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    greedy: bool = True
    temperature: float = 1.0
    # End-to-end int8 serving: projection weights are quantized ONCE at
    # engine construction (column-wise scales) and decode runs
    # int8 x int8 -> int32 GEMMs with scales re-applied in the fused
    # epilogues — no fp32 dequant/requant bounce between GEMMs (the
    # paper's headline 14x-over-fp32 pipeline, §IV-C1).
    int8: bool = False
    # -- robustness ----------------------------------------------------------
    # per-lane health guards (finite logits; int8 saturation probe).
    # Cost rides inside the jitted token pick — see the guard-overhead
    # bench row; the traced decode HLO is identical either way.
    guards: bool = True
    # what a non-finite logit does: 'quarantine' the lane (structured
    # per-request status, peers unaffected), 'raise' NumericalHealthError
    # (fail-stop), or 'off' (pre-hardening behavior)
    on_nonfinite: str = "quarantine"
    # token id emitted for a lane past its quarantine/shed point
    pad_id: int = 0
    # dtype logits are sampled in (jit-cast before the pick)
    logits_dtype: str = "float32"
    # admission control: lanes beyond this are shed at the door (None =
    # admit the whole batch, the pre-hardening behavior)
    max_lanes: Optional[int] = None
    # wall-clock budget per generate() call; on expiry running lanes get
    # a structured 'timeout' status with their partial tokens (None = no
    # budget)
    request_timeout_s: Optional[float] = None
    # int8 only: retain the fp32 weights and finish saturated lanes on
    # them (memory cost: both copies live; off by default)
    fp32_fallback: bool = False
    # int8 only: per-lane fraction of logit values outside the calibrated
    # int8 envelope above which the lane degrades
    saturation_threshold: float = 0.25

    def __post_init__(self):
        # fail LOUDLY on bad values (mirrors XYZConfig's unknown-schedule
        # ValueError): a serving config typo silently defaulting is the
        # failure mode the validation exists to prevent
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")
        if not (self.temperature >= 0.0):  # also rejects NaN
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if self.eos_id is not None and self.eos_id < 0:
            raise ValueError(f"eos_id must be >= 0, got {self.eos_id}")
        if self.pad_id < 0:
            raise ValueError(f"pad_id must be >= 0, got {self.pad_id}")
        if self.on_nonfinite not in _ON_NONFINITE:
            raise ValueError(
                f"unknown on_nonfinite {self.on_nonfinite!r}; valid "
                f"modes are {_ON_NONFINITE}")
        try:
            dt = jnp.dtype(self.logits_dtype)
        except TypeError as e:
            raise ValueError(
                f"unknown logits_dtype {self.logits_dtype!r}: {e}") from None
        if not jnp.issubdtype(dt, jnp.floating):
            raise ValueError(
                f"logits_dtype must be a float dtype, got "
                f"{self.logits_dtype!r}")
        if self.max_lanes is not None and self.max_lanes < 1:
            raise ValueError(
                f"max_lanes must be >= 1 (or None), got {self.max_lanes}")
        if self.request_timeout_s is not None \
                and not (self.request_timeout_s > 0):
            raise ValueError(
                f"request_timeout_s must be > 0 (or None), got "
                f"{self.request_timeout_s}")
        if not (0.0 < self.saturation_threshold <= 1.0):
            raise ValueError(
                f"saturation_threshold must be in (0, 1], got "
                f"{self.saturation_threshold}")
        if self.fp32_fallback and not self.int8:
            raise ValueError(
                "fp32_fallback without int8 is meaningless: the engine "
                "already serves full precision")


class ServeEngine:
    def __init__(self, model: Model, params, scfg: ServeConfig = ServeConfig()):
        self.model = model
        self._fp_params = None
        if scfg.int8:
            # one-shot weight-quantization pass (idempotent): the fp
            # weights are replaced, not duplicated — unless fp32_fallback
            # asks the engine to keep them for saturated-lane degradation
            fp = params
            params = model.quantize_params_for_serving(params)
            if scfg.fp32_fallback:
                self._fp_params = fp
        self.params = params
        self.scfg = scfg
        self._ldtype = jnp.dtype(scfg.logits_dtype)
        self._prefill = jax.jit(
            lambda p, b, ml: model.prefill(p, b, max_len=ml),
            static_argnums=(2,))
        self._decode = _decode_jit(model)
        # fp32 fallback decode: non-donating (it reads the cache the int8
        # step subsequently consumes) and traced on the fp param tree
        self._decode_fp = (jax.jit(model.decode_step)
                           if self._fp_params is not None else None)
        self._pick_guarded = jax.jit(self._pick_and_probe)

    @classmethod
    def decode_step_lowered(cls, model: Model, scfg: ServeConfig,
                            batch: int, prompt_len: int):
        """Lower the engine's decode step ABSTRACTLY (no real weights)
        for the HLO contract auditor.

        Returns ``(lowered, donated_param_numbers)``: the same jit the
        engine serves (``_decode_jit`` — KV cache donated), lowered on
        ShapeDtypeStructs, plus the flat parameter numbers of the donated
        cache leaves (params flatten first, then cache — the numbers the
        compiled module's ``input_output_alias`` must cover for the
        donation to have actually been granted)."""
        aparams = model.abstract_params()
        if scfg.int8:
            aparams = jax.eval_shape(model.quantize_params_for_serving,
                                     aparams)
        max_len = prompt_len + scfg.max_new_tokens
        acache = model.abstract_cache(batch, max_len)
        tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = _decode_jit(model).lower(aparams, acache, tok, pos)
        n_p = len(jax.tree_util.tree_leaves(aparams))
        n_c = len(jax.tree_util.tree_leaves(acache))
        return lowered, tuple(range(n_p, n_p + n_c))

    @classmethod
    def from_checkpoint(cls, model: Model, ckpt_dir: str,
                        step: Optional[int] = None,
                        scfg: ServeConfig = ServeConfig(),
                        fallback: bool = True) -> "ServeEngine":
        """Restore params onto the model's mesh and serve them.  Legacy
        checkpoints with unpacked wq/wk/wv leaves are packed into the
        ``wqkv`` schema in place (CheckpointManager migration).  With
        ``fallback`` (the serving default) a checkpoint that fails
        integrity verification is reported and the newest earlier intact
        step is served instead — stale weights beat no weights.  With
        ``scfg.int8`` the restored weights immediately go through the
        one-shot serving quantization pass (see ``ServeEngine.__init__``);
        the fp checkpoint on disk is untouched."""
        from repro.checkpoint import CheckpointManager
        from repro.launch.specs import param_io_specs
        mgr = CheckpointManager(ckpt_dir)
        abstract, specs = param_io_specs(model)
        _, params = mgr.restore(step, abstract, mesh=model.mesh,
                                specs=specs, defs=model.param_defs(),
                                fallback=fallback)
        return cls(model, params, scfg)

    # -- token pick + fused health probe --------------------------------------

    def _pick_math(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        v = self.model.cfg.vocab
        logits = logits[:, :v].astype(self._ldtype)
        if self.scfg.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        scaled = logits / max(self.scfg.temperature, 1e-6)
        return jax.random.categorical(key, scaled).astype(jnp.int32)

    def _pick(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        return self._pick_math(logits, key)

    def _pick_and_probe(self, logits, key, calib):
        """Token pick + per-lane health probes in ONE jitted dispatch (the
        guarded path costs one fused call, same as the unguarded pick):

          finite  [B] — all-finite over the lane's real-vocab logits;
          absmax  [B] — per-lane absmax (step-0 calibration source);
          sat     [B] — fraction of the lane's logits that saturate a
                        fixed int8 scale calibrated to ``calib`` (the
                        quantize-epilogue saturation counter applied to
                        the decode canary tensor).
        """
        from repro.kernels.quantize import (quantize_fixed_scale,
                                            saturation_fraction)
        v = self.model.cfg.vocab
        real = logits[:, :v]
        tok = self._pick_math(logits, key)
        finite = jnp.all(jnp.isfinite(real), axis=-1)
        absmax = jnp.max(jnp.abs(real), axis=-1)
        scale = jnp.maximum(calib, 1e-6)[:, None] / 127.0
        sat = saturation_fraction(quantize_fixed_scale(real, scale))
        return tok, finite, absmax, sat

    # -- generation ------------------------------------------------------------

    def generate(self, batch: Dict[str, jnp.ndarray], seed: int = 0
                 ) -> np.ndarray:
        """batch['tokens'] [B, S] -> generated tokens [B, <=max_new]."""
        return self.generate_with_status(batch, seed).tokens

    def generate_with_status(self, batch: Dict[str, jnp.ndarray],
                             seed: int = 0,
                             fault_plan=None) -> GenerateResult:
        """Guarded generation with structured per-lane outcomes.

        ``fault_plan`` (a ``repro.robust.FaultPlan``) injects
        deterministic faults for testing; ``None`` (production) leaves
        the loop on the exact pre-hardening compute path.
        """
        scfg = self.scfg
        plan = fault_plan if (fault_plan is not None
                              and fault_plan.enabled) else None
        if plan is not None:
            plan.on_generate_start()

        # admission control: shed surplus lanes before any compute
        b_full = batch["tokens"].shape[0]
        admit = b_full if scfg.max_lanes is None \
            else min(b_full, scfg.max_lanes)
        if admit < b_full:
            batch = {k: v[:admit] for k, v in batch.items()}

        cfg = self.model.cfg
        b, s = batch["tokens"].shape
        prompt_len = s + (cfg.prefix_tokens or 0)
        max_len = prompt_len + scfg.max_new_tokens
        logits, cache = self._prefill(self.params, batch, max_len)
        # the clock starts once prefill is dispatched: the budget bounds
        # the decode loop (where a hung host step strands a request), not
        # the one-time jit compile of a cold engine
        deadline = (time.monotonic() + scfg.request_timeout_s
                    if scfg.request_timeout_s is not None else None)

        status = np.array([STATUS_OK] * admit, dtype=object)
        fault_step = np.full((admit,), -1, np.int64)
        done = np.zeros((admit,), bool)
        degraded = np.zeros((admit,), bool)
        timed_out = False
        calib = None          # step-0 per-lane absmax (int8 probe)
        fp_logits = None      # fp32-fallback logits for degraded lanes
        out: List[np.ndarray] = []

        key = jax.random.PRNGKey(seed)
        pick_key = key  # token 0 samples with the unsplit key (legacy)
        guards_on = scfg.guards and scfg.on_nonfinite != "off"
        sat_on = scfg.guards and scfg.int8

        for i in range(scfg.max_new_tokens):
            if plan is not None:
                plan.maybe_stall(i)
            if deadline is not None and time.monotonic() > deadline:
                running = ~done
                status[running] = STATUS_TIMEOUT
                fault_step[running & (fault_step < 0)] = i
                timed_out = True
                break
            if plan is not None:
                logits = plan.perturb_logits(i, logits)

            if guards_on or sat_on:
                cal = (jnp.ones((admit,), jnp.float32) if calib is None
                       else calib)
                tok, fin_j, absmax_j, sat_j = self._pick_guarded(
                    logits, pick_key, cal)
                if guards_on:
                    newly_bad = ~np.asarray(fin_j) & ~done
                    if newly_bad.any():
                        lanes = np.flatnonzero(newly_bad)
                        if scfg.on_nonfinite == "raise":
                            raise NumericalHealthError(
                                f"non-finite logits at decode step {i} in "
                                f"lanes {lanes.tolist()}")
                        status[newly_bad] = STATUS_NONFINITE
                        fault_step[newly_bad & (fault_step < 0)] = i
                if sat_on:
                    if calib is None:
                        calib = jnp.maximum(absmax_j, 1e-6)
                    else:
                        sat = np.asarray(sat_j)
                        newly_sat = ((sat > scfg.saturation_threshold)
                                     & ~degraded & ~done
                                     & np.asarray(fin_j))
                        if newly_sat.any():
                            degraded |= newly_sat
                            mark = newly_sat & (status == STATUS_OK)
                            status[mark] = STATUS_DEGRADED
                            fault_step[mark & (fault_step < 0)] = i
            else:
                tok = self._pick(logits, pick_key)

            if fp_logits is not None:
                # degraded lanes pick from the fp32 fallback logits; the
                # same key keeps healthy lanes bitwise unchanged
                tok_fp = self._pick(fp_logits, pick_key)
                tok = jnp.where(jnp.asarray(degraded), tok_fp, tok)

            tok_np = np.asarray(tok)
            quarantined = status == STATUS_NONFINITE
            if quarantined.any():
                tok_np = np.where(quarantined, scfg.pad_id,
                                  tok_np).astype(tok_np.dtype)
            out.append(tok_np)
            if scfg.eos_id is not None:
                done = done | (tok_np == scfg.eos_id)
            done = done | quarantined
            if done.all() or i == scfg.max_new_tokens - 1:
                break

            pos = jnp.asarray(prompt_len + i, jnp.int32)
            tok_dev = jnp.asarray(tok_np)[:, None]
            if degraded.any() and self._decode_fp is not None:
                # dispatched BEFORE the donating int8 step: it reads the
                # cache buffers that step consumes
                fp_logits, _ = self._decode_fp(self._fp_params, cache,
                                               tok_dev, pos)
            else:
                fp_logits = None
            logits, cache = self._decode(self.params, cache, tok_dev, pos)
            key, pick_key = jax.random.split(key)

        tokens = (np.stack(out, axis=1) if out
                  else np.zeros((admit, 0), np.int32))
        if admit < b_full:
            shed = b_full - admit
            full = np.full((b_full, tokens.shape[1]), scfg.pad_id,
                           tokens.dtype)
            full[:admit] = tokens
            tokens = full
            status = np.concatenate(
                [status, np.array([STATUS_SHED] * shed, dtype=object)])
            fault_step = np.concatenate(
                [fault_step, np.zeros((shed,), np.int64)])
        return GenerateResult(tokens=tokens, status=list(status),
                              fault_step=fault_step, n_steps=len(out),
                              timed_out=timed_out, admitted=admit)
