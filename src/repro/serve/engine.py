"""Batched serving engine: prefill + decode over a fixed-shape batch slot
("continuous batching lite": fixed batch lanes, per-lane completion).

The step functions are jit'd once per (batch, max_len); logits come back
vocab-sharded over the model axis and are argmax'd shard-locally then
combined — no full-vocab gather ever materializes on one device.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import Model


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    greedy: bool = True
    temperature: float = 1.0
    # End-to-end int8 serving: projection weights are quantized ONCE at
    # engine construction (column-wise scales) and decode runs
    # int8 x int8 -> int32 GEMMs with scales re-applied in the fused
    # epilogues — no fp32 dequant/requant bounce between GEMMs (the
    # paper's headline 14x-over-fp32 pipeline, §IV-C1).
    int8: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, scfg: ServeConfig = ServeConfig()):
        self.model = model
        if scfg.int8:
            # one-shot weight-quantization pass (idempotent): the fp
            # weights are replaced, not duplicated — the engine holds one
            # int8 copy plus f32 column scales
            params = model.quantize_params_for_serving(params)
        self.params = params
        self.scfg = scfg
        self._prefill = jax.jit(
            lambda p, b, ml: model.prefill(p, b, max_len=ml),
            static_argnums=(2,))
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))

    @classmethod
    def from_checkpoint(cls, model: Model, ckpt_dir: str,
                        step: Optional[int] = None,
                        scfg: ServeConfig = ServeConfig()) -> "ServeEngine":
        """Restore params onto the model's mesh and serve them.  Legacy
        checkpoints with unpacked wq/wk/wv leaves are packed into the
        ``wqkv`` schema in place (CheckpointManager migration).  With
        ``scfg.int8`` the restored weights immediately go through the
        one-shot serving quantization pass (see ``ServeEngine.__init__``);
        the fp checkpoint on disk is untouched."""
        from repro.checkpoint import CheckpointManager
        from repro.launch.specs import param_io_specs
        mgr = CheckpointManager(ckpt_dir)
        abstract, specs = param_io_specs(model)
        _, params = mgr.restore(step, abstract, mesh=model.mesh,
                                specs=specs, defs=model.param_defs())
        return cls(model, params, scfg)

    def _pick(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        v = self.model.cfg.vocab
        logits = logits[:, :v]
        if self.scfg.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        scaled = logits / max(self.scfg.temperature, 1e-6)
        return jax.random.categorical(key, scaled).astype(jnp.int32)

    def generate(self, batch: Dict[str, jnp.ndarray], seed: int = 0
                 ) -> np.ndarray:
        """batch['tokens'] [B, S] -> generated tokens [B, <=max_new]."""
        cfg, scfg = self.model.cfg, self.scfg
        b, s = batch["tokens"].shape
        prompt_len = s + (cfg.prefix_tokens or 0)
        max_len = prompt_len + scfg.max_new_tokens
        logits, cache = self._prefill(self.params, batch, max_len)

        key = jax.random.PRNGKey(seed)
        out: List[np.ndarray] = []
        done = np.zeros((b,), bool)
        tok = self._pick(logits, key)
        for i in range(scfg.max_new_tokens):
            out.append(np.asarray(tok))
            if scfg.eos_id is not None:
                done |= np.asarray(tok) == scfg.eos_id
                if done.all():
                    break
            pos = jnp.asarray(prompt_len + i, jnp.int32)
            logits, cache = self._decode(self.params, cache, tok[:, None],
                                         pos)
            key, sub = jax.random.split(key)
            tok = self._pick(logits, sub)
        return np.stack(out, axis=1)
