"""Request-level serving engine: continuous batching over a paged KV
cache, behind the typed ``submit()/step()/collect()`` API.

Two generations of serving loop live here:

  * the PAGED path (``repro.serve.scheduler.PagedScheduler``): requests
    admit into recycled decode lanes backed by a page-table-addressed KV
    pool, prompts prefill in fixed-size chunks interleaved with decode
    steps, and the whole engine compiles exactly TWO step programs — one
    ``[n_lanes]``-wide decode and one ``[n_lanes, chunk]`` prefill —
    that never retrace as requests come and go;
  * the FIXED path (``generate_with_status_fixed``): the PR 5-7
    lockstep batch loop, kept verbatim as the fallback for model
    families the paged attention path does not cover (encoder-decoder,
    prefix-token conditioning, multi-device meshes) and as the reference
    the shim is proven bitwise-equal against.

``generate()`` / ``generate_with_status()`` remain the batch-shaped
surface: on paged-capable models they are thin shims that submit one
request per batch row to a cached fixed-geometry scheduler and reshape
the ``RequestOutput``s into the legacy ``GenerateResult``.

Robustness contract (see ``docs/robustness.md`` for the fault model):

  * one poisoned lane never takes down the batch: a NaN/Inf logit
    quarantines THAT request to a structured ``quarantined_nonfinite``
    status while its peers keep decoding bitwise-unchanged;
  * int8 decode degrades instead of corrupting: a fixed-scale saturation
    probe (calibrated on each request's first decode logits) flags
    requests whose activation range drifted past the int8 envelope, and
    with ``fp32_fallback`` their remaining tokens come from the retained
    full-precision weights;
  * a wall-clock budget (``request_timeout_s``) converts a hung host
    step into per-request ``timeout`` statuses with partial tokens;
  * admission is never a crash: a request that could never fit a lane's
    page budget (or a batch row past ``max_lanes``) is shed with a
    structured ``shed`` status, ``fault_step = -1``.

The guards ride INSIDE the jitted token pick (one fused dispatch per
step either way), so the traced decode HLO — dense or paged — is
byte-identical with guards on/off and all PR 2-4 HLO invariants (single
packed-QKV GEMM dispatch, zero int8 bounces, schedule determinism) are
untouched.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import Model
from repro.robust.guards import (
    STATUS_DEGRADED,
    STATUS_NONFINITE,
    STATUS_OK,
    STATUS_SHED,
    STATUS_TIMEOUT,
    GenerateResult,
    NumericalHealthError,
)
from repro.serve.api import Request, RequestOutput, SamplingParams
from repro.serve.scheduler import PagedScheduler

_ON_NONFINITE = ("quarantine", "raise", "off")

# ServeConfig fields that moved to SamplingParams (PR 8); kept as
# engine-wide DEFAULTS for requests that do not carry their own.
_SAMPLING_DEFAULTS = dict(max_new_tokens=32, eos_id=None, greedy=True,
                          temperature=1.0)


def _decode_jit(model: Model):
    """The fixed-path decode-step program: KV cache donated (argnums 1).

    Single construction site, used by both ``ServeEngine.__init__`` and
    the contract auditor (``ServeEngine.decode_step_lowered``) — the
    served program and the audited program cannot drift apart."""
    return jax.jit(model.decode_step, donate_argnums=(1,))


def _paged_decode_jit(model: Model):
    """The paged decode-step program: page pools donated (argnums 1).
    Shared by the scheduler and ``ServeEngine.paged_decode_lowered``."""
    return jax.jit(model.decode_step_paged, donate_argnums=(1,))


def _prefill_chunk_jit(model: Model):
    """The chunked-prefill program: page pools donated (argnums 1).
    Shared by the scheduler and ``ServeEngine.prefill_chunk_lowered``."""
    return jax.jit(model.prefill_chunk, donate_argnums=(1,))


def _inject_rows(buf: jnp.ndarray, rows: jnp.ndarray,
                 mask: jnp.ndarray) -> jnp.ndarray:
    """Overwrite the masked lanes of the [L, V] pick buffer with the
    matching rows of ``rows`` ([L, V]) — how the final prefill chunks'
    logits enter the fused pick without a per-lane retrace (the mask is
    data, not a trace constant)."""
    return jnp.where(mask[:, None], rows.astype(buf.dtype), buf)


@dataclasses.dataclass
class ServeConfig:
    # -- sampling DEFAULTS (deprecated here; see SamplingParams) -------------
    # These four moved to per-request ``repro.serve.api.SamplingParams``;
    # setting them on ServeConfig still works (they become the engine-wide
    # defaults via ``sampling_defaults()``) but warns: new code should pass
    # SamplingParams on the Request.
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    greedy: bool = True
    temperature: float = 1.0
    # End-to-end int8 serving: projection weights are quantized ONCE at
    # engine construction (column-wise scales) and decode runs
    # int8 x int8 -> int32 GEMMs with scales re-applied in the fused
    # epilogues — no fp32 dequant/requant bounce between GEMMs (the
    # paper's headline 14x-over-fp32 pipeline, §IV-C1).
    int8: bool = False
    # -- robustness ----------------------------------------------------------
    # per-lane health guards (finite logits; int8 saturation probe).
    # Cost rides inside the jitted token pick — see the guard-overhead
    # bench row; the traced decode HLO is identical either way.
    guards: bool = True
    # what a non-finite logit does: 'quarantine' the lane (structured
    # per-request status, peers unaffected), 'raise' NumericalHealthError
    # (fail-stop), or 'off' (pre-hardening behavior)
    on_nonfinite: str = "quarantine"
    # token id emitted for a lane past its quarantine/shed point
    pad_id: int = 0
    # dtype logits are sampled in (jit-cast before the pick)
    logits_dtype: str = "float32"
    # admission control: lanes beyond this are shed at the door (None =
    # admit the whole batch, the pre-hardening behavior)
    max_lanes: Optional[int] = None
    # wall-clock budget per request; on expiry running requests get a
    # structured 'timeout' status with their partial tokens (None = no
    # budget)
    request_timeout_s: Optional[float] = None
    # int8 only: retain the fp32 weights and finish saturated lanes on
    # them (memory cost: both copies live; off by default)
    fp32_fallback: bool = False
    # int8 only: per-lane fraction of logit values outside the calibrated
    # int8 envelope above which the lane degrades
    saturation_threshold: float = 0.25
    # -- paged scheduler geometry (jit-shape constants) ----------------------
    # decode lanes the default scheduler steps in one dispatch
    n_lanes: int = 4
    # positions per KV page
    page_size: int = 16
    # prompt tokens prefilled per chunk dispatch
    prefill_chunk: int = 32
    # per-request position ceiling (prompt + max_new) for the default
    # scheduler; sets the page-table width
    max_seq_len: int = 256
    # total pages in the pool (None = n_lanes full lanes' worth)
    n_pages: Optional[int] = None

    def __post_init__(self):
        # fail LOUDLY on bad values (mirrors XYZConfig's unknown-schedule
        # ValueError): a serving config typo silently defaulting is the
        # failure mode the validation exists to prevent
        moved = [k for k, d in _SAMPLING_DEFAULTS.items()
                 if getattr(self, k) != d]
        if moved:
            warnings.warn(
                f"ServeConfig sampling fields {moved} are deprecated: pass "
                f"repro.serve.api.SamplingParams on each Request (the "
                f"ServeConfig values remain the engine-wide defaults)",
                DeprecationWarning, stacklevel=3)
        # sampling validation lives with the fields now — SamplingParams
        # raises the exact messages this config always raised
        SamplingParams(greedy=self.greedy, temperature=self.temperature,
                       max_new_tokens=self.max_new_tokens,
                       eos_id=self.eos_id)
        if self.pad_id < 0:
            raise ValueError(f"pad_id must be >= 0, got {self.pad_id}")
        if self.on_nonfinite not in _ON_NONFINITE:
            raise ValueError(
                f"unknown on_nonfinite {self.on_nonfinite!r}; valid "
                f"modes are {_ON_NONFINITE}")
        try:
            dt = jnp.dtype(self.logits_dtype)
        except TypeError as e:
            raise ValueError(
                f"unknown logits_dtype {self.logits_dtype!r}: {e}") from None
        if not jnp.issubdtype(dt, jnp.floating):
            raise ValueError(
                f"logits_dtype must be a float dtype, got "
                f"{self.logits_dtype!r}")
        if self.max_lanes is not None and self.max_lanes < 1:
            raise ValueError(
                f"max_lanes must be >= 1 (or None), got {self.max_lanes}")
        if self.request_timeout_s is not None \
                and not (self.request_timeout_s > 0):
            raise ValueError(
                f"request_timeout_s must be > 0 (or None), got "
                f"{self.request_timeout_s}")
        if not (0.0 < self.saturation_threshold <= 1.0):
            raise ValueError(
                f"saturation_threshold must be in (0, 1], got "
                f"{self.saturation_threshold}")
        if self.fp32_fallback and not self.int8:
            raise ValueError(
                "fp32_fallback without int8 is meaningless: the engine "
                "already serves full precision")
        if self.n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {self.n_lanes}")
        if self.page_size < 1:
            raise ValueError(
                f"page_size must be >= 1, got {self.page_size}")
        if self.prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        if self.max_seq_len < 2:
            raise ValueError(
                f"max_seq_len must be >= 2, got {self.max_seq_len}")
        if self.n_pages is not None and self.n_pages < 1:
            raise ValueError(
                f"n_pages must be >= 1 (or None), got {self.n_pages}")

    def sampling_defaults(self) -> SamplingParams:
        """The engine-wide SamplingParams for requests that carry none —
        built from the deprecated ServeConfig fields, so old configs keep
        their exact behavior."""
        return SamplingParams(greedy=self.greedy,
                              temperature=self.temperature,
                              max_new_tokens=self.max_new_tokens,
                              eos_id=self.eos_id)


class ServeEngine:
    def __init__(self, model: Model, params, scfg: ServeConfig = ServeConfig()):
        self.model = model
        self._fp_params = None
        if scfg.int8:
            # one-shot weight-quantization pass (idempotent): the fp
            # weights are replaced, not duplicated — unless fp32_fallback
            # asks the engine to keep them for saturated-lane degradation
            fp = params
            params = model.quantize_params_for_serving(params)
            if scfg.fp32_fallback:
                self._fp_params = fp
        self.params = params
        self.scfg = scfg
        self._ldtype = jnp.dtype(scfg.logits_dtype)
        self._prefill = jax.jit(
            lambda p, b, ml: model.prefill(p, b, max_len=ml),
            static_argnums=(2,))
        self._decode = _decode_jit(model)
        # fp32 fallback decode: non-donating (it reads the cache the int8
        # step subsequently consumes) and traced on the fp param tree
        self._decode_fp = (jax.jit(model.decode_step)
                           if self._fp_params is not None else None)
        self._pick_guarded = jax.jit(self._pick_and_probe)
        # -- paged serving programs (one decode shape per lane count) ----
        self._paged_ok = model.supports_paged_serving
        if self._paged_ok:
            self._decode_paged = _paged_decode_jit(model)
            self._prefill_chunk = _prefill_chunk_jit(model)
            self._decode_paged_fp = (jax.jit(model.decode_step_paged)
                                     if self._fp_params is not None
                                     else None)
            self._pick_paged = jax.jit(self._pick_and_probe_lanes)
            self._inject_rows = jax.jit(_inject_rows)
        else:
            self._decode_paged = self._prefill_chunk = None
            self._decode_paged_fp = None
            self._pick_paged = self._inject_rows = None
        self._sched: Optional[PagedScheduler] = None
        self._finished: List[RequestOutput] = []
        self._shim_cache: Dict[tuple, PagedScheduler] = {}
        self._key_cache: Dict[int, np.ndarray] = {}

    # -- abstract lowerings for the HLO contract auditor ----------------------

    @classmethod
    def decode_step_lowered(cls, model: Model, scfg: ServeConfig,
                            batch: int, prompt_len: int):
        """Lower the fixed-path decode step ABSTRACTLY (no real weights)
        for the HLO contract auditor.

        Returns ``(lowered, donated_param_numbers)``: the same jit the
        engine serves (``_decode_jit`` — KV cache donated), lowered on
        ShapeDtypeStructs, plus the flat parameter numbers of the donated
        cache leaves (params flatten first, then cache — the numbers the
        compiled module's ``input_output_alias`` must cover for the
        donation to have actually been granted)."""
        aparams = model.abstract_params()
        if scfg.int8:
            aparams = jax.eval_shape(model.quantize_params_for_serving,
                                     aparams)
        max_len = prompt_len + scfg.max_new_tokens
        acache = model.abstract_cache(batch, max_len)
        tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = _decode_jit(model).lower(aparams, acache, tok, pos)
        n_p = len(jax.tree_util.tree_leaves(aparams))
        n_c = len(jax.tree_util.tree_leaves(acache))
        return lowered, tuple(range(n_p, n_p + n_c))

    @classmethod
    def paged_decode_lowered(cls, model: Model, scfg: ServeConfig,
                             n_lanes: int, pages_per_lane: int,
                             page_size: int):
        """Lower the scheduler's paged decode step abstractly — the SAME
        ``_paged_decode_jit`` the scheduler dispatches, with the page
        pools as the donated tree (params flatten first, then pools)."""
        aparams = model.abstract_params()
        if scfg.int8:
            aparams = jax.eval_shape(model.quantize_params_for_serving,
                                     aparams)
        acache = model.abstract_paged_cache(n_lanes * pages_per_lane,
                                            page_size)
        tok = jax.ShapeDtypeStruct((n_lanes, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((n_lanes,), jnp.int32)
        pt = jax.ShapeDtypeStruct((n_lanes, pages_per_lane), jnp.int32)
        lowered = _paged_decode_jit(model).lower(aparams, acache, tok,
                                                 pos, pt)
        n_p = len(jax.tree_util.tree_leaves(aparams))
        n_c = len(jax.tree_util.tree_leaves(acache))
        return lowered, tuple(range(n_p, n_p + n_c))

    @classmethod
    def prefill_chunk_lowered(cls, model: Model, scfg: ServeConfig,
                              n_lanes: int, chunk: int,
                              pages_per_lane: int, page_size: int):
        """Lower the scheduler's chunked-prefill step abstractly (pools
        donated, all lanes batched into one [L, chunk] dispatch — the
        same shapes the scheduler serves)."""
        aparams = model.abstract_params()
        if scfg.int8:
            aparams = jax.eval_shape(model.quantize_params_for_serving,
                                     aparams)
        acache = model.abstract_paged_cache(n_lanes * pages_per_lane,
                                            page_size)
        tok = jax.ShapeDtypeStruct((n_lanes, chunk), jnp.int32)
        pos = jax.ShapeDtypeStruct((n_lanes, chunk), jnp.int32)
        pt = jax.ShapeDtypeStruct((n_lanes, pages_per_lane), jnp.int32)
        last = jax.ShapeDtypeStruct((n_lanes,), jnp.int32)
        lowered = _prefill_chunk_jit(model).lower(aparams, acache, tok,
                                                  pos, pt, last)
        n_p = len(jax.tree_util.tree_leaves(aparams))
        n_c = len(jax.tree_util.tree_leaves(acache))
        return lowered, tuple(range(n_p, n_p + n_c))

    @classmethod
    def from_checkpoint(cls, model: Model, ckpt_dir: str,
                        step: Optional[int] = None,
                        scfg: ServeConfig = ServeConfig(),
                        fallback: bool = True) -> "ServeEngine":
        """Restore params onto the model's mesh and serve them.  Legacy
        checkpoints with unpacked wq/wk/wv leaves are packed into the
        ``wqkv`` schema in place (CheckpointManager migration).  With
        ``fallback`` (the serving default) a checkpoint that fails
        integrity verification is reported and the newest earlier intact
        step is served instead — stale weights beat no weights.  With
        ``scfg.int8`` the restored weights immediately go through the
        one-shot serving quantization pass (see ``ServeEngine.__init__``);
        the fp checkpoint on disk is untouched."""
        from repro.checkpoint import CheckpointManager
        from repro.launch.specs import param_io_specs
        mgr = CheckpointManager(ckpt_dir)
        abstract, specs = param_io_specs(model)
        _, params = mgr.restore(step, abstract, mesh=model.mesh,
                                specs=specs, defs=model.param_defs(),
                                fallback=fallback)
        return cls(model, params, scfg)

    # -- token pick + fused health probe --------------------------------------

    def _pick_math(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        v = self.model.cfg.vocab
        logits = logits[:, :v].astype(self._ldtype)
        if self.scfg.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        scaled = logits / max(self.scfg.temperature, 1e-6)
        return jax.random.categorical(key, scaled).astype(jnp.int32)

    def _pick(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        return self._pick_math(logits, key)

    def _pick_and_probe(self, logits, key, calib):
        """Token pick + per-lane health probes in ONE jitted dispatch (the
        guarded path costs one fused call, same as the unguarded pick):

          finite  [B] — all-finite over the lane's real-vocab logits;
          absmax  [B] — per-lane absmax (step-0 calibration source);
          sat     [B] — fraction of the lane's logits that saturate a
                        fixed int8 scale calibrated to ``calib`` (the
                        quantize-epilogue saturation counter applied to
                        the decode canary tensor).
        """
        from repro.kernels.quantize import (quantize_fixed_scale,
                                            saturation_fraction)
        v = self.model.cfg.vocab
        real = logits[:, :v]
        tok = self._pick_math(logits, key)
        finite = jnp.all(jnp.isfinite(real), axis=-1)
        absmax = jnp.max(jnp.abs(real), axis=-1)
        scale = jnp.maximum(calib, 1e-6)[:, None] / 127.0
        sat = saturation_fraction(quantize_fixed_scale(real, scale))
        return tok, finite, absmax, sat

    def _pick_and_probe_lanes(self, logits, key_base, steps, greedy,
                              temp, calib):
        """Per-REQUEST pick + probes, one fused dispatch for all lanes.

        Unlike ``_pick_and_probe`` (one engine-global key and sampling
        mode), every lane carries its own request's sampling: ``greedy``
        [L] bool mask, ``temp`` [L] temperatures, and a private key
        stream ``fold_in(key_base[l], steps[l])`` rooted at the request's
        seed — so a sampled request's tokens are identical no matter
        which lane it lands on or how its neighbors churn."""
        from repro.kernels.quantize import (quantize_fixed_scale,
                                            saturation_fraction)
        v = self.model.cfg.vocab
        real = logits[:, :v]
        lf = real.astype(self._ldtype)
        tok_g = jnp.argmax(lf, axis=-1).astype(jnp.int32)
        keys = jax.vmap(jax.random.fold_in)(key_base, steps)
        scaled = lf / jnp.maximum(temp, 1e-6)[:, None]
        tok_s = jax.vmap(jax.random.categorical)(keys, scaled)
        tok = jnp.where(greedy, tok_g, tok_s.astype(jnp.int32))
        finite = jnp.all(jnp.isfinite(real), axis=-1)
        absmax = jnp.max(jnp.abs(real), axis=-1)
        scale = jnp.maximum(calib, 1e-6)[:, None] / 127.0
        sat = saturation_fraction(quantize_fixed_scale(real, scale))
        return tok, finite, absmax, sat

    def _request_key(self, seed: int) -> np.ndarray:
        """Host-cached uint32[2] PRNGKey(seed) — roots a request's
        private fold_in key stream (one tiny device dispatch per distinct
        seed, not per admission)."""
        k = self._key_cache.get(seed)
        if k is None:
            if len(self._key_cache) > 4096:
                self._key_cache.clear()
            k = np.asarray(jax.random.PRNGKey(seed))
            self._key_cache[seed] = k
        return k

    # -- request-level API -----------------------------------------------------

    @property
    def scheduler(self) -> PagedScheduler:
        """The engine's default continuous-batching scheduler (built
        lazily from the ServeConfig paged-geometry fields)."""
        if self._sched is None:
            self._require_paged()
            scfg = self.scfg
            ppl = -(-scfg.max_seq_len // scfg.page_size)
            n_pages = (scfg.n_pages if scfg.n_pages is not None
                       else scfg.n_lanes * ppl)
            self._sched = PagedScheduler(
                self, n_lanes=scfg.n_lanes, pages_per_lane=ppl,
                n_pages=n_pages, page_size=scfg.page_size,
                chunk=scfg.prefill_chunk)
        return self._sched

    def submit(self, request: Request) -> None:
        """Queue one request (admitted into a lane as capacity frees)."""
        self.scheduler.submit(request)

    def step(self, fault_plan=None) -> List[RequestOutput]:
        """Advance the scheduler one iteration: admissions, at most one
        prefill chunk per prefilling lane, one decode dispatch, one fused
        pick.  Returns the requests that finished THIS step (they are
        also buffered for ``collect()``)."""
        outs = self.scheduler.step(fault_plan)
        self._finished.extend(outs)
        return outs

    def collect(self) -> List[RequestOutput]:
        """Drain every finished-but-uncollected RequestOutput."""
        out, self._finished = self._finished, []
        return out

    @property
    def pending(self) -> bool:
        """True while the default scheduler holds queued or active work."""
        return self._sched is not None and self._sched.has_work

    def drain(self, fault_plan=None) -> List[RequestOutput]:
        """Step until idle; returns all outputs finished along the way
        (including previously buffered ones)."""
        self._finished.extend(self.scheduler.run_to_completion(fault_plan))
        return self.collect()

    def _require_paged(self) -> None:
        if not self._paged_ok:
            raise NotImplementedError(
                "paged serving needs a single-device decoder-only model "
                "with global/local/chunked attention; use "
                "generate_with_status_fixed() for this model")

    def _shim_scheduler(self, n_lanes: int, prompt_len: int,
                        max_new: int) -> PagedScheduler:
        """Fixed-geometry scheduler for the ``generate(batch)`` shim: one
        lane per batch row, pool sized so every row admits immediately
        (the legacy loop's capacity), cached per (lanes, prompt, budget)
        so repeated same-shape calls reuse the compiled programs."""
        key = (n_lanes, prompt_len, max_new)
        sched = self._shim_cache.get(key)
        if sched is None:
            ps = self.scfg.page_size
            ppl = -(-(prompt_len + max_new) // ps)
            sched = PagedScheduler(self, n_lanes=n_lanes,
                                   pages_per_lane=ppl,
                                   n_pages=n_lanes * ppl, page_size=ps,
                                   chunk=self.scfg.prefill_chunk)
            while len(self._shim_cache) >= 4:
                self._shim_cache.pop(next(iter(self._shim_cache)))
            self._shim_cache[key] = sched
        return sched

    # -- batch-shaped generation (shims over the scheduler) -------------------

    def generate(self, batch: Dict[str, jnp.ndarray], seed: int = 0
                 ) -> np.ndarray:
        """batch['tokens'] [B, S] -> generated tokens [B, <=max_new]."""
        return self.generate_with_status(batch, seed).tokens

    def generate_with_status(self, batch: Dict[str, jnp.ndarray],
                             seed: int = 0,
                             fault_plan=None) -> GenerateResult:
        """Guarded generation with structured per-lane outcomes.

        On paged-capable models this is a thin shim over the scheduler:
        each batch row becomes a Request (engine-default sampling, shared
        seed) on a cached fixed-geometry scheduler, and the RequestOutputs
        are reassembled into the legacy GenerateResult — greedy outputs
        are bitwise-identical to the fixed loop's.  Other model families
        fall through to ``generate_with_status_fixed``.

        ``fault_plan`` (a ``repro.robust.FaultPlan``) injects
        deterministic faults for testing; ``None`` (production) leaves
        the loop on the exact pre-hardening compute path.
        """
        if not self._paged_ok:
            return self.generate_with_status_fixed(batch, seed, fault_plan)
        scfg = self.scfg
        plan = fault_plan if (fault_plan is not None
                              and fault_plan.enabled) else None
        if plan is not None:
            plan.on_generate_start()

        toks = np.asarray(batch["tokens"])
        b_full = toks.shape[0]
        if toks.ndim != 2 or toks.shape[1] == 0:
            # zero-length prompts can never be served (the first pick
            # needs at least one prefilled position): structured shed for
            # the whole batch, same contract as the scheduler's
            # fits_ever rejection — never a Request-validation crash
            return GenerateResult(
                tokens=np.zeros((b_full, 0), np.int32),
                status=[STATUS_SHED] * b_full,
                fault_step=np.full((b_full,), -1, np.int64),
                n_steps=0, timed_out=False, admitted=0)
        admit = b_full if scfg.max_lanes is None \
            else min(b_full, scfg.max_lanes)
        sp = scfg.sampling_defaults()
        sched = self._shim_scheduler(admit, toks.shape[1],
                                     sp.max_new_tokens)
        sched.reset_fault_state()
        for r in range(admit):
            sched.submit(Request(id=r, tokens=toks[r], sampling=sp,
                                 seed=seed))
        try:
            outs = sched.run_to_completion(plan)
        except Exception:
            # a raise mid-drain (on_nonfinite='raise') leaves lanes
            # mapped; drop the scheduler rather than reuse a dirty one
            self._shim_cache = {k: v for k, v in self._shim_cache.items()
                                if v is not sched}
            raise

        n_steps = max((len(o.tokens) for o in outs), default=0)
        tokens = np.full((b_full, n_steps), scfg.pad_id, np.int32)
        status = np.array([STATUS_SHED] * b_full, dtype=object)
        fault_step = np.full((b_full,), -1, np.int64)
        for o in outs:
            tokens[o.id, :len(o.tokens)] = o.tokens
            status[o.id] = o.status
            fault_step[o.id] = o.fault_step
        return GenerateResult(tokens=tokens, status=list(status),
                              fault_step=fault_step, n_steps=n_steps,
                              timed_out=sched.timed_out, admitted=admit)

    def generate_with_status_fixed(self, batch: Dict[str, jnp.ndarray],
                                   seed: int = 0,
                                   fault_plan=None) -> GenerateResult:
        """The PR 5-7 lockstep fixed-batch loop: every lane prefills and
        decodes in step, one engine-global sampling config.  Kept as the
        serving path for model families the paged attention kernel does
        not cover, and as the reference the scheduler shim is proven
        bitwise-equal against."""
        scfg = self.scfg
        plan = fault_plan if (fault_plan is not None
                              and fault_plan.enabled) else None
        if plan is not None:
            plan.on_generate_start()

        # admission control: shed surplus lanes before any compute
        b_full = batch["tokens"].shape[0]
        toks0 = np.asarray(batch["tokens"])
        if toks0.ndim != 2 or toks0.shape[1] == 0:
            # same zero-length structured shed as the scheduler shim
            return GenerateResult(
                tokens=np.zeros((b_full, 0), np.int32),
                status=[STATUS_SHED] * b_full,
                fault_step=np.full((b_full,), -1, np.int64),
                n_steps=0, timed_out=False, admitted=0)
        admit = b_full if scfg.max_lanes is None \
            else min(b_full, scfg.max_lanes)
        if admit < b_full:
            batch = {k: v[:admit] for k, v in batch.items()}

        cfg = self.model.cfg
        b, s = batch["tokens"].shape
        prompt_len = s + (cfg.prefix_tokens or 0)
        max_len = prompt_len + scfg.max_new_tokens
        logits, cache = self._prefill(self.params, batch, max_len)
        # the clock starts once prefill is dispatched: the budget bounds
        # the decode loop (where a hung host step strands a request), not
        # the one-time jit compile of a cold engine
        deadline = (time.monotonic() + scfg.request_timeout_s
                    if scfg.request_timeout_s is not None else None)

        status = np.array([STATUS_OK] * admit, dtype=object)
        fault_step = np.full((admit,), -1, np.int64)
        done = np.zeros((admit,), bool)
        degraded = np.zeros((admit,), bool)
        timed_out = False
        calib = None          # step-0 per-lane absmax (int8 probe)
        fp_logits = None      # fp32-fallback logits for degraded lanes
        out: List[np.ndarray] = []

        key = jax.random.PRNGKey(seed)
        pick_key = key  # token 0 samples with the unsplit key (legacy)
        guards_on = scfg.guards and scfg.on_nonfinite != "off"
        sat_on = scfg.guards and scfg.int8

        for i in range(scfg.max_new_tokens):
            if plan is not None:
                plan.maybe_stall(i)
            if deadline is not None and time.monotonic() > deadline:
                running = ~done
                status[running] = STATUS_TIMEOUT
                fault_step[running & (fault_step < 0)] = i
                timed_out = True
                break
            if plan is not None:
                logits = plan.perturb_logits(i, logits)

            if guards_on or sat_on:
                cal = (jnp.ones((admit,), jnp.float32) if calib is None
                       else calib)
                tok, fin_j, absmax_j, sat_j = self._pick_guarded(
                    logits, pick_key, cal)
                if guards_on:
                    newly_bad = ~np.asarray(fin_j) & ~done
                    if newly_bad.any():
                        lanes = np.flatnonzero(newly_bad)
                        if scfg.on_nonfinite == "raise":
                            raise NumericalHealthError(
                                f"non-finite logits at decode step {i} in "
                                f"lanes {lanes.tolist()}")
                        status[newly_bad] = STATUS_NONFINITE
                        fault_step[newly_bad & (fault_step < 0)] = i
                if sat_on:
                    if calib is None:
                        calib = jnp.maximum(absmax_j, 1e-6)
                    else:
                        sat = np.asarray(sat_j)
                        newly_sat = ((sat > scfg.saturation_threshold)
                                     & ~degraded & ~done
                                     & np.asarray(fin_j))
                        if newly_sat.any():
                            degraded |= newly_sat
                            mark = newly_sat & (status == STATUS_OK)
                            status[mark] = STATUS_DEGRADED
                            fault_step[mark & (fault_step < 0)] = i
            else:
                tok = self._pick(logits, pick_key)

            if fp_logits is not None:
                # degraded lanes pick from the fp32 fallback logits; the
                # same key keeps healthy lanes bitwise unchanged
                tok_fp = self._pick(fp_logits, pick_key)
                tok = jnp.where(jnp.asarray(degraded), tok_fp, tok)

            tok_np = np.asarray(tok)
            quarantined = status == STATUS_NONFINITE
            if quarantined.any():
                tok_np = np.where(quarantined, scfg.pad_id,
                                  tok_np).astype(tok_np.dtype)
            out.append(tok_np)
            if scfg.eos_id is not None:
                done = done | (tok_np == scfg.eos_id)
            done = done | quarantined
            if done.all() or i == scfg.max_new_tokens - 1:
                break

            pos = jnp.asarray(prompt_len + i, jnp.int32)
            tok_dev = jnp.asarray(tok_np)[:, None]
            if degraded.any() and self._decode_fp is not None:
                # dispatched BEFORE the donating int8 step: it reads the
                # cache buffers that step consumes
                fp_logits, _ = self._decode_fp(self._fp_params, cache,
                                               tok_dev, pos)
            else:
                fp_logits = None
            logits, cache = self._decode(self.params, cache, tok_dev, pos)
            key, pick_key = jax.random.split(key)

        tokens = (np.stack(out, axis=1) if out
                  else np.zeros((admit, 0), np.int32))
        if admit < b_full:
            shed = b_full - admit
            full = np.full((b_full, tokens.shape[1]), scfg.pad_id,
                           tokens.dtype)
            full[:admit] = tokens
            tokens = full
            status = np.concatenate(
                [status, np.array([STATUS_SHED] * shed, dtype=object)])
            # shed lanes never ran: fault_step is the documented -1
            # sentinel, not 0 (which would claim a step-0 fault)
            fault_step = np.concatenate(
                [fault_step, np.full((shed,), -1, np.int64)])
        return GenerateResult(tokens=tokens, status=list(status),
                              fault_step=fault_step, n_steps=len(out),
                              timed_out=timed_out, admitted=admit)

    # -- introspection ---------------------------------------------------------

    def jit_cache_sizes(self) -> Dict[str, int]:
        """Compiled-program counts per serving jit — what the
        zero-recompilation-under-churn test pins down."""
        sizes = {"decode": self._decode._cache_size()}
        if self._paged_ok:
            sizes["decode_paged"] = self._decode_paged._cache_size()
            sizes["prefill_chunk"] = self._prefill_chunk._cache_size()
            sizes["pick_paged"] = self._pick_paged._cache_size()
        return sizes
