"""Blocked MatMul Pallas kernel with fused epilogues — the TPU adaptation
of the paper's single-AIE MatMul kernel (§IV-C1).

The AIE kernel computes an ``M x K x N`` tile chosen so that (a) the vector
unit runs near peak, (b) streaming the tile in/out does not outrun the
stream bandwidth, and (c) the double-buffered working set fits the 32 KB
local memory.  Here the same three constraints pick the VMEM block
``(bm, bk, bn)`` (see ``core.planner.plan_tpu_block``): MXU-aligned shapes,
HBM-bandwidth-balanced ``bm``/``bn``, and a double-buffered working set
within the VMEM budget.  Pallas' pipeline emitter provides the double
buffering that Fig. 5 of the paper builds by hand.

Accumulation is always 32-bit (fp32 / int32), matching the paper's int8
pipeline with int32 accumulators.

Fused epilogues (the ``Epilogue`` spec, ``kernels.epilogue``)
-------------------------------------------------------------
MaxEVA's efficiency comes from never letting partial results touch slow
memory: partial products ping-pong through local memory (§IV-C, Fig. 5)
and are reduced on-array by the adder tree (§IV-B) before a single PLIO
write-out.  The TPU analogue of that discipline is applying the GEMM
epilogue — bias add, gelu/silu/relu, residual add, output cast, rowwise
int8 quantize — on the VMEM accumulator tile in the kernel's store phase,
instead of writing the fp32 accumulator to HBM and reading it back in a
separate XLA op.  Declaratively:

    ep = Epilogue(bias=True, activation="gelu", out_dtype=jnp.bfloat16)
    y = matmul_pallas(a, b, block=blk, epilogue=ep, bias=bias_row)

    epq = Epilogue(activation="silu", quantize=True)
    q, scale = matmul_pallas(a, b, block=blk, epilogue=epq)

Semantics are defined once in ``kernels.epilogue.apply_epilogue``; the XLA
reference path (``kernels.ref.matmul_fused_ref``) calls the same function
on the full accumulator, so both paths are numerically identical.

Constraint: ``quantize`` computes a full-row absmax, so the N dimension
must not be blocked — the kernel pads N to one block (``bn = N_padded``)
and grids over M and K only, exactly like ``kernels.quantize``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.epilogue import Epilogue, apply_epilogue
from repro.kernels.ref import accum_dtype


def _matmul_kernel(*refs, k_steps: int, out_dtype, epilogue: Epilogue,
                   has_a_scale: bool, has_b_scale: bool,
                   has_bias: bool, has_residual: bool,
                   has_operand2: bool, has_norm_scale: bool,
                   norm_n: Optional[int]):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (sequential) axis; the
    fp32/int32 accumulator tile lives in VMEM scratch across K steps.  The
    epilogue runs on the accumulator tile at the final K step (the store
    phase), so the only HBM write is the finished output.  With int8
    inputs the row/col quantization scales are re-applied right there (the
    paper's int32 -> output boundary), never via a separate dequant op."""
    refs = list(refs)
    a_ref, b_ref = refs[:2]
    pos = 2
    as_ref = refs[pos] if has_a_scale else None
    pos += int(has_a_scale)
    bs_ref = refs[pos] if has_b_scale else None
    pos += int(has_b_scale)
    bias_ref = refs[pos] if has_bias else None
    pos += int(has_bias)
    res_ref = refs[pos] if has_residual else None
    pos += int(has_residual)
    op2_ref = refs[pos] if has_operand2 else None
    pos += int(has_operand2)
    ns_ref = refs[pos] if has_norm_scale else None
    pos += int(has_norm_scale)
    out_refs = refs[pos:-1]
    acc_ref = refs[-1]

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=acc_ref.dtype
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        acc = acc_ref[...]
        if epilogue.is_identity and not (has_a_scale or has_b_scale):
            out_refs[0][...] = acc.astype(out_dtype)
            return
        out = apply_epilogue(
            acc, epilogue,
            bias=bias_ref[...] if has_bias else None,
            residual=res_ref[...] if has_residual else None,
            row_scale=as_ref[...] if has_a_scale else None,
            col_scale=bs_ref[...] if has_b_scale else None,
            operand2=op2_ref[...] if has_operand2 else None,
            norm_scale=ns_ref[...] if has_norm_scale else None,
            norm_n=norm_n,
        )
        if epilogue.quantize:
            q, s = out
            out_refs[0][...] = q
            out_refs[1][...] = s
        elif epilogue.norm != "none":
            value, normed = out
            out_refs[0][...] = value.astype(out_dtype)
            out_refs[1][...] = normed.astype(out_dtype)
        else:
            out_refs[0][...] = out.astype(out_dtype)


def _pad_to(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    pm = (-x.shape[0]) % m0
    pn = (-x.shape[1]) % m1
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


@functools.partial(
    jax.jit,
    static_argnames=("block", "out_dtype", "interpret", "cost_hint",
                     "epilogue"),
)
def matmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block: Tuple[int, int, int],
    out_dtype=None,
    interpret: bool = False,
    cost_hint: bool = True,
    epilogue: Optional[Epilogue] = None,
    a_scale: Optional[jnp.ndarray] = None,
    b_scale: Optional[jnp.ndarray] = None,
    bias: Optional[jnp.ndarray] = None,
    residual: Optional[jnp.ndarray] = None,
    operand2: Optional[jnp.ndarray] = None,
    norm_scale: Optional[jnp.ndarray] = None,
):
    """C[M, N] = epilogue(A[M, K] @ B[K, N]) via the blocked Pallas kernel.

    Inputs are zero-padded to block multiples (the paper's Fig. 8 padding
    model) and the result is sliced back.  With ``epilogue.quantize`` the
    return value is ``(q int8 [M, N], scale f32 [M, 1])`` (``[1, N]``
    under ``quantize_axis='col'``); with ``epilogue.norm`` it is
    ``(value, normed)``, both ``[M, N]``; otherwise a single ``[M, N]``
    array in the epilogue/out dtype.

    ``a_scale [M, 1]`` / ``b_scale [1, N]`` are the int8 pipeline's
    quantization scales, re-applied on the int32 accumulator tile in the
    store phase (before bias/activation) — int8 in, one HBM write out.
    ``operand2 [M, N]`` is the gate epilogue's second tensor operand
    (tiled like the residual); ``norm_scale [N]`` the rmsnorm scale row
    (tiled like the bias).
    """
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[0]
    ep = epilogue or Epilogue()
    m, k = a.shape
    _, n = b.shape
    bm, bk, bn = block
    acc = accum_dtype(a.dtype)
    scaled = a_scale is not None or b_scale is not None
    out_dtype = ep.out_dtype or out_dtype or (jnp.float32 if scaled
                                              else acc)

    if ep.quantize and ep.quantize_axis == "col":
        # colwise scale needs the whole column in one tile: M is one block
        # (sublane-aligned); zero-pad rows cannot raise a column's absmax.
        bm = _ceil_mult(m, 8)
    ap = _pad_to(a, bm, bk)
    if (ep.quantize and ep.quantize_axis == "row") or ep.norm != "none":
        # rowwise scale / rmsnorm needs the whole row in one tile: N is
        # one block (lane-aligned), exactly like kernels.quantize —
        # zero-pad columns cannot raise a row's absmax, and they
        # contribute exact +0.0 to the rmsnorm sum of squares (the mean
        # divides by the TRUE n via norm_n below).
        bn = _ceil_mult(n, 128)
    bp = _pad_to(b, bk, bn)
    mp, kp = ap.shape
    np_ = bp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
        pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
    ]
    operands = [ap, bp]
    if a_scale is not None:
        assert a_scale.shape == (m, 1), (a_scale.shape, m)
        in_specs.append(pl.BlockSpec((bm, 1), lambda i, j, s: (i, 0)))
        operands.append(_pad_to(a_scale, bm, 1))
    if b_scale is not None:
        assert b_scale.shape == (1, n), (b_scale.shape, n)
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, s: (0, j)))
        operands.append(_pad_to(b_scale, 1, bn))
    if ep.bias:
        assert bias is not None and bias.shape[-1] == n, (
            "epilogue.bias requires a [N] bias operand")
        b2 = bias.reshape(1, n)
        b2 = jnp.pad(b2, ((0, 0), (0, np_ - n))) if np_ != n else b2
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, s: (0, j)))
        operands.append(b2)
    if ep.residual:
        assert residual is not None and residual.shape == (m, n), (
            "epilogue.residual requires a [M, N] residual operand")
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)))
        operands.append(_pad_to(residual, bm, bn))
    if ep.gate != "none":
        assert operand2 is not None and operand2.shape == (m, n), (
            "epilogue.gate requires a [M, N] operand2")
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)))
        operands.append(_pad_to(operand2, bm, bn))
    if ep.norm != "none":
        assert norm_scale is not None and norm_scale.shape[-1] == n, (
            "epilogue.norm requires a [N] norm_scale operand")
        ns2 = norm_scale.reshape(1, n)
        ns2 = jnp.pad(ns2, ((0, 0), (0, np_ - n))) if np_ != n else ns2
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, s: (0, j)))
        operands.append(ns2)

    if ep.quantize:
        out_specs = [pl.BlockSpec((bm, bn), lambda i, j, s: (i, j))]
        out_shape = [jax.ShapeDtypeStruct((mp, np_), jnp.int8)]
        if ep.quantize_axis == "row":
            out_specs.append(pl.BlockSpec((bm, 1), lambda i, j, s: (i, 0)))
            out_shape.append(jax.ShapeDtypeStruct((mp, 1), jnp.float32))
        else:
            out_specs.append(pl.BlockSpec((1, bn), lambda i, j, s: (0, j)))
            out_shape.append(jax.ShapeDtypeStruct((1, np_), jnp.float32))
    elif ep.norm != "none":
        out_specs = [pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
                     pl.BlockSpec((bm, bn), lambda i, j, s: (i, j))]
        out_shape = [jax.ShapeDtypeStruct((mp, np_), out_dtype),
                     jax.ShapeDtypeStruct((mp, np_), out_dtype)]
    else:
        out_specs = pl.BlockSpec((bm, bn), lambda i, j, s: (i, j))
        out_shape = jax.ShapeDtypeStruct((mp, np_), out_dtype)

    kernel = functools.partial(
        _matmul_kernel, k_steps=grid[2], out_dtype=out_dtype, epilogue=ep,
        has_a_scale=a_scale is not None, has_b_scale=b_scale is not None,
        has_bias=ep.bias, has_residual=ep.residual,
        has_operand2=ep.gate != "none",
        has_norm_scale=ep.norm != "none",
        norm_n=n if ep.norm != "none" else None,
    )
    params = {}
    cp_cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None
    )
    if cp_cls is not None:
        params["compiler_params"] = cp_cls(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    cost = None
    if cost_hint:
        # the fused path stores the finished epilogue output ONCE; the
        # unfused sequence would add an fp32 accumulator write + read.
        out_bytes = mp * np_ * ep.out_itemsize(acc)
        if ep.quantize:
            # scale vector: a column (rowwise) or a row (colwise)
            out_bytes += (mp if ep.quantize_axis == "row" else np_) * 4
        if ep.norm != "none":
            # second [M, N] output: the normed residual-stream view
            out_bytes += mp * np_ * ep.out_itemsize(acc)
        extra_in = (np_ * 4 if ep.bias else 0) + (
            mp * np_ * jnp.dtype(residual.dtype).itemsize
            if ep.residual else 0)
        extra_in += (mp * np_ * jnp.dtype(operand2.dtype).itemsize
                     if ep.gate != "none" else 0)
        extra_in += np_ * 4 if ep.norm != "none" else 0
        extra_in += (mp * 4 if a_scale is not None else 0) + (
            np_ * 4 if b_scale is not None else 0)
        transc = mp * np_ if ep.activation in ("gelu", "silu") else 0
        transc += mp * np_ if ep.gate in ("gelu", "silu") else 0
        transc += mp if ep.norm != "none" else 0
        cost = pl.CostEstimate(
            flops=2 * mp * kp * np_,
            bytes_accessed=(mp * kp * ap.dtype.itemsize
                            + kp * np_ * bp.dtype.itemsize
                            + out_bytes + extra_in),
            transcendentals=transc,
        )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), acc)],
        interpret=interpret,
        cost_estimate=cost,
        **params,
    )(*operands)
    if ep.quantize:
        q, s = out
        return (q[:m, :n], s[:m]) if ep.quantize_axis == "row" \
            else (q[:m, :n], s[:, :n])
    if ep.norm != "none":
        value, normed = out
        return value[:m, :n], normed[:m, :n]
    return out[:m, :n]


def _ceil_mult(v: int, a: int) -> int:
    return a * ((v + a - 1) // a)
