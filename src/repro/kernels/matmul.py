"""Blocked MatMul Pallas kernel — the TPU adaptation of the paper's
single-AIE MatMul kernel (§IV-C1).

The AIE kernel computes an ``M x K x N`` tile chosen so that (a) the vector
unit runs near peak, (b) streaming the tile in/out does not outrun the
stream bandwidth, and (c) the double-buffered working set fits the 32 KB
local memory.  Here the same three constraints pick the VMEM block
``(bm, bk, bn)`` (see ``core.planner.plan_tpu_block``): MXU-aligned shapes,
HBM-bandwidth-balanced ``bm``/``bn``, and a double-buffered working set
within the VMEM budget.  Pallas' pipeline emitter provides the double
buffering that Fig. 5 of the paper builds by hand.

Accumulation is always 32-bit (fp32 / int32), matching the paper's int8
pipeline with int32 accumulators.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import accum_dtype


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int, out_dtype):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (sequential) axis; the
    fp32/int32 accumulator tile lives in VMEM scratch across K steps."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=acc_ref.dtype
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def _pad_to(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    pm = (-x.shape[0]) % m0
    pn = (-x.shape[1]) % m1
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


@functools.partial(
    jax.jit,
    static_argnames=("block", "out_dtype", "interpret", "cost_hint"),
)
def matmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block: Tuple[int, int, int],
    out_dtype=None,
    interpret: bool = False,
    cost_hint: bool = True,
) -> jnp.ndarray:
    """C[M, N] = A[M, K] @ B[K, N] via the blocked Pallas kernel.

    Inputs are zero-padded to block multiples (the paper's Fig. 8 padding
    model) and the result is sliced back.
    """
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[0]
    m, k = a.shape
    _, n = b.shape
    bm, bk, bn = block
    acc = accum_dtype(a.dtype)
    out_dtype = out_dtype or acc

    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    mp, kp = ap.shape
    np_ = bp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    kernel = functools.partial(
        _matmul_kernel, k_steps=grid[2], out_dtype=out_dtype
    )
    params = {}
    cp_cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None
    )
    if cp_cls is not None:
        params["compiler_params"] = cp_cls(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    cost = None
    if cost_hint:
        cost = pl.CostEstimate(
            flops=2 * mp * kp * np_,
            bytes_accessed=(mp * kp * ap.dtype.itemsize
                            + kp * np_ * bp.dtype.itemsize
                            + mp * np_ * jnp.dtype(out_dtype).itemsize),
            transcendentals=0,
        )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc)],
        interpret=interpret,
        cost_estimate=cost,
        **params,
    )(ap, bp)
    return out[:m, :n]
