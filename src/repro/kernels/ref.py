"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's tests sweep shapes and
dtypes and ``assert_allclose`` against these functions.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

_ACCUM = {
    jnp.dtype("int8"): jnp.int32,
    jnp.dtype("bfloat16"): jnp.float32,
    jnp.dtype("float32"): jnp.float32,
}


def accum_dtype(dtype) -> jnp.dtype:
    """32-bit accumulator for a given input dtype; unlisted dtypes fall
    back by kind (ints -> int32, floats -> fp32) — EXCEPT inputs already
    wider than 32 bits, which keep their width (an f64 reference run must
    not silently accumulate at fp32)."""
    dt = jnp.dtype(dtype)
    if dt in _ACCUM:
        return _ACCUM[dt]
    if dt.kind in ("i", "u"):
        return jnp.int32 if dt.itemsize <= 4 else jnp.int64
    if dt.kind == "f" and dt.itemsize >= 8:
        return jnp.float64
    return jnp.float32


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray,
               out_dtype: Optional[jnp.dtype] = None) -> jnp.ndarray:
    """C = A @ B with 32-bit accumulation (paper §IV-C1: int8 inputs
    accumulate in int32; floats accumulate in fp32)."""
    acc = accum_dtype(a.dtype)
    out_dtype = out_dtype or acc
    return jnp.dot(a, b, preferred_element_type=acc).astype(out_dtype)


def matmul_fused_ref(a: jnp.ndarray, b: jnp.ndarray, epilogue,
                     bias: Optional[jnp.ndarray] = None,
                     residual: Optional[jnp.ndarray] = None,
                     operand2: Optional[jnp.ndarray] = None,
                     norm_scale: Optional[jnp.ndarray] = None):
    """epilogue(A @ B): the XLA mirror of the fused-epilogue Pallas kernel.

    Shares ``kernels.epilogue.apply_epilogue`` with the kernel's store
    phase, so both paths are numerically identical by construction — and
    f64 inputs keep the whole chain (dot AND epilogue) at f64, making
    this the oracle for the two-operand stages too.  Returns ``(q,
    scale)`` under ``epilogue.quantize``, ``(value, normed)`` under
    ``epilogue.norm``, else one array."""
    from repro.kernels.epilogue import apply_epilogue
    acc = jnp.dot(a, b, preferred_element_type=accum_dtype(a.dtype))
    return apply_epilogue(acc, epilogue, bias=bias, residual=residual,
                          operand2=operand2, norm_scale=norm_scale)


def addertree_ref(partials: jnp.ndarray,
                  out_dtype: Optional[jnp.dtype] = None) -> jnp.ndarray:
    """Sum of Y stacked (M, N) partial products -- the paper's adder tree
    (Y-1 sequential Add kernels on one core)."""
    acc = accum_dtype(partials.dtype) if partials.dtype in _ACCUM else partials.dtype
    out_dtype = out_dtype or partials.dtype
    return jnp.sum(partials.astype(acc), axis=0).astype(out_dtype)


def quantize_rowwise_ref(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-wise symmetric int8 quantization: q = round(x / s), s = absmax/127.
    Returns (q int8 [M, N], scale f32 [M, 1])."""
    from repro.kernels.epilogue import quantize_symmetric
    return quantize_symmetric(x.astype(jnp.float32), axis=-1)


def quantize_colwise_ref(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Column-wise symmetric int8 quantization (the weight / weight-grad
    layout): q = round(x / s), s = per-column absmax / 127.  Works on any
    rank >= 2 (leading axes, e.g. a scan group axis, pass through).
    Returns (q int8 [..., K, N], scale f32 [..., 1, N])."""
    from repro.kernels.epilogue import quantize_symmetric
    return quantize_symmetric(x.astype(jnp.float32), axis=-2)


def dequantize_rowwise_ref(q: jnp.ndarray, scale: jnp.ndarray,
                           dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def int8_matmul_ref(qa: jnp.ndarray, sa: jnp.ndarray, qb: jnp.ndarray,
                    sb: jnp.ndarray, epilogue=None,
                    bias: Optional[jnp.ndarray] = None,
                    residual: Optional[jnp.ndarray] = None,
                    operand2: Optional[jnp.ndarray] = None,
                    norm_scale: Optional[jnp.ndarray] = None):
    """epilogue(sa * sb * (QA @ QB)): the serving int8 GEMM's XLA mirror.

    ``qa [M, K]`` int8 with rowwise scales ``sa [M, 1]``; ``qb [K, N]``
    int8 with columnwise scales ``sb [1, N]``.  Accumulation is int32 and
    both scales are re-applied at the int32 -> fp32 boundary INSIDE the
    epilogue (paper §IV-C1: scales come back on the way out), so the
    quantized pipeline never materializes a dequantized fp32 operand.
    Shares ``apply_epilogue`` with the Pallas kernel's store phase."""
    from repro.kernels.epilogue import Epilogue, apply_epilogue
    acc = jnp.dot(qa, qb, preferred_element_type=jnp.int32)
    return apply_epilogue(acc, epilogue or Epilogue(), bias=bias,
                          residual=residual, row_scale=sa, col_scale=sb,
                          operand2=operand2, norm_scale=norm_scale)


def quantized_matmul_ref(a: jnp.ndarray, b: jnp.ndarray,
                         out_dtype=jnp.float32) -> jnp.ndarray:
    """int8 x int8 -> int32 matmul with row/col scales applied afterwards:
    the fully-quantized MatMul path (paper's int8 pipeline)."""
    qa, sa = quantize_rowwise_ref(a)
    qb, sb = quantize_colwise_ref(b)
    from repro.kernels.epilogue import Epilogue
    return int8_matmul_ref(qa, sa, qb, sb,
                           Epilogue(out_dtype=out_dtype))


# ---------------------------------------------------------------------------
# flash-attention oracles
# ---------------------------------------------------------------------------
#
# Ground truth for the flash kernels is the PLAIN (untiled) masked softmax
# at ``accum_dtype`` width: with f64 inputs the whole softmax runs at f64,
# which is what anchors the consistency-budget comparisons.  The tiled
# kernels and their tiled XLA mirrors must land within rounding distance
# of these, never bitwise — the bitwise contracts (split-count invariance,
# dense == paged) are between tiled paths sharing one combine.

_NEG_REF = -1e30


def attention_mask_ref(qpos: jnp.ndarray, kpos: jnp.ndarray, *,
                       kind: str = "global", window: int = 0,
                       prefix_len: int = 0) -> jnp.ndarray:
    """[Q, K] bool mask shared by every attention oracle — the same
    semantics as ``models.attention._block_attend``: causal for
    global/local/chunked/prefix, sliding window for 'local', block-local
    for 'chunked', bidirectional prefix override for 'prefix', everything
    valid for 'full'; ``kpos < 0`` always masks (padding sentinel)."""
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if kind in ("global", "local", "chunked", "prefix"):
        mask &= qpos[:, None] >= kpos[None, :]
    if kind == "local":
        mask &= (qpos[:, None] - kpos[None, :]) < window
    if kind == "chunked":
        mask &= (qpos[:, None] // window) == (kpos[None, :] // window)
    if kind == "prefix":
        mask |= kpos[None, :] < prefix_len
    mask &= kpos[None, :] >= 0
    return mask


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        kind: str = "global", window: int = 0,
                        prefix_len: int = 0, softcap=None,
                        q_offset: int = 0) -> jnp.ndarray:
    """Prefill/train attention oracle, head-expanded [B, S, H, hd] (k/v
    may carry KV < H heads; GQA head h reads kv head h // (H // KV)).
    Plain softmax — the S x S scores ARE materialized here; that is the
    point of an oracle."""
    b, sq, n_h, hd = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    acc = accum_dtype(q.dtype)
    if n_kv != n_h:
        g = n_h // n_kv
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bKhd->bhqK", q.astype(acc), k.astype(acc))
    s = s * jnp.asarray(hd, acc) ** -0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(skv)
    mask = attention_mask_ref(qpos, kpos, kind=kind, window=window,
                              prefix_len=prefix_len)
    s = jnp.where(mask[None, None], s, _NEG_REF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(mask[None, None], jnp.exp(s - m), 0.0).astype(acc)
    out = jnp.einsum("bhqK,bKhd->bhqd", p, v.astype(acc))
    out = out / jnp.maximum(jnp.sum(p, axis=-1)[..., None], 1e-30)
    return jnp.einsum("bhqd->bqhd", out).astype(q.dtype)


def flash_decode_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos, *, kind: str = "global",
                     softcap=None) -> jnp.ndarray:
    """Decode oracle: q [B, 1, KV, G, hd] against dense caches
    [B, K, KV, hd]; ``pos`` is the current position ('global' attends
    slots <= pos; 'full' attends every slot — cross-attention).  Plain
    masked softmax at ``accum_dtype``."""
    hd = q.shape[-1]
    acc = accum_dtype(q.dtype)
    s = jnp.einsum("bqkgd,bKkd->bkgqK", q.astype(acc),
                   k_cache.astype(acc))
    s = s * jnp.asarray(hd, acc) ** -0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    slots = jnp.arange(k_cache.shape[1])
    valid = slots >= 0 if kind == "full" else slots <= pos
    s = jnp.where(valid[None, None, None, None, :], s, _NEG_REF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(valid[None, None, None, None, :], jnp.exp(s - m),
                  0.0).astype(acc)
    out = jnp.einsum("bkgqK,bKkd->bkgqd", p, v_cache.astype(acc))
    out = out / jnp.maximum(jnp.sum(p, axis=-1)[..., None], 1e-30)
    return jnp.einsum("bkgqd->bqkgd", out).astype(q.dtype)


def paged_flash_decode_ref(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, page_table: jnp.ndarray,
                           positions: jnp.ndarray, *,
                           kind: str = "global", window: int = 0,
                           softcap=None) -> jnp.ndarray:
    """Paged decode/prefill-chunk oracle: q [B, S, KV, G, hd] against the
    page pools [NP, PS, KV, hd] through ``page_table`` [B, P] (-1 =
    unmapped -> trash page NP-1, masked to contribute exact zeros);
    ``positions`` [B, S] global query positions, -1 = inactive."""
    n_pool, ps = k_pool.shape[0], k_pool.shape[1]
    b, p_max = page_table.shape
    hd = q.shape[-1]
    acc = accum_dtype(q.dtype)
    mapped = page_table >= 0
    ptc = jnp.where(mapped, page_table, n_pool - 1)
    kl = k_pool[ptc].reshape(b, p_max * ps, *k_pool.shape[2:])
    vl = v_pool[ptc].reshape(b, p_max * ps, *v_pool.shape[2:])
    s = jnp.einsum("bqkgd,bKkd->bkgqK", q.astype(acc), kl.astype(acc))
    s = s * jnp.asarray(hd, acc) ** -0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    kvpos = jnp.arange(p_max * ps)
    kvalid = jnp.repeat(mapped, ps, axis=1)
    qpos = positions
    mask = (kvalid[:, None, :]
            & (kvpos[None, None, :] <= qpos[:, :, None])
            & (qpos[:, :, None] >= 0))
    if kind == "local":
        mask &= (qpos[:, :, None] - kvpos[None, None, :]) < window
    elif kind == "chunked":
        mask &= ((qpos[:, :, None] // window)
                 == (kvpos[None, None, :] // window))
    m4 = mask[:, None, None]
    s = jnp.where(m4, s, _NEG_REF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(m4, jnp.exp(s - m), 0.0).astype(acc)
    out = jnp.einsum("bkgqK,bKkd->bkgqd", p, vl.astype(acc))
    out = out / jnp.maximum(jnp.sum(p, axis=-1)[..., None], 1e-30)
    return jnp.einsum("bkgqd->bqkgd", out).astype(q.dtype)
