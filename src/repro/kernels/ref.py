"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's tests sweep shapes and
dtypes and ``assert_allclose`` against these functions.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

_ACCUM = {
    jnp.dtype("int8"): jnp.int32,
    jnp.dtype("bfloat16"): jnp.float32,
    jnp.dtype("float32"): jnp.float32,
}


def accum_dtype(dtype) -> jnp.dtype:
    """32-bit accumulator for a given input dtype; unlisted dtypes fall
    back by kind (ints -> int32, floats -> fp32) — EXCEPT inputs already
    wider than 32 bits, which keep their width (an f64 reference run must
    not silently accumulate at fp32)."""
    dt = jnp.dtype(dtype)
    if dt in _ACCUM:
        return _ACCUM[dt]
    if dt.kind in ("i", "u"):
        return jnp.int32 if dt.itemsize <= 4 else jnp.int64
    if dt.kind == "f" and dt.itemsize >= 8:
        return jnp.float64
    return jnp.float32


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray,
               out_dtype: Optional[jnp.dtype] = None) -> jnp.ndarray:
    """C = A @ B with 32-bit accumulation (paper §IV-C1: int8 inputs
    accumulate in int32; floats accumulate in fp32)."""
    acc = accum_dtype(a.dtype)
    out_dtype = out_dtype or acc
    return jnp.dot(a, b, preferred_element_type=acc).astype(out_dtype)


def matmul_fused_ref(a: jnp.ndarray, b: jnp.ndarray, epilogue,
                     bias: Optional[jnp.ndarray] = None,
                     residual: Optional[jnp.ndarray] = None):
    """epilogue(A @ B): the XLA mirror of the fused-epilogue Pallas kernel.

    Shares ``kernels.epilogue.apply_epilogue`` with the kernel's store
    phase, so both paths are numerically identical by construction.
    Returns ``(q, scale)`` under ``epilogue.quantize``, else one array."""
    from repro.kernels.epilogue import apply_epilogue
    acc = jnp.dot(a, b, preferred_element_type=accum_dtype(a.dtype))
    return apply_epilogue(acc, epilogue, bias=bias, residual=residual)


def addertree_ref(partials: jnp.ndarray,
                  out_dtype: Optional[jnp.dtype] = None) -> jnp.ndarray:
    """Sum of Y stacked (M, N) partial products -- the paper's adder tree
    (Y-1 sequential Add kernels on one core)."""
    acc = accum_dtype(partials.dtype) if partials.dtype in _ACCUM else partials.dtype
    out_dtype = out_dtype or partials.dtype
    return jnp.sum(partials.astype(acc), axis=0).astype(out_dtype)


def quantize_rowwise_ref(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-wise symmetric int8 quantization: q = round(x / s), s = absmax/127.
    Returns (q int8 [M, N], scale f32 [M, 1])."""
    from repro.kernels.epilogue import quantize_symmetric
    return quantize_symmetric(x.astype(jnp.float32), axis=-1)


def quantize_colwise_ref(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Column-wise symmetric int8 quantization (the weight / weight-grad
    layout): q = round(x / s), s = per-column absmax / 127.  Works on any
    rank >= 2 (leading axes, e.g. a scan group axis, pass through).
    Returns (q int8 [..., K, N], scale f32 [..., 1, N])."""
    from repro.kernels.epilogue import quantize_symmetric
    return quantize_symmetric(x.astype(jnp.float32), axis=-2)


def dequantize_rowwise_ref(q: jnp.ndarray, scale: jnp.ndarray,
                           dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def int8_matmul_ref(qa: jnp.ndarray, sa: jnp.ndarray, qb: jnp.ndarray,
                    sb: jnp.ndarray, epilogue=None,
                    bias: Optional[jnp.ndarray] = None,
                    residual: Optional[jnp.ndarray] = None):
    """epilogue(sa * sb * (QA @ QB)): the serving int8 GEMM's XLA mirror.

    ``qa [M, K]`` int8 with rowwise scales ``sa [M, 1]``; ``qb [K, N]``
    int8 with columnwise scales ``sb [1, N]``.  Accumulation is int32 and
    both scales are re-applied at the int32 -> fp32 boundary INSIDE the
    epilogue (paper §IV-C1: scales come back on the way out), so the
    quantized pipeline never materializes a dequantized fp32 operand.
    Shares ``apply_epilogue`` with the Pallas kernel's store phase."""
    from repro.kernels.epilogue import Epilogue, apply_epilogue
    acc = jnp.dot(qa, qb, preferred_element_type=jnp.int32)
    return apply_epilogue(acc, epilogue or Epilogue(), bias=bias,
                          residual=residual, row_scale=sa, col_scale=sb)


def quantized_matmul_ref(a: jnp.ndarray, b: jnp.ndarray,
                         out_dtype=jnp.float32) -> jnp.ndarray:
    """int8 x int8 -> int32 matmul with row/col scales applied afterwards:
    the fully-quantized MatMul path (paper's int8 pipeline)."""
    qa, sa = quantize_rowwise_ref(a)
    qb, sb = quantize_colwise_ref(b)
    from repro.kernels.epilogue import Epilogue
    return int8_matmul_ref(qa, sa, qb, sb,
                           Epilogue(out_dtype=out_dtype))
