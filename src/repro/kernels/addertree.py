"""Adder-tree Pallas kernel — the paper's Add kernel (§IV-B, Fig. 5).

MaxEVA reduces the Y partial products of each (x, z) group *on the array*,
running all Y-1 Add kernels sequentially on a single AIE core with
single-buffered intermediates.  The TPU analogue reduces a stack of
partial-product tiles inside VMEM with a single accumulator tile, walking
the Y axis sequentially in the grid — one pass over HBM for Y partials
instead of Y-1 separate binary-add passes.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import accum_dtype


def _addertree_kernel(p_ref, o_ref, acc_ref, *, s_steps: int, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += p_ref[...].astype(acc_ref.dtype)

    @pl.when(pl.program_id(2) == s_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("block", "out_dtype", "interpret"))
def addertree_pallas(
    partials: jnp.ndarray,
    *,
    block: Tuple[int, int] = (256, 256),
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """out[M, N] = sum_s partials[s, M, N], 32-bit accumulation."""
    assert partials.ndim == 3
    s, m, n = partials.shape
    bm, bn = block
    acc = (accum_dtype(partials.dtype)
           if partials.dtype in (jnp.dtype("int8"), jnp.dtype("bfloat16"),
                                 jnp.dtype("float32"))
           else partials.dtype)
    out_dtype = out_dtype or partials.dtype

    pm = (-m) % bm
    pn = (-n) % bn
    p = jnp.pad(partials, ((0, 0), (0, pm), (0, pn))) if (pm or pn) else partials
    mp, np_ = p.shape[1], p.shape[2]
    grid = (mp // bm, np_ // bn, s)

    out = pl.pallas_call(
        functools.partial(_addertree_kernel, s_steps=s, out_dtype=out_dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((None, bm, bn), lambda i, j, y: (y, i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, y: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc)],
        interpret=interpret,
    )(p)
    return out[:m, :n]
