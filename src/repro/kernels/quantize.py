"""Row-wise symmetric int8 quantization Pallas kernel, plus the
``QuantizedWeight`` container the int8 serving path stores weights in.

Supports the paper's int8 MatMul pipeline (int8 inputs, int32 accumulation,
scales re-applied on the way out), the int8 error-feedback gradient
compression used by the distributed optimizer (``optim.compression``), and
the one-shot column-wise weight quantization of the serving engine.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedWeight:
    """An int8-quantized GEMM weight with per-column scales.

    ``q`` keeps the original weight's shape (possibly with leading stack
    axes: a scan group axis, or the xyz layout's model axis); ``scale`` is
    f32 with the second-to-last axis reduced to 1 (one scale per output
    column), so both leaves share every leading axis and a ``lax.scan``
    over stacked layer groups slices them in lockstep.

    Serving-only: produced by ``Model.quantize_params_for_serving`` after
    checkpoint restore, never checkpointed or trained.
    """

    q: jnp.ndarray       # int8 [..., K, N]
    scale: jnp.ndarray   # f32  [..., 1, N]

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self):
        return self.q.ndim

    def as_matrix(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Collapse leading singleton axes to the 2D GEMM operand pair
        ``(q [K, N], scale [1, N])`` — e.g. the xyz layout's ``[1, K, N]``
        single-shard weight."""
        k, n = self.q.shape[-2], self.q.shape[-1]
        assert all(s == 1 for s in self.q.shape[:-2]), self.q.shape
        return self.q.reshape(k, n), self.scale.reshape(1, n)

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        return (self.q.astype(jnp.float32) * self.scale).astype(dtype)


def quantize_weight_colwise(w: jnp.ndarray) -> QuantizedWeight:
    """One-shot column-wise weight quantization (the serving pass): one
    scale per output column, shared by every row of the contraction — the
    layout the int8 GEMM's store-phase epilogue folds back in."""
    from repro.kernels.ref import quantize_colwise_ref
    q, s = quantize_colwise_ref(w)
    return QuantizedWeight(q, s)


def quantize_fixed_scale(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Quantize with a FIXED (externally calibrated) scale.

    Unlike ``quantize_symmetric`` — whose per-call absmax scale maps the
    largest value to exactly ±127 and therefore never clips — a fixed
    calibrated scale CAN saturate when the activation range drifts past
    calibration.  The clip at ±127 is exactly where that saturation lands,
    which makes it countable: ``saturation_fraction`` on this function's
    output is the quantize-epilogue health counter the serving guard
    monitors (``ServeEngine``'s int8 -> fp32 graceful degradation).
    """
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8)


def saturation_fraction(q: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Fraction of int8 values pinned at the clip boundary (|q| == 127)
    along ``axis`` — per-row with the default, i.e. one health number per
    batch lane for a ``[B, N]`` activation tile.  A freshly
    absmax-quantized tensor reports ~1/N (only the max element sits at
    127); values approaching 1.0 mean the fixed scale is clipping most of
    the tensor and the int8 GEMM results are garbage."""
    sat = (jnp.abs(q.astype(jnp.int32)) >= 127).astype(jnp.float32)
    return jnp.mean(sat, axis=axis)


def _quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_rowwise_pallas(
    x: jnp.ndarray,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(q int8 [M, N], scale f32 [M, 1]) = rowwise-quantize(x [M, N]).

    Rows must be block-complete (the scale is a full-row reduction), so the
    grid tiles M only and each block spans all of N.
    """
    assert x.ndim == 2
    m, n = x.shape
    if m == 0:
        # zero rows: nothing to reduce — a 0-length grid is ill-formed, so
        # return the (well-defined) empty result directly
        return (jnp.zeros((0, n), jnp.int8), jnp.zeros((0, 1), jnp.float32))
    pm = (-m) % block_rows
    xp = jnp.pad(x, ((0, pm), (0, 0))) if pm else x
    mp = xp.shape[0]
    grid = (mp // block_rows,)

    q, s = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, n), jnp.int8),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp)
    return q[:m], s[:m]
