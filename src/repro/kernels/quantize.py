"""Row-wise symmetric int8 quantization Pallas kernel.

Supports the paper's int8 MatMul pipeline (int8 inputs, int32 accumulation,
scales re-applied on the way out) and the int8 error-feedback gradient
compression used by the distributed optimizer (``optim.compression``).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_rowwise_pallas(
    x: jnp.ndarray,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(q int8 [M, N], scale f32 [M, 1]) = rowwise-quantize(x [M, N]).

    Rows must be block-complete (the scale is a full-row reduction), so the
    grid tiles M only and each block spans all of N.
    """
    assert x.ndim == 2
    m, n = x.shape
    pm = (-m) % block_rows
    xp = jnp.pad(x, ((0, pm), (0, 0))) if pm else x
    mp = xp.shape[0]
    grid = (mp // block_rows,)

    q, s = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, n), jnp.int8),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp)
    return q[:m], s[:m]
