"""jit'd dispatch wrappers over the Pallas kernels.

On a TPU backend the Pallas path compiles natively; everywhere else (this
container is CPU-only) callers either get the XLA reference path (identical
semantics, real HLO for the dry-run/roofline) or may force
``interpret=True`` to execute the kernel bodies in Python for validation.
The mode is a process-global policy so that model code never has to thread
a backend flag through every layer.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.planner import plan_tpu_block
from repro.kernels import ref
from repro.kernels.epilogue import Epilogue
from repro.kernels.matmul import matmul_pallas
from repro.kernels.addertree import addertree_pallas
from repro.kernels.quantize import QuantizedWeight, quantize_rowwise_pallas

# 'auto': pallas on TPU, XLA elsewhere.  'pallas': force pallas (native).
# 'interpret': force pallas interpret mode (CPU validation).  'xla': force
# the reference path.
_MODE = os.environ.get("REPRO_KERNEL_MODE", "auto")
_VALID_MODES = ("auto", "pallas", "interpret", "xla")


def set_kernel_mode(mode: str) -> None:
    global _MODE
    assert mode in _VALID_MODES, mode
    _MODE = mode


def kernel_mode() -> str:
    if _MODE == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return _MODE


# Planner dtype keys for the dtypes the paper pipeline uses natively; any
# other dtype falls back by itemsize (2-byte floats plan like bf16, 1-byte
# ints like int8, everything else like fp32) instead of raising KeyError.
_PLANNER_DTYPE = {"bfloat16": "bf16", "float32": "fp32", "int8": "int8"}


def planner_dtype_key(dtype) -> str:
    if isinstance(dtype, str) and dtype in ("bf16", "fp32", "int8"):
        return dtype  # already a planner key
    dt = jnp.dtype(dtype)
    key = _PLANNER_DTYPE.get(dt.name)
    if key is not None:
        return key
    if dt.kind in ("i", "u") and dt.itemsize == 1:
        return "int8"
    if dt.kind == "f" and dt.itemsize == 2:
        return "bf16"
    return "fp32"


@functools.lru_cache(maxsize=None)
def default_block(m: int, k: int, n: int, dtype: str) -> Tuple[int, int, int]:
    b = plan_tpu_block(m, k, n, planner_dtype_key(dtype))
    return (b.bm, b.bk, b.bn)


def _clamped_default_block(m: int, k: int, n: int,
                           dtype: str) -> Tuple[int, int, int]:
    """Planned block, never exceeding the (padded) problem itself."""
    block = default_block(m, k, n, dtype)
    return (
        min(block[0], _round_pow2_up(m)),
        min(block[1], _round_pow2_up(k)),
        min(block[2], _round_pow2_up(n)),
    )


def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    out_dtype=None,
    block: Optional[Tuple[int, int, int]] = None,
    mode: Optional[str] = None,
    epilogue: Optional[Epilogue] = None,
    bias: Optional[jnp.ndarray] = None,
    residual: Optional[jnp.ndarray] = None,
    operand2: Optional[jnp.ndarray] = None,
    norm_scale: Optional[jnp.ndarray] = None,
):
    """Planned, blocked matmul (2D x 2D) with an optional fused epilogue.
    Higher-rank callers flatten the leading dims (activation rows are the
    M axis, as in the paper).

    With ``epilogue`` the bias/activation/residual/cast/quantize sequence
    runs in the kernel's store phase (one HBM write); the XLA path applies
    the same spec via ``ref.matmul_fused_ref`` (identical semantics, and
    XLA fuses the elementwise tail into the dot consumer).

    ``b`` may be a ``QuantizedWeight`` (the int8 serving path): ``a`` is
    then rowwise-quantized and the GEMM runs int8 x int8 -> int32 with
    both scales re-applied inside the epilogue — no fp32 weight dequant
    ever reaches the HLO."""
    mode = mode or kernel_mode()
    if epilogue is None:
        assert bias is None and residual is None and operand2 is None \
            and norm_scale is None, (
                "bias/residual/operand2/norm_scale operands require an "
                "Epilogue spec (e.g. epilogue=Epilogue(bias=True))")
    if isinstance(b, QuantizedWeight):
        qa, sa = quantize_rowwise(a, mode=mode)
        qb, sb = b.as_matrix()
        return int8_matmul(qa, sa, qb, sb, out_dtype=out_dtype,
                           block=block, mode=mode, epilogue=epilogue,
                           bias=bias, residual=residual,
                           operand2=operand2, norm_scale=norm_scale)
    if mode == "xla":
        if epilogue is None:
            return ref.matmul_ref(a, b, out_dtype)
        if out_dtype is not None and epilogue.out_dtype is None:
            # honor the out_dtype argument exactly like the kernel path
            import dataclasses
            epilogue = dataclasses.replace(epilogue, out_dtype=out_dtype)
        return ref.matmul_fused_ref(a, b, epilogue, bias=bias,
                                    residual=residual, operand2=operand2,
                                    norm_scale=norm_scale)
    if block is None:
        block = _clamped_default_block(a.shape[0], a.shape[1], b.shape[1],
                                       str(a.dtype))
    return matmul_pallas(
        a, b, block=block, out_dtype=out_dtype,
        interpret=(mode == "interpret"), epilogue=epilogue, bias=bias,
        residual=residual, operand2=operand2, norm_scale=norm_scale,
    )


def int8_matmul(
    qa: jnp.ndarray,
    sa: jnp.ndarray,
    qb: jnp.ndarray,
    sb: jnp.ndarray,
    *,
    out_dtype=None,
    block: Optional[Tuple[int, int, int]] = None,
    mode: Optional[str] = None,
    epilogue: Optional[Epilogue] = None,
    bias: Optional[jnp.ndarray] = None,
    residual: Optional[jnp.ndarray] = None,
    operand2: Optional[jnp.ndarray] = None,
    norm_scale: Optional[jnp.ndarray] = None,
):
    """Planned, blocked int8 x int8 -> int32 GEMM with both quantization
    scales folded into the fused epilogue (paper §IV-C1: int8 inputs,
    int32 accumulation, scales re-applied on the way out).

    ``qa [M, K]`` int8 activations with rowwise scales ``sa [M, 1]`` —
    exactly the ``(q, scale)`` pair the fused quantize epilogue of the
    previous GEMM (or ``quantize_rowwise``) emits; ``qb [K, N]`` int8
    weights with columnwise scales ``sb [1, N]`` (the one-shot serving
    weight-quantization layout).  The int32 -> fp32 boundary lives inside
    the store phase, so consecutive quantized GEMMs never bounce through
    a dequantized fp32 tensor in HBM."""
    assert qa.dtype == jnp.int8 and qb.dtype == jnp.int8, (qa.dtype,
                                                          qb.dtype)
    mode = mode or kernel_mode()
    ep = epilogue or Epilogue()
    assert ep.bias or bias is None, (
        "a bias operand requires Epilogue(bias=True)")
    assert ep.residual or residual is None, (
        "a residual operand requires Epilogue(residual=True)")
    assert ep.gate != "none" or operand2 is None, (
        "an operand2 requires Epilogue(gate=...)")
    if out_dtype is not None and ep.out_dtype is None:
        import dataclasses
        ep = dataclasses.replace(ep, out_dtype=out_dtype)
    if mode == "xla":
        return ref.int8_matmul_ref(qa, sa, qb, sb, ep, bias=bias,
                                   residual=residual, operand2=operand2,
                                   norm_scale=norm_scale)
    if block is None:
        block = _clamped_default_block(qa.shape[0], qa.shape[1],
                                       qb.shape[1], "int8")
    return matmul_pallas(
        qa, qb, block=block, interpret=(mode == "interpret"), epilogue=ep,
        a_scale=sa, b_scale=sb, bias=bias, residual=residual,
        operand2=operand2, norm_scale=norm_scale,
    )


def addertree(
    partials: jnp.ndarray,
    *,
    out_dtype=None,
    block: Tuple[int, int] = (256, 256),
    mode: Optional[str] = None,
) -> jnp.ndarray:
    mode = mode or kernel_mode()
    if mode == "xla":
        return ref.addertree_ref(partials, out_dtype)
    block = (
        min(block[0], _round_pow2_up(partials.shape[1])),
        min(block[1], _round_pow2_up(partials.shape[2])),
    )
    return addertree_pallas(
        partials, block=block, out_dtype=out_dtype,
        interpret=(mode == "interpret"),
    )


def quantize_rowwise(
    x: jnp.ndarray, *, block_rows: int = 256, mode: Optional[str] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    # the named_scope marks this as a STANDALONE quantize dispatch in the
    # traced HLO's op_name metadata — the fusion audit
    # (analysis/passes.py::fusion_scope_pass) counts these to prove the
    # fused (q, scale) handoffs really replaced separate quantize ops
    mode = mode or kernel_mode()
    with jax.named_scope("quantize_rowwise"):
        if mode == "xla":
            return ref.quantize_rowwise_ref(x)
        return quantize_rowwise_pallas(
            x, block_rows=min(block_rows, _round_pow2_up(x.shape[0])),
            interpret=(mode == "interpret"),
        )


def quantize_colwise(
    x: jnp.ndarray, *, block_rows: int = 256, mode: Optional[str] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Column-wise symmetric int8 quantization (weight / weight-grad
    layout): (q [M, N], scale [1, N]).  The Pallas path reuses the rowwise
    kernel on the transpose (the reduction is the kernel's fast axis
    either way); XLA mode uses the direct reference."""
    mode = mode or kernel_mode()
    if mode == "xla" or x.ndim != 2:
        return ref.quantize_colwise_ref(x)
    q_t, s_t = quantize_rowwise_pallas(
        x.T, block_rows=min(block_rows, _round_pow2_up(x.shape[1])),
        interpret=(mode == "interpret"))
    return q_t.T, s_t.reshape(1, -1)


def dequantize_rowwise(q, scale, dtype=jnp.float32):
    return ref.dequantize_rowwise_ref(q, scale, dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
#
# The decode wrappers dispatch all three modes ('xla' -> the tiled-XLA
# mirror with identical tile semantics).  The prefill wrapper covers the
# Pallas kernel only: the XLA prefill path is the chunked running-softmax
# scan in models/attention.py (it predates the kernel and stays the CPU
# production path), so models code calls this wrapper only when
# ``kernel_mode() != 'xla'``.

def flash_attention(q, k, v, *, kind="global", window=0, prefix_len=0,
                    softcap=None, q_offset=0, block_q=128, block_k=128,
                    mode: Optional[str] = None):
    from repro.kernels.flash_attention import flash_attention_pallas
    mode = mode or kernel_mode()
    assert mode in ("pallas", "interpret"), mode
    return flash_attention_pallas(
        q, k, v, kind=kind, window=window, prefix_len=prefix_len,
        softcap=softcap, q_offset=q_offset, block_q=block_q,
        block_k=block_k, interpret=(mode == "interpret"))


def flash_decode(q, k_cache, v_cache, pos, *, kind="global", softcap=None,
                 kv_tile: Optional[int] = None, n_splits: int = 1,
                 mode: Optional[str] = None):
    from repro.kernels import flash_attention as fa
    mode = mode or kernel_mode()
    kv_tile = kv_tile or fa.DEFAULT_KV_TILE
    if mode == "xla":
        return fa.flash_decode_xla(q, k_cache, v_cache, pos, kind=kind,
                                   softcap=softcap, kv_tile=kv_tile)
    return fa.flash_decode_pallas(
        q, k_cache, v_cache, pos, kind=kind, softcap=softcap,
        kv_tile=kv_tile, n_splits=n_splits,
        interpret=(mode == "interpret"))


def paged_flash_decode(q, k_pool, v_pool, page_table, positions, *,
                       kind="global", window=0, softcap=None,
                       kv_tile: Optional[int] = None,
                       mode: Optional[str] = None):
    from repro.kernels import flash_attention as fa
    mode = mode or kernel_mode()
    kv_tile = kv_tile or fa.DEFAULT_KV_TILE
    if mode == "xla" or q.shape[1] != 1:
        # the Pallas paged kernel is decode-only; prefill chunks (S > 1)
        # always take the tiled-XLA mirror
        return fa.paged_flash_decode_xla(
            q, k_pool, v_pool, page_table, positions, kind=kind,
            window=window, softcap=softcap, kv_tile=kv_tile)
    return fa.paged_flash_decode_pallas(
        q, k_pool, v_pool, page_table, positions.reshape(-1), kind=kind,
        window=window, softcap=softcap, interpret=(mode == "interpret"))


def _round_pow2_up(v: int) -> int:
    p = 1
    while p < v:
        p *= 2
    return p
