"""Fused flash-attention Pallas kernels: prefill, decode, paged decode.

The recipe follows ``kernels/matmul.py``: a Pallas kernel with an explicit
grid and VMEM scratch, an XLA mirror with IDENTICAL tile semantics for the
CPU production path, and f64-capable oracles in ``kernels/ref.py``.  The
prefill kernel is the classic online-softmax flash loop (no S x S score
materialization); decode and paged decode are split-K flash-decode in the
SNIPPETS flashdecode shape: partial softmax per KV split, combined after.

Determinism contract — the MaxEVA rank-order rule applied to softmax
----------------------------------------------------------------------
Every decode path reduces the KV axis in fixed ``kv_tile`` tiles anchored
at position 0.  Each tile yields an independent partial

    m_t   = max of its masked scores            (fp32)
    l_t   = sum exp(s - m_t) over the tile      (fp32)
    acc_t = sum exp(s - m_t) * v over the tile  (fp32)

and the combine is a global fp-max over tiles (associative and
commutative, so order-free) followed by an elementwise rescale
``alpha_t = exp(m_t - m)`` and an ASCENDING rank-order fold at fp32 —
``_rank_order_sum`` from ``core/maxeva_matmul.py``, the same association
that locked the four collective schedules bitwise-equal.  Partial values
never depend on how tiles are grouped into kernel programs, and the fold
order never depends on the split count, so ``n_splits`` in {1, 2, 4}
produces bitwise-identical fp32 outputs.  A fully masked tile (cache
padding, future positions, unmapped/trash pages) contributes exact +0.0
to the fold, which is what keeps a paged lane's output bitwise-equal to
the same history in a dense cache: the tiles they share see identical
rows at the valid slots, and everything else folds in as +0.0.

Score dots run at the storage dtype with ``preferred_element_type=fp32``
— a single dot_general per tile, no full-pool ``convert`` in the traced
HLO (XLA CPU legalizes bf16 dots by upcasting per-tile operands inside
the dot fusion, never the whole cache).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30

# Default KV tile of the decode paths.  Both the dense and the paged
# decode MUST use the same value (and the paged logical view is tiled
# from position 0) or their partials stop lining up bitwise.
DEFAULT_KV_TILE = 32


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _ceil_mult(v: int, m: int) -> int:
    return _ceil_div(v, m) * m


def _pad_axis(x: jnp.ndarray, axis: int, to: int) -> jnp.ndarray:
    if x.shape[axis] == to:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, to - x.shape[axis])
    return jnp.pad(x, pads)


def _softcap(s: jnp.ndarray, softcap) -> jnp.ndarray:
    if softcap:
        return softcap * jnp.tanh(s / softcap)
    return s


def combine_tile_partials(m_t: jnp.ndarray, l_t: jnp.ndarray,
                          acc_t: jnp.ndarray) -> jnp.ndarray:
    """Combine per-tile softmax partials stacked on axis 0.

    ``m_t``/``l_t`` [T, ...], ``acc_t`` [T, ..., hd], all fp32.  Returns
    the normalized attention output [..., hd] fp32.  The fold is the
    rank-order association from ``core/maxeva_matmul`` so the result is
    independent of how tiles were grouped into splits; fully masked
    tiles (m_t == _NEG while any tile is live) rescale to exact 0.
    """
    from repro.core.maxeva_matmul import _rank_order_sum
    m = jnp.max(m_t, axis=0)
    alpha = jnp.exp(m_t - m[None])
    l = _rank_order_sum(l_t * alpha, jnp.float32)
    acc = _rank_order_sum(acc_t * alpha[..., None], jnp.float32)
    return acc / jnp.maximum(l, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# decode: dense cache, tiled XLA mirror
# ---------------------------------------------------------------------------

def _decode_tile_partials_xla(q, k_cache, v_cache, pos, *, kind, softcap,
                              kv_tile):
    """Per-tile partials over a dense cache.  q [B, S, KV, G, hd],
    caches [B, K, KV, hd].  Returns (m_t, l_t, acc_t) stacked on axis 0
    with inner layout [B, KV, G, S(, hd)].

    One dot_general PER TILE (a static unrolled loop): XLA CPU legalizes
    each bf16 dot by converting only that tile's operands, so the traced
    HLO never contains a full-cache fp32 ``convert`` — the bug the
    einsum fallback had.
    """
    hd = q.shape[-1]
    kv_len = k_cache.shape[1]
    n_tiles = _ceil_div(kv_len, kv_tile)
    kp = _pad_axis(k_cache, 1, n_tiles * kv_tile)
    vp = _pad_axis(v_cache, 1, n_tiles * kv_tile)
    scale = jnp.float32(hd) ** -0.5
    ms, ls, accs = [], [], []
    for t in range(n_tiles):
        kt = jax.lax.slice_in_dim(kp, t * kv_tile, (t + 1) * kv_tile, axis=1)
        vt = jax.lax.slice_in_dim(vp, t * kv_tile, (t + 1) * kv_tile, axis=1)
        s = jnp.einsum("bqkgd,bKkd->bkgqK", q, kt,
                       preferred_element_type=jnp.float32) * scale
        s = _softcap(s, softcap)
        slots = t * kv_tile + jnp.arange(kv_tile)
        valid = slots < kv_len
        if kind != "full":
            valid &= slots <= pos
        v5 = valid[None, None, None, None, :]
        s = jnp.where(v5, s, _NEG)
        m_t = jnp.max(s, axis=-1)
        p = jnp.where(v5, jnp.exp(s - m_t[..., None]), 0.0)
        l_t = jnp.sum(p, axis=-1)
        acc_t = jnp.einsum("bkgqK,bKkd->bkgqd", p, vt,
                           preferred_element_type=jnp.float32)
        ms.append(m_t)
        ls.append(l_t)
        accs.append(acc_t)
    return jnp.stack(ms), jnp.stack(ls), jnp.stack(accs)


def flash_decode_xla(q, k_cache, v_cache, pos, *, kind="global",
                     softcap=None, kv_tile=DEFAULT_KV_TILE) -> jnp.ndarray:
    """Tiled-XLA flash decode: q [B, S, KV, G, hd] against dense caches
    [B, K, KV, hd] -> [B, S, KV, G, hd].  'global' attends slots <= pos,
    'full' attends every slot (cross-attention)."""
    m_t, l_t, acc_t = _decode_tile_partials_xla(
        q, k_cache, v_cache, pos, kind=kind, softcap=softcap,
        kv_tile=kv_tile)
    out = combine_tile_partials(m_t, l_t, acc_t)
    return jnp.einsum("bkgqd->bqkgd", out).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode: paged pools, tiled XLA mirror
# ---------------------------------------------------------------------------

def _paged_tile_partials_xla(q, k_pool, v_pool, page_table, positions, *,
                             kind, window, softcap, kv_tile):
    """Per-tile partials over the gathered logical view [B, P*PS, ...].

    The gather stays at the pools' storage dtype (bf16 moves, no
    convert); tiles are anchored at logical position 0 with the SAME
    ``kv_tile`` as the dense path, so a lane's live tiles are
    bitwise-identical to the dense-cache tiles over the same history
    and every masked slot (unmapped page, future position, inactive
    lane) contributes exact +0.0 to the fold.
    """
    n_pool, ps = k_pool.shape[0], k_pool.shape[1]
    b, p_max = page_table.shape
    hd = q.shape[-1]
    mapped = page_table >= 0
    ptc = jnp.where(mapped, page_table, n_pool - 1)
    kl = k_pool[ptc].reshape(b, p_max * ps, *k_pool.shape[2:])
    vl = v_pool[ptc].reshape(b, p_max * ps, *v_pool.shape[2:])
    kv_len = p_max * ps
    n_tiles = _ceil_div(kv_len, kv_tile)
    kl = _pad_axis(kl, 1, n_tiles * kv_tile)
    vl = _pad_axis(vl, 1, n_tiles * kv_tile)
    kvalid = _pad_axis(jnp.repeat(mapped, ps, axis=1), 1,
                       n_tiles * kv_tile)
    scale = jnp.float32(hd) ** -0.5
    qpos = positions                                         # [B, S]
    ms, ls, accs = [], [], []
    for t in range(n_tiles):
        kt = jax.lax.slice_in_dim(kl, t * kv_tile, (t + 1) * kv_tile, axis=1)
        vt = jax.lax.slice_in_dim(vl, t * kv_tile, (t + 1) * kv_tile, axis=1)
        s = jnp.einsum("bqkgd,bKkd->bkgqK", q, kt,
                       preferred_element_type=jnp.float32) * scale
        s = _softcap(s, softcap)
        kvpos = t * kv_tile + jnp.arange(kv_tile)
        mask = (kvalid[:, t * kv_tile:(t + 1) * kv_tile][:, None, :]
                & (kvpos[None, None, :] <= qpos[:, :, None])
                & (qpos[:, :, None] >= 0))
        if kind == "local":
            mask &= (qpos[:, :, None] - kvpos[None, None, :]) < window
        elif kind == "chunked":
            mask &= ((qpos[:, :, None] // window)
                     == (kvpos[None, None, :] // window))
        m5 = mask[:, None, None]                             # [B,1,1,S,T]
        s = jnp.where(m5, s, _NEG)
        m_t = jnp.max(s, axis=-1)
        p = jnp.where(m5, jnp.exp(s - m_t[..., None]), 0.0)
        l_t = jnp.sum(p, axis=-1)
        acc_t = jnp.einsum("bkgqK,bKkd->bkgqd", p, vt,
                           preferred_element_type=jnp.float32)
        ms.append(m_t)
        ls.append(l_t)
        accs.append(acc_t)
    return jnp.stack(ms), jnp.stack(ls), jnp.stack(accs)


def paged_flash_decode_xla(q, k_pool, v_pool, page_table, positions, *,
                           kind="global", window=0, softcap=None,
                           kv_tile=DEFAULT_KV_TILE) -> jnp.ndarray:
    """Tiled-XLA paged flash decode: q [B, S, KV, G, hd] through the page
    table against pools [NP, PS, KV, hd] -> [B, S, KV, G, hd]."""
    m_t, l_t, acc_t = _paged_tile_partials_xla(
        q, k_pool, v_pool, page_table, positions, kind=kind, window=window,
        softcap=softcap, kv_tile=kv_tile)
    out = combine_tile_partials(m_t, l_t, acc_t)
    return jnp.einsum("bkgqd->bqkgd", out).astype(q.dtype)


# ---------------------------------------------------------------------------
# prefill: online-softmax Pallas kernel
# ---------------------------------------------------------------------------

def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                    n_k: int, kind: str, window: int, prefix_len: int,
                    softcap, q_offset: int, kv_len: int, scale: float,
                    block_q: int, block_k: int):
    """Grid = (B*H, Sq/bq, Skv/bk); the kv axis is the innermost
    (sequential) axis and the running (m, l, acc) live in VMEM scratch
    across kv steps — the matmul kernel's zero/accumulate/store phasing
    with the online-softmax rescale in the accumulate step."""
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _zero():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, hd_p)
    k = k_ref[0]                                   # (bk, hd_p)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    qpos = (q_offset + i * block_q
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0))
    kpos = (j * block_k
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1))
    mask = kpos < kv_len                           # right padding
    if kind in ("global", "local", "chunked", "prefix"):
        causal = qpos >= kpos
        if kind == "local":
            causal &= (qpos - kpos) < window
        elif kind == "chunked":
            causal &= (qpos // window) == (kpos // window)
        elif kind == "prefix":
            causal |= kpos < prefix_len
        mask &= causal
    s = jnp.where(mask, s, _NEG)

    m_prev = m_ref[:, :1]
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # guard fully-masked rows: exp(_NEG - _NEG) would be 1
    alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * alpha
                    + jax.lax.dot_general(
                        p, v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == n_k - 1)
    def _store():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "window", "prefix_len", "softcap", "q_offset",
                     "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, kind="global", window=0,
                           prefix_len=0, softcap=None, q_offset=0,
                           block_q=128, block_k=128,
                           interpret=False) -> jnp.ndarray:
    """Online-softmax flash prefill kernel.

    Head-expanded ``q [B, Sq, H, hd]``; ``k``/``v`` [B, Skv, KV, hd] may
    carry fewer (GQA) heads — the kernel's index maps point q head h at
    kv head ``h // (H // KV)``, so the grouped K/V views coming off the
    packed ``wqkv`` projection are consumed WITHOUT materializing the
    ``jnp.repeat`` head expansion the XLA path pays.
    """
    b, sq, n_h, hd = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    assert n_h % n_kv == 0, (n_h, n_kv)
    g = n_h // n_kv
    bq = min(block_q, _ceil_mult(sq, 8))
    bk = min(block_k, _ceil_mult(skv, 8))
    sq_p, skv_p = _ceil_mult(sq, bq), _ceil_mult(skv, bk)
    hd_p = max(_ceil_mult(hd, 128), 128)

    qr = jnp.moveaxis(q, 2, 1).reshape(b * n_h, sq, hd)
    kr = jnp.moveaxis(k, 2, 1).reshape(b * n_kv, skv, hd)
    vr = jnp.moveaxis(v, 2, 1).reshape(b * n_kv, skv, hd)
    qr = _pad_axis(_pad_axis(qr, 1, sq_p), 2, hd_p)
    kr = _pad_axis(_pad_axis(kr, 1, skv_p), 2, hd_p)
    vr = _pad_axis(_pad_axis(vr, 1, skv_p), 2, hd_p)
    n_q, n_k = sq_p // bq, skv_p // bk

    def kv_row(bh):
        return (bh // n_h) * n_kv + (bh % n_h) // g

    grid = (b * n_h, n_q, n_k)
    kernel = functools.partial(
        _prefill_kernel, n_k=n_k, kind=kind, window=window,
        prefix_len=prefix_len, softcap=softcap, q_offset=q_offset,
        kv_len=skv, scale=float(hd) ** -0.5, block_q=bq, block_k=bk)
    cp_cls = (getattr(pltpu, "CompilerParams", None)
              or getattr(pltpu, "TPUCompilerParams", None))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd_p), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, hd_p), lambda bh, i, j: (kv_row(bh), j, 0)),
            pl.BlockSpec((1, bk, hd_p), lambda bh, i, j: (kv_row(bh), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd_p), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * n_h, sq_p, hd_p), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, hd_p), jnp.float32),
        ],
        compiler_params=cp_cls(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    out = out[:, :sq, :hd].reshape(b, n_h, sq, hd)
    return jnp.moveaxis(out, 1, 2)


# ---------------------------------------------------------------------------
# decode: split-K flash-decode Pallas kernel
# ---------------------------------------------------------------------------

def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref, *,
                   tiles_per_split: int, kv_tile: int, kv_len: int,
                   kind: str, softcap, scale: float, g_p: int):
    """Grid = (B*KV, n_splits); each program emits per-tile partials for
    its split's tiles.  Nothing is carried across tiles — partial values
    are a pure function of (tile index, inputs), which is what makes the
    split count irrelevant to the combine's numerics."""
    split = pl.program_id(1)
    pos = pos_ref[0]
    q = q_ref[0]                                    # (g_p, hd_p)
    for tt in range(tiles_per_split):
        k_t = k_ref[0, tt * kv_tile:(tt + 1) * kv_tile]
        v_t = v_ref[0, tt * kv_tile:(tt + 1) * kv_tile]
        s = jax.lax.dot_general(q, k_t, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        slot = ((split * tiles_per_split + tt) * kv_tile
                + jax.lax.broadcasted_iota(jnp.int32, (g_p, kv_tile), 1))
        valid = slot < kv_len
        if kind != "full":
            valid &= slot <= pos
        s = jnp.where(valid, s, _NEG)
        m_t = jnp.max(s, axis=-1)                   # (g_p,)
        p = jnp.where(valid, jnp.exp(s - m_t[:, None]), 0.0)
        l_t = jnp.sum(p, axis=-1)
        acc_t = jax.lax.dot_general(p, v_t, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        m_ref[0, tt] = m_t
        l_ref[0, tt] = l_t
        acc_ref[0, tt] = acc_t


@functools.partial(
    jax.jit,
    static_argnames=("kind", "softcap", "kv_tile", "n_splits", "interpret"))
def flash_decode_pallas(q, k_cache, v_cache, pos, *, kind="global",
                        softcap=None, kv_tile=DEFAULT_KV_TILE, n_splits=1,
                        interpret=False) -> jnp.ndarray:
    """Split-K flash decode: q [B, 1, KV, G, hd] against dense caches
    [B, K, KV, hd] -> [B, 1, KV, G, hd].  ``n_splits`` partitions the KV
    tiles over kernel programs; partials combine OUTSIDE the kernel via
    ``combine_tile_partials``, so any split count is bitwise-identical.
    """
    b, s_q, n_kv, g, hd = q.shape
    assert s_q == 1, "flash decode is single-token (use prefill for S>1)"
    kv_len = k_cache.shape[1]
    n_tiles = _ceil_mult(_ceil_div(kv_len, kv_tile), n_splits)
    tiles_per_split = n_tiles // n_splits
    kv_p = n_tiles * kv_tile
    split_len = tiles_per_split * kv_tile
    hd_p = max(_ceil_mult(hd, 128), 128)
    g_p = _ceil_mult(g, 8)

    qr = _pad_axis(_pad_axis(
        q.reshape(b, n_kv, g, hd), 2, g_p), 3, hd_p)
    qr = qr.reshape(b * n_kv, g_p, hd_p)
    kr = jnp.moveaxis(k_cache, 2, 1).reshape(b * n_kv, kv_len, hd)
    vr = jnp.moveaxis(v_cache, 2, 1).reshape(b * n_kv, kv_len, hd)
    kr = _pad_axis(_pad_axis(kr, 1, kv_p), 2, hd_p)
    vr = _pad_axis(_pad_axis(vr, 1, kv_p), 2, hd_p)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(
        _decode_kernel, tiles_per_split=tiles_per_split, kv_tile=kv_tile,
        kv_len=kv_len, kind=kind, softcap=softcap,
        scale=float(hd) ** -0.5, g_p=g_p)
    cp_cls = (getattr(pltpu, "CompilerParams", None)
              or getattr(pltpu, "TPUCompilerParams", None))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * n_kv, n_splits),
        in_specs=[
            pl.BlockSpec((1, g_p, hd_p), lambda r, s, pos_ref: (r, 0, 0)),
            pl.BlockSpec((1, split_len, hd_p),
                         lambda r, s, pos_ref: (r, s, 0)),
            pl.BlockSpec((1, split_len, hd_p),
                         lambda r, s, pos_ref: (r, s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tiles_per_split, g_p),
                         lambda r, s, pos_ref: (r, s, 0)),
            pl.BlockSpec((1, tiles_per_split, g_p),
                         lambda r, s, pos_ref: (r, s, 0)),
            pl.BlockSpec((1, tiles_per_split, g_p, hd_p),
                         lambda r, s, pos_ref: (r, s, 0, 0)),
        ],
    )
    m_t, l_t, acc_t = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b * n_kv, n_tiles, g_p), jnp.float32),
            jax.ShapeDtypeStruct((b * n_kv, n_tiles, g_p), jnp.float32),
            jax.ShapeDtypeStruct((b * n_kv, n_tiles, g_p, hd_p),
                                 jnp.float32),
        ],
        compiler_params=cp_cls(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(pos_arr, qr, kr, vr)
    # tiles to axis 0, then the shared deterministic combine
    out = combine_tile_partials(jnp.moveaxis(m_t, 1, 0),
                                jnp.moveaxis(l_t, 1, 0),
                                jnp.moveaxis(acc_t, 1, 0))
    out = out.reshape(b, n_kv, g_p, hd_p)[:, :, :g, :hd]
    return out[:, None].astype(q.dtype)


# ---------------------------------------------------------------------------
# decode: paged flash-decode Pallas kernel (gather-in-kernel)
# ---------------------------------------------------------------------------

def _paged_decode_kernel(table_ref, pos_ref, q_ref, k_ref, v_ref,
                         m_ref, l_ref, acc_ref, *, ps: int, kind: str,
                         window: int, softcap, scale: float, g_p: int,
                         n_kv: int):
    """Grid = (B, KV, P): one program per (lane, kv head, logical page).
    The page gather happens in the BlockSpec index map (scalar-prefetched
    page table -> pool row), so only mapped pages move — unmapped slots
    read the trash page and are masked to exact zeros here."""
    lane, page = pl.program_id(0), pl.program_id(2)
    pos = pos_ref[lane]
    q = q_ref[0, 0]                                 # (g_p, hd_p)
    k_t = k_ref[0]                                  # (ps, hd_p)
    v_t = v_ref[0]
    s = jax.lax.dot_general(q, k_t, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    kvpos = (page * ps
             + jax.lax.broadcasted_iota(jnp.int32, (g_p, ps), 1))
    valid = (table_ref[lane, page] >= 0) & (kvpos <= pos) & (pos >= 0)
    if kind == "local":
        valid &= (pos - kvpos) < window
    elif kind == "chunked":
        valid &= (kvpos // window) == (pos // window)
    s = jnp.where(valid, s, _NEG)
    m_t = jnp.max(s, axis=-1)
    p = jnp.where(valid, jnp.exp(s - m_t[:, None]), 0.0)
    l_t = jnp.sum(p, axis=-1)
    acc_t = jax.lax.dot_general(p, v_t, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    m_ref[0, 0, 0] = m_t
    l_ref[0, 0, 0] = l_t
    acc_ref[0, 0, 0] = acc_t


@functools.partial(
    jax.jit,
    static_argnames=("kind", "window", "softcap", "interpret"))
def paged_flash_decode_pallas(q, k_pool, v_pool, page_table, positions, *,
                              kind="global", window=0, softcap=None,
                              interpret=False) -> jnp.ndarray:
    """Paged flash decode, gather-in-kernel: q [B, 1, KV, G, hd] against
    pools [NP, PS, KV, hd] through ``page_table`` [B, P] (-1 = unmapped
    -> trash page NP-1, masked to exact zeros) at per-lane ``positions``
    [B] (-1 = idle lane -> all-zero output).  The KV tile is one page;
    partials combine outside the kernel with the same deterministic fold
    as the dense path.
    """
    b, s_q, n_kv, g, hd = q.shape
    assert s_q == 1, "paged flash kernel is decode-only (S == 1)"
    n_pool, ps = k_pool.shape[0], k_pool.shape[1]
    p_max = page_table.shape[1]
    hd_p = max(_ceil_mult(hd, 128), 128)
    g_p = _ceil_mult(g, 8)

    qr = _pad_axis(_pad_axis(q.reshape(b, n_kv, g, hd), 2, g_p), 3, hd_p)
    kr = jnp.moveaxis(k_pool, 2, 1).reshape(n_pool * n_kv, ps, hd)
    vr = jnp.moveaxis(v_pool, 2, 1).reshape(n_pool * n_kv, ps, hd)
    kr = _pad_axis(kr, 2, hd_p)
    vr = _pad_axis(vr, 2, hd_p)
    table = jnp.asarray(page_table, jnp.int32)
    pos = jnp.asarray(positions, jnp.int32).reshape(b)

    def pool_row(lane, h, page, table_ref, pos_ref):
        t = table_ref[lane, page]
        return (jnp.where(t >= 0, t, n_pool - 1) * n_kv + h, 0, 0)

    kernel = functools.partial(
        _paged_decode_kernel, ps=ps, kind=kind, window=window,
        softcap=softcap, scale=float(hd) ** -0.5, g_p=g_p, n_kv=n_kv)
    cp_cls = (getattr(pltpu, "CompilerParams", None)
              or getattr(pltpu, "TPUCompilerParams", None))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_kv, p_max),
        in_specs=[
            pl.BlockSpec((1, 1, g_p, hd_p),
                         lambda lane, h, page, t, p: (lane, h, 0, 0)),
            pl.BlockSpec((1, ps, hd_p), pool_row),
            pl.BlockSpec((1, ps, hd_p), pool_row),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, g_p),
                         lambda lane, h, page, t, p: (page, lane, h, 0)),
            pl.BlockSpec((1, 1, 1, g_p),
                         lambda lane, h, page, t, p: (page, lane, h, 0)),
            pl.BlockSpec((1, 1, 1, g_p, hd_p),
                         lambda lane, h, page, t, p: (page, lane, h, 0, 0)),
        ],
    )
    m_t, l_t, acc_t = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((p_max, b, n_kv, g_p), jnp.float32),
            jax.ShapeDtypeStruct((p_max, b, n_kv, g_p), jnp.float32),
            jax.ShapeDtypeStruct((p_max, b, n_kv, g_p, hd_p), jnp.float32),
        ],
        compiler_params=cp_cls(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(table, pos, qr, kr, vr)
    out = combine_tile_partials(m_t, l_t, acc_t)     # [B, KV, g_p, hd_p]
    out = out[:, :, :g, :hd]
    return out[:, None].astype(q.dtype)
