"""Declarative fused-GEMM epilogue spec, shared by every backend.

The paper keeps partial products out of slow memory by reducing them
on-array (the adder tree, §IV-B) and ping-pong buffering tiles in local
memory (§IV-C).  The TPU analogue of the remaining leak is the GEMM
*epilogue*: bias add, activation, residual add, output cast, and rowwise
int8 quantization were separate XLA ops, so every matmul wrote its fp32
accumulator to HBM and a second op read it back.  An ``Epilogue`` spec
lets the Pallas kernel apply all of them on the VMEM accumulator tile in
the store phase — one HBM write instead of write + read + write.

``apply_epilogue`` is the single implementation of the spec's semantics.
The Pallas kernel calls it on the accumulator *tile*; the XLA reference
path (``kernels.ref.matmul_fused_ref``) calls it on the full accumulator
matrix.  Because both run the same jnp ops in fp32, the two paths are
numerically identical by construction.

Application order (all math in fp32 — or the int32 accumulator is first
upcast when any step beyond the cast is requested):

    acc -> (+ bias) -> activation -> (+ residual) -> cast | rowwise-int8

With ``quantize=True`` the epilogue emits ``(q int8 [M, N], scale f32
[M, 1])`` as the kernel's two outputs and ``out_dtype`` is ignored.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp

_ACTIVATIONS = ("none", "gelu", "silu", "relu")


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Static (hashable) description of a fused GEMM store phase.

    bias:       add a ``[N]`` bias row (operand supplied at call time).
    activation: 'none' | 'gelu' | 'silu' | 'relu', applied in fp32.
    residual:   add a ``[M, N]`` residual (operand supplied at call time).
    out_dtype:  storage dtype of the single output (None -> accumulator
                dtype).  Ignored when ``quantize`` is set.
    quantize:   rowwise symmetric int8 quantization; the GEMM emits
                ``(q, scale)`` instead of one output.
    """

    bias: bool = False
    activation: str = "none"
    residual: bool = False
    out_dtype: Optional[Any] = None
    quantize: bool = False

    def __post_init__(self):
        assert self.activation in _ACTIVATIONS, self.activation

    @property
    def is_identity(self) -> bool:
        """True when the epilogue is nothing but the accumulator cast."""
        return not (self.bias or self.residual or self.quantize
                    or self.activation != "none")

    @property
    def n_outputs(self) -> int:
        return 2 if self.quantize else 1

    def out_itemsize(self, acc_dtype=jnp.float32) -> int:
        """Bytes per output element actually stored to HBM (the quantize
        scale column is amortized over N and ignored here)."""
        if self.quantize:
            return 1
        return jnp.dtype(self.out_dtype or acc_dtype).itemsize


def _activate(x: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "gelu":
        return jax.nn.gelu(x)
    if activation == "silu":
        return jax.nn.silu(x)
    if activation == "relu":
        return jax.nn.relu(x)
    return x


def apply_epilogue(
    acc: jnp.ndarray,
    ep: Epilogue,
    bias: Optional[jnp.ndarray] = None,
    residual: Optional[jnp.ndarray] = None,
) -> Union[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Apply ``ep`` to an accumulator (tile or full matrix).

    ``acc`` is the 32-bit GEMM accumulator.  ``bias`` broadcasts over rows
    (shape ``[N]`` or ``[1, N]``); ``residual`` matches ``acc``.  Returns
    the cast output, or ``(q, scale)`` under ``quantize``.
    """
    if ep.is_identity:
        return acc.astype(ep.out_dtype) if ep.out_dtype else acc

    x = acc.astype(jnp.float32)
    if ep.bias:
        assert bias is not None, "Epilogue.bias set but no bias operand"
        b = bias.astype(jnp.float32)
        x = x + (b if b.ndim == x.ndim else b[None, :])
    x = _activate(x, ep.activation)
    if ep.residual:
        assert residual is not None, (
            "Epilogue.residual set but no residual operand")
        x = x + residual.astype(jnp.float32)

    if ep.quantize:
        absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        scale = (jnp.maximum(absmax, 1e-12) / 127.0).astype(jnp.float32)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return q, scale

    return x.astype(ep.out_dtype or acc.dtype)
