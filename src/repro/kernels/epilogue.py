"""Declarative fused-GEMM epilogue spec, shared by every backend.

The paper keeps partial products out of slow memory by reducing them
on-array (the adder tree, §IV-B) and ping-pong buffering tiles in local
memory (§IV-C).  The TPU analogue of the remaining leak is the GEMM
*epilogue*: bias add, activation, residual add, output cast, and rowwise
int8 quantization were separate XLA ops, so every matmul wrote its fp32
accumulator to HBM and a second op read it back.  An ``Epilogue`` spec
lets the Pallas kernel apply all of them on the VMEM accumulator tile in
the store phase — one HBM write instead of write + read + write.

``apply_epilogue`` is the single implementation of the spec's semantics.
The Pallas kernel calls it on the accumulator *tile*; the XLA reference
path (``kernels.ref.matmul_fused_ref``) calls it on the full accumulator
matrix.  Because both run the same jnp ops in fp32, the two paths are
numerically identical by construction.

Application order (all math in fp32 — or the int32 accumulator is first
upcast when any step beyond the cast is requested):

    acc -> (* row/col scales) -> (+ bias) -> activation -> (+ residual)
        -> cast | rowwise/colwise-int8

The scale step is the int8 pipeline's dequantization (paper §IV-C1: int8
inputs accumulate in int32 and the scales are re-applied *on the way
out*): an int8 x int8 GEMM passes its activation rowwise scale
(``row_scale [M, 1]``) and weight columnwise scale (``col_scale [1, N]``)
so the int32 -> fp32 boundary happens exactly once, inside the store
phase — the quantized serving path never bounces through an fp32 HBM
tensor between GEMMs.

With ``quantize=True`` the epilogue emits ``(q int8, scale f32)`` as the
kernel's two outputs and ``out_dtype`` is ignored.  ``quantize_axis``
picks the scale granularity: ``'row'`` (scale ``[M, 1]``, one per
activation row — the layout the next layer's int8 GEMM consumes) or
``'col'`` (scale ``[1, N]``, one per output column — the weight /
weight-grad layout).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp

_ACTIVATIONS = ("none", "gelu", "silu", "relu")
_QUANT_AXES = ("row", "col")


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Static (hashable) description of a fused GEMM store phase.

    bias:       add a ``[N]`` bias row (operand supplied at call time).
    activation: 'none' | 'gelu' | 'silu' | 'relu', applied in fp32.
    residual:   add a ``[M, N]`` residual (operand supplied at call time).
    out_dtype:  storage dtype of the single output (None -> accumulator
                dtype).  Ignored when ``quantize`` is set.
    quantize:   symmetric int8 quantization; the GEMM emits ``(q, scale)``
                instead of one output.
    quantize_axis: 'row' (scale [M, 1], activation layout) or 'col'
                (scale [1, N], weight/weight-grad layout).
    """

    bias: bool = False
    activation: str = "none"
    residual: bool = False
    out_dtype: Optional[Any] = None
    quantize: bool = False
    quantize_axis: str = "row"

    def __post_init__(self):
        assert self.activation in _ACTIVATIONS, self.activation
        assert self.quantize_axis in _QUANT_AXES, self.quantize_axis

    @property
    def is_identity(self) -> bool:
        """True when the epilogue is nothing but the accumulator cast."""
        return not (self.bias or self.residual or self.quantize
                    or self.activation != "none")

    @property
    def n_outputs(self) -> int:
        return 2 if self.quantize else 1

    def out_itemsize(self, acc_dtype=jnp.float32) -> int:
        """Bytes per output element actually stored to HBM (the quantize
        scale vector is amortized over the other dim and ignored here)."""
        if self.quantize:
            return 1
        return jnp.dtype(self.out_dtype or acc_dtype).itemsize


def _activate(x: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "gelu":
        return jax.nn.gelu(x)
    if activation == "silu":
        return jax.nn.silu(x)
    if activation == "relu":
        return jax.nn.relu(x)
    return x


def quantize_symmetric(x: jnp.ndarray, axis: int
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 quantization along ``axis`` (the reduced axis):
    ``axis=-1`` gives per-row scales, ``axis=-2`` per-column scales."""
    absmax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = (jnp.maximum(absmax, 1e-12) / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def apply_epilogue(
    acc: jnp.ndarray,
    ep: Epilogue,
    bias: Optional[jnp.ndarray] = None,
    residual: Optional[jnp.ndarray] = None,
    row_scale: Optional[jnp.ndarray] = None,
    col_scale: Optional[jnp.ndarray] = None,
) -> Union[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Apply ``ep`` to an accumulator (tile or full matrix).

    ``acc`` is the 32-bit GEMM accumulator.  ``row_scale [M, 1]`` /
    ``col_scale [1, N]`` dequantize an int8 GEMM's int32 accumulator at
    the fp32 boundary (both broadcast over ``acc``).  ``bias`` broadcasts
    over rows (shape ``[N]`` or ``[1, N]``); ``residual`` matches ``acc``.
    Returns the cast output, or ``(q, scale)`` under ``quantize``.
    """
    scaled = row_scale is not None or col_scale is not None
    if ep.is_identity and not scaled:
        return acc.astype(ep.out_dtype) if ep.out_dtype else acc

    x = acc.astype(jnp.float32)
    if row_scale is not None:
        x = x * row_scale.astype(jnp.float32)
    if col_scale is not None:
        x = x * col_scale.astype(jnp.float32)
    if ep.bias:
        assert bias is not None, "Epilogue.bias set but no bias operand"
        b = bias.astype(jnp.float32)
        x = x + (b if b.ndim == x.ndim else b[None, :])
    x = _activate(x, ep.activation)
    if ep.residual:
        assert residual is not None, (
            "Epilogue.residual set but no residual operand")
        x = x + residual.astype(jnp.float32)

    if ep.quantize:
        return quantize_symmetric(
            x, axis=-1 if ep.quantize_axis == "row" else -2)

    # an int8 (scaled) accumulator that was dequantized defaults to fp32
    # output, never back to the int32 container dtype
    default = jnp.float32 if scaled else acc.dtype
    return x.astype(ep.out_dtype or default)
