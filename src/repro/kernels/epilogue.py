"""Declarative fused-GEMM epilogue spec, shared by every backend.

The paper keeps partial products out of slow memory by reducing them
on-array (the adder tree, §IV-B) and ping-pong buffering tiles in local
memory (§IV-C).  The TPU analogue of the remaining leak is the GEMM
*epilogue*: bias add, activation, residual add, output cast, and rowwise
int8 quantization were separate XLA ops, so every matmul wrote its fp32
accumulator to HBM and a second op read it back.  An ``Epilogue`` spec
lets the Pallas kernel apply all of them on the VMEM accumulator tile in
the store phase — one HBM write instead of write + read + write.

``apply_epilogue`` is the single implementation of the spec's semantics.
The Pallas kernel calls it on the accumulator *tile*; the XLA reference
path (``kernels.ref.matmul_fused_ref``) calls it on the full accumulator
matrix.  Because both run the same jnp ops at the same width, the two
paths are numerically identical by construction.  A 64-bit accumulator
(the consistency-budget oracles) keeps the epilogue math at f64 — the
spec is f64-capable without a separate reference implementation.

Application order (all math in fp32 — or the int32 accumulator is first
upcast when any step beyond the cast is requested):

    acc -> (* row/col scales) -> (+ bias) -> activation
        -> (* gate(operand2))  -> (+ residual)
        -> cast | rowwise/colwise-int8 | rmsnorm two-output

The scale step is the int8 pipeline's dequantization (paper §IV-C1: int8
inputs accumulate in int32 and the scales are re-applied *on the way
out*): an int8 x int8 GEMM passes its activation rowwise scale
(``row_scale [M, 1]``) and weight columnwise scale (``col_scale [1, N]``)
so the int32 -> fp32 boundary happens exactly once, inside the store
phase — the quantized serving path never bounces through an fp32 HBM
tensor between GEMMs.

Two-operand stages (the epilogue *algebra*, ROADMAP item 5):

``gate``    multiplies the accumulator by a second ``[M, N]`` tensor
            operand after the activation step: ``x = act_g(operand2) *
            x`` with ``act_g`` named by the field ('mul' is a raw
            multiply).  This is the gated MLP's ``silu(g) * u`` running
            on the up-GEMM's accumulator tile instead of a separate XLA
            op — and with ``quantize=True`` the gated path emits one
            fused ``(q, scale)`` for the down GEMM.

``norm``    'rmsnorm' turns the GEMM into a two-output op: the cast
            value (the residual stream) AND its rmsnorm with a ``[N]``
            scale operand + ``norm_eps`` — the *next* layer's input
            norm folded into the down-projection's store phase, saving
            a full residual-stream read+write per block.  The normed
            output is computed from the *cast* value (upcast back to
            the working width), so ``(value, normed)`` is bitwise
            identical to storing ``value`` and re-reading it through
            ``models.layers.rmsnorm`` — fusing never changes bits,
            it only deletes the HBM round trip.  ``norm`` needs the
            full output row, so it is illegal on N-sharded outputs and
            incompatible with ``quantize``.

With ``quantize=True`` the epilogue emits ``(q int8, scale f32)`` as the
kernel's two outputs and ``out_dtype`` is ignored.  ``quantize_axis``
picks the scale granularity: ``'row'`` (scale ``[M, 1]``, one per
activation row — the layout the next layer's int8 GEMM consumes) or
``'col'`` (scale ``[1, N]``, one per output column — the weight /
weight-grad layout).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp

_ACTIVATIONS = ("none", "gelu", "silu", "relu")
_GATES = ("none", "mul", "gelu", "silu", "relu")
_NORMS = ("none", "rmsnorm")
_QUANT_AXES = ("row", "col")

# named_scope marker on every op apply_epilogue emits: the HLO fusion
# audit (analysis/passes.py::fusion_scope_pass) tells fused-epilogue
# math from standalone ops by this scope in the op_name metadata
FUSED_SCOPE = "fused_epilogue"


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Static (hashable) description of a fused GEMM store phase.

    bias:       add a ``[N]`` bias row (operand supplied at call time).
    activation: 'none' | 'gelu' | 'silu' | 'relu', applied in fp32.
    gate:       'none' | 'mul' | 'gelu' | 'silu' | 'relu' — multiply by
                a second ``[M, N]`` tensor operand (supplied at call
                time), optionally passed through the named activation
                first: ``x = gate(operand2) * x``.
    residual:   add a ``[M, N]`` residual (operand supplied at call time).
    norm:       'none' | 'rmsnorm' — emit ``(value, rmsnorm(value))``
                as two outputs; the norm scale ``[N]`` is supplied at
                call time, ``norm_eps`` is static.
    norm_eps:   rmsnorm epsilon (must be > 0).
    out_dtype:  storage dtype of the value output (None -> accumulator
                dtype).  Ignored when ``quantize`` is set.
    quantize:   symmetric int8 quantization; the GEMM emits ``(q, scale)``
                instead of one output.  Incompatible with ``norm``.
    quantize_axis: 'row' (scale [M, 1], activation layout) or 'col'
                (scale [1, N], weight/weight-grad layout).
    """

    bias: bool = False
    activation: str = "none"
    gate: str = "none"
    residual: bool = False
    norm: str = "none"
    norm_eps: float = 1e-6
    out_dtype: Optional[Any] = None
    quantize: bool = False
    quantize_axis: str = "row"

    def __post_init__(self):
        # ValueError (not assert) so invalid specs fail under python -O
        # too — same convention as XYZConfig.__post_init__
        if self.activation not in _ACTIVATIONS:
            raise ValueError(
                f"Epilogue.activation must be one of {_ACTIVATIONS}, "
                f"got {self.activation!r}")
        if self.gate not in _GATES:
            raise ValueError(
                f"Epilogue.gate must be one of {_GATES}, "
                f"got {self.gate!r}")
        if self.norm not in _NORMS:
            raise ValueError(
                f"Epilogue.norm must be one of {_NORMS}, "
                f"got {self.norm!r}")
        if self.quantize_axis not in _QUANT_AXES:
            raise ValueError(
                f"Epilogue.quantize_axis must be one of {_QUANT_AXES}, "
                f"got {self.quantize_axis!r}")
        if self.quantize and self.norm != "none":
            raise ValueError(
                "Epilogue.quantize and Epilogue.norm are mutually "
                "exclusive: the normed output feeds a full-width GEMM "
                "input, quantize emits (q, scale)")
        if not self.norm_eps > 0:
            raise ValueError(
                f"Epilogue.norm_eps must be > 0, got {self.norm_eps!r}")

    @property
    def is_identity(self) -> bool:
        """True when the epilogue is nothing but the accumulator cast."""
        return not (self.bias or self.residual or self.quantize
                    or self.activation != "none"
                    or self.gate != "none" or self.norm != "none")

    @property
    def n_outputs(self) -> int:
        return 2 if (self.quantize or self.norm != "none") else 1

    def out_itemsize(self, acc_dtype=jnp.float32) -> int:
        """Bytes per output element actually stored to HBM (the quantize
        scale vector is amortized over the other dim and ignored here;
        a norm epilogue stores TWO [M, N] outputs of this itemsize)."""
        if self.quantize:
            return 1
        return jnp.dtype(self.out_dtype or acc_dtype).itemsize


def _activate(x: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "gelu":
        return jax.nn.gelu(x)
    if activation == "silu":
        return jax.nn.silu(x)
    if activation == "relu":
        return jax.nn.relu(x)
    return x


def quantize_symmetric(x: jnp.ndarray, axis: int
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 quantization along ``axis`` (the reduced axis):
    ``axis=-1`` gives per-row scales, ``axis=-2`` per-column scales."""
    absmax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = (jnp.maximum(absmax, 1e-12) / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale.astype(x.dtype)), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def apply_epilogue(
    acc: jnp.ndarray,
    ep: Epilogue,
    bias: Optional[jnp.ndarray] = None,
    residual: Optional[jnp.ndarray] = None,
    row_scale: Optional[jnp.ndarray] = None,
    col_scale: Optional[jnp.ndarray] = None,
    operand2: Optional[jnp.ndarray] = None,
    norm_scale: Optional[jnp.ndarray] = None,
    norm_n: Optional[int] = None,
) -> Union[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Apply ``ep`` to an accumulator (tile or full matrix).

    ``acc`` is the 32-bit (or, for the oracles, 64-bit) GEMM
    accumulator.  ``row_scale [M, 1]`` / ``col_scale [1, N]`` dequantize
    an int8 GEMM's int32 accumulator at the fp32 boundary (both
    broadcast over ``acc``).  ``bias`` broadcasts over rows (shape
    ``[N]`` or ``[1, N]``); ``residual`` and ``operand2`` match ``acc``;
    ``norm_scale`` broadcasts over rows like ``bias``.

    ``norm_n`` is the TRUE output-row length when ``acc`` is a
    zero-padded kernel tile: padded columns contribute exact +0.0 to the
    rmsnorm sum of squares, but the mean must divide by the real N, not
    the padded tile width.  ``None`` means the trailing dim is unpadded.

    Returns the cast output, ``(q, scale)`` under ``quantize``, or
    ``(value, normed)`` under ``norm='rmsnorm'``.
    """
    scaled = row_scale is not None or col_scale is not None
    if ep.is_identity and not scaled:
        return acc.astype(ep.out_dtype) if ep.out_dtype else acc

    # a 64-bit accumulator keeps the whole epilogue at f64 (the oracle
    # path); every production accumulator (f32 / int32) runs at f32 —
    # bitwise-unchanged from the single-width implementation
    wide = acc.dtype if acc.dtype == jnp.float64 else jnp.float32

    with jax.named_scope(FUSED_SCOPE):
        x = acc.astype(wide)
        if row_scale is not None:
            x = x * row_scale.astype(wide)
        if col_scale is not None:
            x = x * col_scale.astype(wide)
        if ep.bias:
            assert bias is not None, "Epilogue.bias set but no bias operand"
            b = bias.astype(wide)
            x = x + (b if b.ndim == x.ndim else b[None, :])
        x = _activate(x, ep.activation)
        if ep.gate != "none":
            assert operand2 is not None, (
                "Epilogue.gate set but no operand2")
            g = operand2.astype(wide)
            if ep.gate != "mul":
                g = _activate(g, ep.gate)
            x = g * x
        if ep.residual:
            assert residual is not None, (
                "Epilogue.residual set but no residual operand")
            x = x + residual.astype(wide)

        if ep.quantize:
            return quantize_symmetric(
                x, axis=-1 if ep.quantize_axis == "row" else -2)

        # an int8 (scaled) accumulator that was dequantized defaults to
        # fp32 output, never back to the int32 container dtype
        default = jnp.float32 if scaled else acc.dtype
        value = x.astype(ep.out_dtype or default)

        if ep.norm == "rmsnorm":
            assert norm_scale is not None, (
                "Epilogue.norm set but no norm_scale operand")
            # computed from the CAST value so (value, normed) is bitwise
            # what store-then-rmsnorm(value) would produce — the fusion
            # deletes the HBM round trip without changing a single bit.
            # The nested scope makes the site's op_name carry BOTH
            # markers ('.../fused_epilogue/rmsnorm/...'), which is how
            # analysis.passes.fusion_scope_pass tells a fused norm from
            # a standalone models.layers.rmsnorm.
            with jax.named_scope("rmsnorm"):
                n = norm_n if norm_n is not None else x.shape[-1]
                nf = value.astype(wide)
                ms = jnp.sum(nf * nf, axis=-1, keepdims=True) / n
                s = norm_scale.astype(wide)
                s = s if s.ndim == nf.ndim else s[None, :]
                normed = (nf * jax.lax.rsqrt(ms + ep.norm_eps)
                          * (1.0 + s)).astype(value.dtype)
            return value, normed

        return value
