# Pallas compute hot-spots the paper optimizes: the MatMul kernel itself
# (§IV-C1), the adder-tree Add kernel (§IV-B), and the int8 quantizer
# feeding the paper's int8 pipeline.
from repro.kernels.epilogue import Epilogue, apply_epilogue
from repro.kernels.ops import (
    addertree,
    dequantize_rowwise,
    kernel_mode,
    matmul,
    quantize_rowwise,
    set_kernel_mode,
)

__all__ = [
    "Epilogue",
    "apply_epilogue",
    "matmul",
    "addertree",
    "quantize_rowwise",
    "dequantize_rowwise",
    "set_kernel_mode",
    "kernel_mode",
]
