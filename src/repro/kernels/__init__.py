# Pallas compute hot-spots the paper optimizes: the MatMul kernel itself
# (§IV-C1), the adder-tree Add kernel (§IV-B), and the int8 quantizer
# feeding the paper's int8 pipeline (rowwise activations, columnwise
# weights, scales re-applied in the fused epilogue).
from repro.kernels.epilogue import Epilogue, apply_epilogue
from repro.kernels.ops import (
    addertree,
    dequantize_rowwise,
    int8_matmul,
    kernel_mode,
    matmul,
    quantize_colwise,
    quantize_rowwise,
    set_kernel_mode,
)
from repro.kernels.quantize import QuantizedWeight, quantize_weight_colwise

__all__ = [
    "Epilogue",
    "apply_epilogue",
    "matmul",
    "int8_matmul",
    "addertree",
    "quantize_rowwise",
    "quantize_colwise",
    "quantize_weight_colwise",
    "QuantizedWeight",
    "dequantize_rowwise",
    "set_kernel_mode",
    "kernel_mode",
]
