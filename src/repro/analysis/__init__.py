"""Graph-based static analysis of traced HLO — the contract auditor.

``hlo_graph``  — typed parser: instructions with operands / def-use
                 edges, computations, module-level input/output aliasing,
                 hardened while-loop trip-count extraction.
``passes``     — the pass framework (``Finding``, ``run_passes``) and the
                 four production passes:
                   * collective-schedule checker (permutation validity,
                     bidir-ring inverse rotations, barrier collectives on
                     overlapped paths),
                   * dtype-flow taint (int8 dequant bounces, f64 leaks,
                     silent upcasts),
                   * donation / aliasing audit (donated buffers actually
                     aliased; full-tensor copies flagged),
                   * dispatch counts (GEMM dispatch sites, apply-time
                     weight concats).
``contract``   — ``HloContract`` (a registered production trace plus its
                 expectations), the production-trace registry, and the
                 committed-baseline diff (``HLO_CONTRACTS.json``,
                 bench-gate style: violations always fail, unexplained
                 structural drift fails CI).

``launch/hlo_analysis.py`` keeps its historical guard API
(``gemm_dispatches`` / ``weight_concat_count`` / ``int8_bounce_count``)
as thin shims over these passes; ``launch/audit.py`` is the CLI.
"""
from repro.analysis.hlo_graph import HloModule, parse_hlo
from repro.analysis.passes import (
    Finding,
    PASSES,
    collective_schedule_pass,
    dispatch_count_pass,
    donation_pass,
    dtype_flow_pass,
    run_passes,
)
from repro.analysis.contract import (
    HloContract,
    TraceReport,
    diff_baseline,
    production_contracts,
    run_contract,
)

__all__ = [
    "HloModule", "parse_hlo",
    "Finding", "PASSES", "run_passes",
    "collective_schedule_pass", "dtype_flow_pass", "donation_pass",
    "dispatch_count_pass",
    "HloContract", "TraceReport", "run_contract", "diff_baseline",
    "production_contracts",
]
