"""HLO contracts: every production trace, registered with its structural
expectations and diffed against a committed baseline.

An ``HloContract`` names one production program (the train step, the
serving engine's decode step, one collective-matmul schedule cell...),
knows how to trace it ABSTRACTLY (``jax.ShapeDtypeStruct`` lowering — no
real weights, so the full registry audits in seconds), and declares the
expectations the analysis passes enforce on the compiled module.

``run_contract`` traces + parses + runs the passes; ``diff_baseline``
compares the resulting reports against ``HLO_CONTRACTS.json`` exactly
the way ``scripts/bench_gate.py`` gates timings against
``BENCH_baseline.json``:

  * ``error`` findings are contract VIOLATIONS — they fail regardless of
    the baseline (a violated invariant is never "explained" by drift);
  * metric or warning-signature changes vs the committed baseline are
    structural DRIFT — they fail CI until a human re-seeds the baseline
    with ``launch/audit.py --update-baseline`` (and the diff shows up in
    review, which is the point);
  * a contract that disappeared, or skipped for lack of devices when the
    caller didn't allow it, is a coverage regression and fails.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.hlo_graph import parse_hlo
from repro.analysis.passes import Finding, run_passes

BASELINE_NAME = "HLO_CONTRACTS.json"


@dataclasses.dataclass
class HloContract:
    """One registered production trace.

    ``trace`` returns the OPTIMIZED HLO text (``.lower(...).compile()
    .as_text()`` — donation and fusion decisions only exist post-
    optimization).  ``expect`` is the pass expectation dict (see
    ``repro.analysis.passes``).  ``extra_checks`` run after the passes
    and contribute findings (e.g. the guard-invariance digest compare).
    """
    name: str
    description: str
    trace: Callable[[], str]
    expect: Dict[str, Any] = dataclasses.field(default_factory=dict)
    requires_devices: int = 1
    extra_checks: Tuple[Callable[[], List[Finding]], ...] = ()


@dataclasses.dataclass
class TraceReport:
    contract: str
    findings: List[Finding]
    metrics: Dict[str, Any]
    skipped: str = ""          # non-empty reason => not traced

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def finding_signature(self) -> Dict[str, int]:
        """Baseline-diff key: finding occurrence counts by
        severity:pass/code (locations stay out — instruction names churn
        with XLA versions, structure shouldn't)."""
        sig: Dict[str, int] = {}
        for f in self.findings:
            key = f"{f.severity}:{f.pass_name}/{f.code}"
            sig[key] = sig.get(key, 0) + 1
        return sig

    def format(self) -> str:
        lines = [f"== {self.contract} =="]
        if self.skipped:
            lines.append(f"   SKIPPED: {self.skipped}")
            return "\n".join(lines)
        for k in sorted(self.metrics):
            lines.append(f"   {k} = {self.metrics[k]}")
        for f in self.findings:
            lines.append(f"   {f.format()}")
        if not self.findings:
            lines.append("   no findings")
        return "\n".join(lines)


def run_contract(contract: HloContract) -> TraceReport:
    import jax
    n = len(jax.devices())
    if n < contract.requires_devices:
        return TraceReport(
            contract.name, [], {},
            skipped=f"needs {contract.requires_devices} devices, "
                    f"have {n} (set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count="
                    f"{contract.requires_devices})")
    module = parse_hlo(contract.trace())
    findings, metrics = run_passes(module, contract.expect)
    for check in contract.extra_checks:
        findings.extend(check())
    return TraceReport(contract.name, findings, metrics)


# ---------------------------------------------------------------------------
# baseline diff (pure, unit-tested — mirrors bench_gate.compare)
# ---------------------------------------------------------------------------

def to_baseline(reports: Sequence[TraceReport]) -> Dict[str, Any]:
    """The committed-baseline payload for these reports (skipped
    contracts are omitted — seed the baseline on a host with enough
    devices, i.e. through ``launch/audit.py`` which forces 8)."""
    return {"contracts": {
        r.contract: {"metrics": r.metrics,
                     "findings": r.finding_signature()}
        for r in reports if not r.skipped}}


def diff_baseline(reports: Sequence[TraceReport],
                  baseline: Optional[Dict[str, Any]],
                  allow_device_skips: bool = False
                  ) -> Tuple[List[str], List[str]]:
    """Returns (failures, report lines).  ``baseline=None`` means no
    committed file: violations still fail, drift can't be judged."""
    failures: List[str] = []
    lines: List[str] = []
    base = (baseline or {}).get("contracts", {})

    for r in reports:
        if r.skipped:
            if allow_device_skips:
                lines.append(f"skip {r.contract}: {r.skipped}")
            else:
                failures.append(
                    f"SKIPPED contract {r.contract} ({r.skipped}) — "
                    f"coverage regression; rerun with enough devices or "
                    f"pass --allow-device-skips for a local spot check")
                lines.append(f"FAIL {r.contract}: skipped")
            continue
        for f in r.errors:
            failures.append(f"VIOLATION {r.contract}: {f.format()}")
        if baseline is None:
            lines.append(f"new  {r.contract}: no baseline to diff")
            continue
        if r.contract not in base:
            failures.append(
                f"NEW contract {r.contract} has no committed baseline — "
                f"reseed with --update-baseline")
            lines.append(f"FAIL {r.contract}: not in baseline")
            continue
        entry = base[r.contract]
        drift: List[str] = []
        bm = entry.get("metrics", {})
        for k in sorted(set(bm) | set(r.metrics)):
            if bm.get(k) != r.metrics.get(k):
                drift.append(f"{k}: {bm.get(k)!r} -> {r.metrics.get(k)!r}")
        bf = entry.get("findings", {})
        sig = r.finding_signature()
        for k in sorted(set(bf) | set(sig)):
            if bf.get(k, 0) != sig.get(k, 0):
                drift.append(f"finding {k}: x{bf.get(k, 0)} -> "
                             f"x{sig.get(k, 0)}")
        if drift:
            failures.append(
                f"DRIFT {r.contract}: " + "; ".join(drift)
                + " — structural change; if intended, reseed with "
                  "--update-baseline")
            lines.append(f"FAIL {r.contract}: structural drift "
                         f"({len(drift)} fields)")
        else:
            status = "ok  " if not r.errors else "FAIL"
            lines.append(f"{status} {r.contract}: matches baseline "
                         f"({len(r.metrics)} metrics, "
                         f"{len(r.findings)} findings)")

    traced = {r.contract for r in reports if not r.skipped}
    skipped = {r.contract for r in reports if r.skipped}
    for name in sorted(set(base) - traced - skipped):
        failures.append(f"MISSING contract {name} (present in baseline) "
                        f"— a silently dropped trace is a coverage "
                        f"regression")
        lines.append(f"FAIL {name}: missing from this run")
    return failures, lines


# ---------------------------------------------------------------------------
# the production registry
# ---------------------------------------------------------------------------

# NEW is sized so the decode-path KV view spans several flash tiles
# (256 positions = 8 tiles of 32) AND one pool's logical view
# (b * max_len * n_kv * hd = 16384 elems) sits far above every
# legitimate program-requested widening convert — the flash paths'
# per-tile converts top out at 2048 elems, the chunk-prefill logits
# upcast at 8192 — which is the gap that gives the big-upcast audit
# teeth: an einsum path that fp32-materializes a whole cache/pool per
# step converts >= 16384 elems in one op and trips it
_SMOKE_B, _SMOKE_S, _SMOKE_NEW = 2, 16, 240


def _smoke_cfg():
    import dataclasses as dc
    from repro.configs import get_config
    # d_ff=96 keeps the packed-QKV width unique in the module (the smoke
    # config's d_ff collides with q_dim + 2*kv_dim — the same move
    # tests/test_int8_serving.py makes)
    return dc.replace(get_config("internlm2-1.8b", smoke=True), d_ff=96)


def production_contracts() -> List[HloContract]:
    """Every traced production path, with its declared expectations.

    Model/mesh construction happens lazily inside each ``trace`` closure
    (first jax touch is deferred to ``run_contract`` time); only
    shape/leaf-count bookkeeping runs here.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config  # noqa: F401  (doc pointer)
    from repro.core.maxeva_matmul import (XYZConfig, schedule_wire_ops,
                                          xyz_weight_shape)

    cfg = _smoke_cfg()
    packed = cfg.q_dim + 2 * cfg.kv_dim
    assert packed not in (cfg.d_model, cfg.d_ff, cfg.padded_vocab())
    b, s, new = _SMOKE_B, _SMOKE_S, _SMOKE_NEW
    max_len = s + new

    def _model():
        from repro.launch.mesh import make_mesh
        from repro.models.lm import Model
        return Model(cfg, make_mesh(1, 1))

    # expectations shared by every single-device production path: the
    # trace must be f64-free (the f64 consistency REFERENCE never leaks
    # into production programs) and collective-free (model axis of 1)
    single_dev = {"forbid_f64": True, "allowed_collectives": ()}

    # one K (or V) pool's full logical view in elements: any float
    # widening convert this big in a decode-path trace means a whole
    # cache/pool was materialized at fp32 in one step — the bug the
    # flash kernels' per-tile converts exist to kill.  The audit runs on
    # the PRE-optimization module (``lowered.as_text('hlo')``): the CPU
    # backend's dot legalization inserts (and hoists) its own full-array
    # converts post-optimization, so only the unoptimized module shows
    # which converts the program asked for.
    pool_view_elems = b * max_len * cfg.n_kv_heads * cfg.head_dim

    def no_big_upcast(trace_unopt: Callable[[], str],
                      limit: int = pool_view_elems
                      ) -> Callable[[], List[Finding]]:
        def check() -> List[Finding]:
            from repro.analysis.passes import dtype_flow_pass
            module = parse_hlo(trace_unopt())
            found, _ = dtype_flow_pass(
                module, {"forbid_big_upcast_elems": limit})
            return [f for f in found if f.code == "full-pool-upcast"]
        return check

    def trace_train():
        from repro.optim import AdamWConfig, abstract_opt_state
        from repro.train.step import jit_train_step
        model = _model()
        opt_cfg = AdamWConfig()
        aparams = model.abstract_params()
        aopt = abstract_opt_state(aparams, opt_cfg)
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 "targets": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        step = jit_train_step(model, opt_cfg, donate=True)
        return step.lower(aparams, aopt, batch).compile().as_text()

    def train_donated() -> Tuple[int, ...]:
        from repro.optim import AdamWConfig, abstract_opt_state
        model = _model()
        aparams = model.abstract_params()
        aopt = abstract_opt_state(aparams, AdamWConfig())
        n = (len(jax.tree_util.tree_leaves(aparams))
             + len(jax.tree_util.tree_leaves(aopt)))
        return tuple(range(n))

    def trace_prefill(int8: bool):
        def tr():
            model = _model()
            aparams = model.abstract_params()
            if int8:
                aparams = jax.eval_shape(
                    model.quantize_params_for_serving, aparams)
            abatch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
            fn = jax.jit(lambda p, bb: model.prefill(p, bb,
                                                     max_len=max_len))
            return fn.lower(aparams, abatch).compile().as_text()
        return tr

    def serve_cfg(**kw):
        from repro.serve.engine import ServeConfig
        return ServeConfig(max_new_tokens=new, **kw)

    def trace_decode(scfg_kw: Dict[str, Any], unopt: bool = False):
        def tr():
            from repro.serve.engine import ServeEngine
            lowered, _ = ServeEngine.decode_step_lowered(
                _model(), serve_cfg(**scfg_kw), b, s)
            if unopt:
                return lowered.as_text(dialect="hlo")
            return lowered.compile().as_text()
        return tr

    def decode_donated(int8: bool) -> Tuple[int, ...]:
        # the donated cache leaves' parameter numbers sit AFTER the param
        # leaves — and the quantized tree has more leaves than the fp one
        # (each projection weight flattens to q + scale), so the numbers
        # are computed per serving mode
        model = _model()
        aparams = model.abstract_params()
        if int8:
            aparams = jax.eval_shape(model.quantize_params_for_serving,
                                     aparams)
        n_p = len(jax.tree_util.tree_leaves(aparams))
        n_c = len(jax.tree_util.tree_leaves(
            model.abstract_cache(b, max_len)))
        return tuple(range(n_p, n_p + n_c))

    donated_cache = decode_donated(int8=False)

    def guard_invariance() -> List[Finding]:
        """Health guards must never alter the traced decode step: the
        engine-built decode program with guards on and with guards off
        must be byte-identical (the serve_guard_overhead bench asserts
        this dynamically; the auditor pins it structurally)."""
        from repro.serve.engine import ServeEngine
        model = _model()
        on, _ = ServeEngine.decode_step_lowered(
            model, serve_cfg(), b, s)
        off, _ = ServeEngine.decode_step_lowered(
            model, serve_cfg(guards=False, on_nonfinite="off"), b, s)
        if on.compile().as_text() != off.compile().as_text():
            return [Finding(
                "contract", "guards-changed-decode-hlo", "error",
                "decode_guarded",
                "decode-step HLO differs with guards on vs off — the "
                "guards contract requires the traced step to be "
                "byte-identical")]
        return []

    # v2 epilogue-fold expectations (fusion_scope_pass): every block's
    # residual add + NEXT input norm rides the MLP down projection's
    # fused epilogue, so the only standalone rmsnorms left in a traced
    # forward are the ENTRY norm (1) plus each traced block body's
    # pre-MLP ln2 — bodies = the scanned pattern (traced once) + the
    # unrolled tail.  The gated MLP's silu(g) * u must never appear as a
    # tagged standalone multiply.  On int8 paths the only standalone
    # rowwise quantizes are the per-body INPUT quantizes (packed-QKV in,
    # o-projection in, MLP in = 3): the up GEMM hands the down GEMM its
    # (q, scale) pair straight from the store phase.
    n_bodies = (len(cfg.block_pattern) if cfg.n_groups > 0 else 0) \
        + len(cfg.tail_blocks)
    fused_mlp = dict(expect_standalone_rmsnorm=1 + n_bodies,
                     forbid_unfused_gate_mul=True)
    int8_fused_mlp = dict(fused_mlp,
                          expect_standalone_quantize=3 * n_bodies)

    decode_expect = dict(single_dev, gemm_out_cols=packed,
                         expect_gemm_dispatches=1,
                         d_model=cfg.d_model, expect_weight_concats=0,
                         donated_params=donated_cache, **fused_mlp)

    # -- paged serving (PR 8): scheduler decode + chunked prefill ----------
    lanes, page = b, 16
    ppl = -(-max_len // page)          # pages per lane for prompt+new
    chunk = 16

    def paged_donated(int8: bool) -> Tuple[int, ...]:
        model = _model()
        aparams = model.abstract_params()
        if int8:
            aparams = jax.eval_shape(model.quantize_params_for_serving,
                                     aparams)
        n_p = len(jax.tree_util.tree_leaves(aparams))
        n_c = len(jax.tree_util.tree_leaves(
            model.abstract_paged_cache(lanes * ppl, page)))
        return tuple(range(n_p, n_p + n_c))

    def trace_paged_decode(scfg_kw: Dict[str, Any], unopt: bool = False):
        def tr():
            from repro.serve.engine import ServeEngine
            lowered, _ = ServeEngine.paged_decode_lowered(
                _model(), serve_cfg(**scfg_kw), lanes, ppl, page)
            if unopt:
                return lowered.as_text(dialect="hlo")
            return lowered.compile().as_text()
        return tr

    def trace_prefill_chunk(scfg_kw: Dict[str, Any], unopt: bool = False):
        def tr():
            from repro.serve.engine import ServeEngine
            lowered, _ = ServeEngine.prefill_chunk_lowered(
                _model(), serve_cfg(**scfg_kw), lanes, chunk, ppl, page)
            if unopt:
                return lowered.as_text(dialect="hlo")
            return lowered.compile().as_text()
        return tr

    def paged_guard_invariance() -> List[Finding]:
        """Stronger than the dense-decode variant: the paged decode step
        never even SEES the guard config (guards live in the fused pick),
        so the guarded and unguarded programs must be byte-identical."""
        from repro.serve.engine import ServeEngine
        model = _model()
        on, _ = ServeEngine.paged_decode_lowered(
            model, serve_cfg(), lanes, ppl, page)
        off, _ = ServeEngine.paged_decode_lowered(
            model, serve_cfg(guards=False, on_nonfinite="off"),
            lanes, ppl, page)
        if on.compile().as_text() != off.compile().as_text():
            return [Finding(
                "contract", "guards-changed-paged-decode-hlo", "error",
                "decode_paged_guarded",
                "paged decode-step HLO differs with guards on vs off — "
                "the scheduler's guards ride in the pick dispatch and "
                "must never reshape the decode program")]
        return []

    paged_decode_expect = dict(single_dev, gemm_out_cols=packed,
                               expect_gemm_dispatches=1,
                               d_model=cfg.d_model,
                               expect_weight_concats=0,
                               donated_params=paged_donated(int8=False),
                               **fused_mlp)

    contracts = [
        HloContract(
            "train_step",
            "jit_train_step on the smoke config: fwd+bwd+AdamW, params "
            "and opt state donated",
            trace_train,
            expect=dict(single_dev, d_model=cfg.d_model,
                        expect_weight_concats=0,
                        donated_params=train_donated())),
        HloContract(
            "prefill_fp32",
            "serving prefill (fp32 weights), decode headroom reserved",
            trace_prefill(int8=False),
            expect=dict(single_dev, gemm_out_cols=packed,
                        d_model=cfg.d_model, expect_weight_concats=0,
                        **fused_mlp)),
        HloContract(
            "decode_fp32",
            "engine decode step, fp32, guards off, KV cache donated; "
            "no full-cache fp32 upcast in the program",
            trace_decode(dict(guards=False, on_nonfinite="off")),
            expect=decode_expect,
            extra_checks=(no_big_upcast(trace_decode(
                dict(guards=False, on_nonfinite="off"), unopt=True)),)),
        HloContract(
            "decode_guarded",
            "engine decode step under the production guarded config — "
            "must be byte-identical to decode_fp32",
            trace_decode({}),
            expect=decode_expect,
            extra_checks=(guard_invariance,)),
        HloContract(
            "prefill_int8",
            "serving prefill on one-shot-quantized weights: zero fp32 "
            "dequant bounces, fused (q, scale) GEMM->GEMM handoffs",
            trace_prefill(int8=True),
            expect=dict(single_dev, int8_clean=True,
                        gemm_out_cols=packed, d_model=cfg.d_model,
                        expect_weight_concats=0, **int8_fused_mlp)),
        HloContract(
            "decode_int8",
            "engine int8 decode step: zero bounces, single packed-QKV "
            "dispatch, KV cache donated",
            trace_decode(dict(int8=True)),
            expect=dict(decode_expect, int8_clean=True,
                        donated_params=decode_donated(int8=True),
                        **int8_fused_mlp),
            extra_checks=(no_big_upcast(trace_decode(
                dict(int8=True), unopt=True)),)),
        HloContract(
            "decode_paged_fp32",
            "scheduler paged decode step, fp32: page pools donated, "
            "single packed-QKV dispatch; no full-pool fp32 upcast in "
            "the program",
            trace_paged_decode(dict(guards=False, on_nonfinite="off")),
            expect=paged_decode_expect,
            extra_checks=(no_big_upcast(trace_paged_decode(
                dict(guards=False, on_nonfinite="off"), unopt=True)),)),
        HloContract(
            "decode_paged_guarded",
            "scheduler paged decode step under the production guarded "
            "config — must be byte-identical to decode_paged_fp32",
            trace_paged_decode({}),
            expect=paged_decode_expect,
            extra_checks=(paged_guard_invariance,)),
        HloContract(
            "decode_paged_int8",
            "scheduler int8 paged decode step: zero fp32 dequant "
            "bounces, page pools donated",
            trace_paged_decode(dict(int8=True)),
            expect=dict(paged_decode_expect, int8_clean=True,
                        donated_params=paged_donated(int8=True),
                        **int8_fused_mlp),
            extra_checks=(no_big_upcast(trace_paged_decode(
                dict(int8=True), unopt=True)),)),
        HloContract(
            "prefill_chunk_fp32",
            "scheduler chunked-prefill step (all lanes, fixed chunk): "
            "page pools donated; no full-pool fp32 upcast in the "
            "program",
            trace_prefill_chunk({}),
            expect=dict(single_dev, gemm_out_cols=packed,
                        d_model=cfg.d_model, expect_weight_concats=0,
                        **fused_mlp),
            extra_checks=(no_big_upcast(
                trace_prefill_chunk({}, unopt=True)),)),
        HloContract(
            "prefill_chunk_int8",
            "scheduler int8 chunked-prefill step: zero fp32 dequant "
            "bounces",
            trace_prefill_chunk(dict(int8=True)),
            expect=dict(single_dev, int8_clean=True,
                        gemm_out_cols=packed, d_model=cfg.d_model,
                        expect_weight_concats=0, **int8_fused_mlp),
            extra_checks=(no_big_upcast(
                trace_prefill_chunk(dict(int8=True), unopt=True)),)),
    ]

    # -- collective-matmul schedule cells (8 fake devices, mesh 2x4) -------
    xb, xs, xk, xn = 4, 8, 32, 64
    model_axis = 4

    def trace_xyz(xcfg: XYZConfig):
        def tr():
            from repro.core.maxeva_matmul import xyz_matmul
            from repro.launch.mesh import make_mesh
            mesh = make_mesh(2, model_axis)
            x = jax.ShapeDtypeStruct((xb, xs, xk), jnp.float32)
            w = jax.ShapeDtypeStruct(
                xyz_weight_shape(xk, xn, model_axis, xcfg.y), jnp.float32)
            fn = jax.jit(lambda xa, wa: xyz_matmul(xa, wa, mesh=mesh,
                                                   cfg=xcfg))
            return fn.lower(x, w).compile().as_text()
        return tr

    for sched in ("allreduce", "reduce_scatter", "ring", "bidir_ring"):
        # ksharded Y=2 Z=2: the overlapped-gather path — NO barrier
        # all-gather allowed on any of the four schedules (the ROADMAP
        # invariant the auditor now owns)
        xcfg = XYZConfig(y=2, schedule=sched, x_layout="ksharded")
        allowed = schedule_wire_ops(xcfg, model_axis)
        assert "all-gather" not in allowed
        contracts.append(HloContract(
            f"xyz_{sched}_ksharded_y2",
            f"collective matmul, schedule={sched}, ksharded X, Y=2 Z=2 "
            f"on mesh(2,4): overlapped ppermute gather, no barrier "
            f"all-gather",
            trace_xyz(xcfg),
            expect={"allowed_collectives": allowed,
                    "forbid_f64": True,
                    "require_inverse_permutes": sched == "bidir_ring"},
            requires_devices=8))

    # bidir_ring at Y=4 (full model axis): rotations +/-s are distinct
    # maps, so the inverse-rotation pairing check has teeth
    xcfg4 = XYZConfig(y=4, schedule="bidir_ring", x_layout="replicated")
    contracts.append(HloContract(
        "xyz_bidir_ring_replicated_y4",
        "bidir_ring at Y=4: opposite-rotation ppermute sets must be "
        "exact inverses",
        trace_xyz(xcfg4),
        expect={"allowed_collectives": schedule_wire_ops(xcfg4,
                                                         model_axis),
                "forbid_f64": True,
                "require_inverse_permutes": True},
        requires_devices=8))

    return contracts
