"""Analysis passes over the typed HLO graph.

Each pass walks an ``HloModule`` under a per-contract expectation dict
and returns ``(findings, metrics)``:

  * findings — structural defects.  ``severity='error'`` findings fail
    the auditor unconditionally (a violated contract); ``'warning'``
    findings are reported and baseline-diffed but don't fail on their
    own.
  * metrics — deterministic structural counts (dispatch sites, bounce
    counts, per-collective op counts, aliased-buffer counts) that the
    auditor diffs against the committed ``HLO_CONTRACTS.json`` baseline:
    unexplained drift fails CI even when no expectation is violated,
    exactly like the bench gate's committed medians.

Expectation keys (all optional — a pass only enforces what the contract
declares):

  allowed_collectives   tuple of collective op names the planner priced
                        on this path; any OTHER collective is a barrier
                        the overlap model never saw -> error
  require_inverse_permutes  every rotation ppermute set must have its
                        exact inverse in the module (the bidir_ring
                        opposite-rotation contract) -> error if missing
  int8_clean            s8->float dequants reaching a dot are errors
                        (else info findings + a metric)
  forbid_f64            any f64-typed instruction or float upcast to f64
                        is an error (fp32-path contracts)
  forbid_big_upcast_elems  float widening converts whose operand holds
                        >= this many elements are errors (the decode
                        contract that no whole KV cache/page pool is
                        materialized at fp32 per step)
  donated_params        parameter numbers that MUST be aliased into the
                        output (donate_argnums buffers) -> error if not
  gemm_out_cols         result-column width identifying the audited GEMM
  expect_gemm_dispatches  exact dot-site count at gemm_out_cols
  d_model               weight K dimension for the concat detector
  expect_weight_concats   exact apply-time weight-concat count
  expect_standalone_rmsnorm  exact count of rmsnorm sites NOT riding a
                        fused GEMM epilogue (named_scope anchored)
  forbid_unfused_gate_mul  any 'gate_mul_unfused'-tagged multiply in the
                        module -> error (fused gated-MLP contracts)
  expect_standalone_quantize  exact count of standalone rowwise
                        activation quantizes (int8 handoff contracts)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.analysis.hlo_graph import (
    FLOAT_DTYPES,
    DTYPE_BYTES,
    HloModule,
    Instruction,
    normalize_shape,
    shape_info,
)

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute", "ragged-all-to-all")
# collectives with a synchronization barrier: every participant must
# arrive before any data moves (ppermute hops are point-to-point)
BARRIER_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all")


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_name: str
    code: str          # stable slug, the baseline-diff key
    severity: str      # 'error' | 'warning' | 'info'
    where: str         # 'computation/instruction'
    message: str

    def format(self) -> str:
        return (f"[{self.severity.upper():7s}] {self.pass_name}"
                f"/{self.code} at {self.where}: {self.message}")


def _collective_base(ins: Instruction) -> Optional[str]:
    """Base collective op name, folding async -start/-done forms (the
    -done half is skipped: one logical collective, one count)."""
    op = ins.op
    if op.endswith("-done"):
        return None
    if op.endswith("-start"):
        op = op[:-6]
    return op if op in COLLECTIVE_OPS else None


# ---------------------------------------------------------------------------
# pass 1: collective-schedule checker
# ---------------------------------------------------------------------------

def collective_schedule_pass(module: HloModule, expect: Dict[str, Any]
                             ) -> Tuple[List[Finding], Dict[str, Any]]:
    """The repo's analog of a race detector for the wire schedule.

    * every ``collective-permute`` source-target list must be a valid
      permutation (unique sources, unique targets, equal participant
      sets) — a malformed rotation deadlocks or drops a contribution;
    * under ``require_inverse_permutes`` (the bidir_ring contract) every
      rotation set must have its exact inverse map present — the two
      opposite-direction ppermute sets of ``_bidir_ring_collective_matmul``;
    * under ``allowed_collectives`` any other collective is a barrier on
      a path the planner priced as overlapped (``est_step_s`` hides
      ring-family wire time under chunk GEMMs; a barrier all-gather
      serializes it) -> error.
    """
    findings: List[Finding] = []
    counts: Dict[str, int] = {}
    permute_maps: List[Dict[int, int]] = []
    permute_sites: List[str] = []
    allowed = expect.get("allowed_collectives")

    for cname, ins in module.instructions():
        base = _collective_base(ins)
        if base is None:
            continue
        where = f"{cname}/{ins.name}"
        counts[base] = counts.get(base, 0) + 1
        if base == "collective-permute":
            pairs = ins.source_target_pairs or []
            srcs = [a for a, _ in pairs]
            tgts = [b for _, b in pairs]
            if len(set(srcs)) != len(srcs) or len(set(tgts)) != len(tgts) \
                    or set(srcs) != set(tgts):
                findings.append(Finding(
                    "collective-schedule", "invalid-permutation", "error",
                    where,
                    f"source_target_pairs {pairs} is not a permutation "
                    f"(duplicate or mismatched endpoints)"))
            else:
                permute_maps.append(dict(pairs))
                permute_sites.append(where)
        if allowed is not None and base not in allowed:
            sev = "error" if base in BARRIER_OPS else "warning"
            findings.append(Finding(
                "collective-schedule", f"barrier-{base}", sev, where,
                f"{base} of {ins.shape} on a path the planner priced as "
                f"overlapped (allowed: {tuple(allowed)})"))

    inverse_paired = 0
    if permute_maps:
        inv_index = {tuple(sorted((t, s) for s, t in m.items()))
                     for m in permute_maps}
        for m, where in zip(permute_maps, permute_sites):
            key = tuple(sorted(m.items()))
            if key in inv_index:
                inverse_paired += 1
            elif expect.get("require_inverse_permutes"):
                findings.append(Finding(
                    "collective-schedule", "missing-inverse-rotation",
                    "error", where,
                    f"rotation {dict(m)} has no exact-inverse partner "
                    f"(bidir_ring ships each half-chunk on opposite "
                    f"rotation sets)"))

    metrics = {"collective_ops": counts,
               "n_permutes": len(permute_maps),
               "inverse_paired_permutes": inverse_paired}
    return findings, metrics


# ---------------------------------------------------------------------------
# pass 2: dtype-flow taint
# ---------------------------------------------------------------------------

def _taint_dequants(module: HloModule) -> Set[Tuple[str, str]]:
    """Forward taint propagation: seed at every s8 -> float ``convert``
    (a dequantization), flow through def-use edges, across call sites
    into callee parameters, and from dirty callees back to call-site
    results.  Returns the set of (computation, dot name) sites consuming
    a tainted operand — the fp32 bounces.

    Conservative across calls (any tainted operand taints every callee
    parameter; any tainted callee taints the call result), which can
    only over-count — safe for a zero-bounce gate.  This is the fixpoint
    the legacy ``int8_bounce_count`` ran over raw regex tables, now on
    the typed graph.
    """
    comps = module.computations
    tainted: Dict[str, Set[str]] = {c: set() for c in comps}
    comp_dirty: Dict[str, bool] = {c: False for c in comps}
    bounces: Set[Tuple[str, str]] = set()

    changed = True
    while changed:
        changed = False
        for cname, comp in comps.items():
            for ins in comp.instructions:
                hit = ins.name in tainted[cname]
                if not hit:
                    if ins.op == "convert" and ins.dtype in FLOAT_DTYPES \
                            and ins.operands:
                        opshape = comp.shape_of(ins.operands[0])
                        if opshape is not None and \
                                opshape.lstrip("%").startswith("s8["):
                            hit = True
                    if not hit and any(o in tainted[cname]
                                       for o in ins.operands):
                        hit = True
                    if not hit and any(comp_dirty.get(sub)
                                       for sub in ins.called):
                        hit = True
                    if hit:
                        tainted[cname].add(ins.name)
                        comp_dirty[cname] = True
                        changed = True
                # tainted operands taint every parameter of the callee
                if any(o in tainted[cname] for o in ins.operands):
                    for sub in ins.called:
                        callee = comps.get(sub)
                        if callee is None:
                            continue
                        for p in callee.params.values():
                            if p.name not in tainted[sub]:
                                tainted[sub].add(p.name)
                                comp_dirty[sub] = True
                                changed = True
                if ins.op == "dot" and any(o in tainted[cname]
                                           for o in ins.operands):
                    bounces.add((cname, ins.name))
    return bounces


def dtype_flow_pass(module: HloModule, expect: Dict[str, Any]
                    ) -> Tuple[List[Finding], Dict[str, Any]]:
    """Dtype taint & width audit.

    * int8 bounces: a dequantized (s8 -> float) value reaching any
      ``dot`` — the fp32 round trip the end-to-end int8 path must not
      contain (error under ``int8_clean``);
    * f64 leaks: any f64-typed instruction on a path contracted fp32
      (the f64 consistency REFERENCE must never leak into production
      traces) — error under ``forbid_f64``;
    * silent upcasts: a float -> wider-float ``convert`` landing at f64
      (error under ``forbid_f64``; bf16 -> f32 promotion is the normal
      epilogue accumulate and stays a metric);
    * FULL-POOL upcasts: under ``forbid_big_upcast_elems: N`` any float
      widening ``convert`` whose operand holds >= N elements is an error
      — the decode-path contract that the whole KV cache/page pool is
      never materialized at fp32 per step (the flash paths convert only
      per-tile operands inside the dot fusions; set N to the pool's
      logical element count).  ``max_widening_convert_elems`` tracks the
      largest widening convert on every contract for baseline diffing.
    """
    findings: List[Finding] = []
    bounces = sorted(_taint_dequants(module))
    int8_sev = "error" if expect.get("int8_clean") else "info"
    for cname, dname in bounces:
        findings.append(Finding(
            "dtype-flow", "int8-bounce", int8_sev, f"{cname}/{dname}",
            "dot consumes a dequantized int8 tensor (fp32 dequant -> "
            "requant round trip; keep GEMM inputs int8 and re-apply "
            "scales on the int32 accumulator)"))

    f64_count = 0
    widening_converts = 0
    max_widening_elems = 0
    big_upcast_limit = expect.get("forbid_big_upcast_elems")
    big_upcasts = 0
    for cname, ins in module.instructions():
        if ins.op == "parameter":
            continue
        if ins.dtype == "f64":
            f64_count += 1
            if expect.get("forbid_f64"):
                findings.append(Finding(
                    "dtype-flow", "f64-leak", "error",
                    f"{cname}/{ins.name}",
                    f"f64 {ins.op} ({ins.shape}) on an fp32-contracted "
                    f"path"))
        if ins.op == "convert" and ins.dtype in FLOAT_DTYPES \
                and ins.operands:
            src = module.computations[cname].shape_of(ins.operands[0])
            src_dt = src.lstrip("%").split("[")[0] if src else ""
            if src_dt in FLOAT_DTYPES and \
                    DTYPE_BYTES[ins.dtype] > DTYPE_BYTES[src_dt]:
                widening_converts += 1
                _, elems = shape_info(normalize_shape(src.lstrip("%")))
                max_widening_elems = max(max_widening_elems, elems)
                if big_upcast_limit is not None and \
                        elems >= big_upcast_limit:
                    big_upcasts += 1
                    findings.append(Finding(
                        "dtype-flow", "full-pool-upcast", "error",
                        f"{cname}/{ins.name}",
                        f"{src_dt} -> {ins.dtype} convert over {elems} "
                        f"elements (>= {big_upcast_limit}): a whole "
                        f"cache/pool is materialized at the wider dtype "
                        f"every step — convert per-tile inside the dot "
                        f"instead"))
                if ins.dtype == "f64" and expect.get("forbid_f64"):
                    findings.append(Finding(
                        "dtype-flow", "silent-upcast", "error",
                        f"{cname}/{ins.name}",
                        f"silent {src_dt} -> f64 upcast"))

    metrics = {"int8_bounce_count": len(bounces),
               "f64_instruction_count": f64_count,
               "float_widening_converts": widening_converts,
               "max_widening_convert_elems": max_widening_elems}
    if big_upcast_limit is not None:
        metrics["big_upcast_count"] = big_upcasts
    return findings, metrics


# ---------------------------------------------------------------------------
# pass 3: donation / aliasing audit
# ---------------------------------------------------------------------------

def donation_pass(module: HloModule, expect: Dict[str, Any]
                  ) -> Tuple[List[Finding], Dict[str, Any]]:
    """Prove ``donate_argnums`` buffers are actually aliased.

    ``jax.jit(..., donate_argnums=...)`` is a *request*: if the aliasing
    is refused (a dtype change, a copy forced by layout, a plain typo
    dropping the argument) the program silently keeps BOTH buffers live
    — for the serving engine's KV cache (``serve/engine.py``) that
    doubles cache HBM and adds a full-tensor copy per decode step.  The
    compiled module records granted donations in ``input_output_alias``;
    this pass checks every contracted parameter number appears there,
    and flags full-tensor ``copy`` instructions whose result shape
    matches a contracted buffer (the symptom of a refused donation).
    """
    findings: List[Finding] = []
    aliased = module.aliased_parameters()
    expected = expect.get("donated_params") or ()
    entry = module.entry_computation

    param_shapes: Dict[int, str] = {}
    if entry is not None:
        for pn, ins in entry.params.items():
            param_shapes[pn] = normalize_shape(ins.shape)

    missing = [p for p in expected if p not in aliased]
    for p in missing:
        shp = param_shapes.get(p, "?")
        findings.append(Finding(
            "donation", "non-donated-buffer", "error",
            f"{module.entry or '?'}/parameter {p}",
            f"donated buffer (parameter {p}, {shp}) is NOT aliased into "
            f"the output — the step keeps two live copies"))

    # full-tensor copies of contracted buffer shapes: the copy a refused
    # donation forces.  Flagged (warning) even when aliasing succeeded —
    # a same-shaped copy next to an aliased cache is still a full
    # read+write of cache HBM worth explaining.  Scalar / one-element
    # shapes are excluded: every s32[] loop counter copy in the module
    # would match the donated optimizer step scalar and drown the signal.
    expected_shapes = set()
    for p in expected:
        shp = param_shapes.get(p)
        if shp is not None and shape_info(shp)[1] > 1:
            expected_shapes.add(shp)
    full_copies = 0
    for cname, ins in module.instructions():
        if ins.op != "copy":
            continue
        if normalize_shape(ins.shape) in expected_shapes:
            full_copies += 1
            findings.append(Finding(
                "donation", "full-tensor-copy", "warning",
                f"{cname}/{ins.name}",
                f"full-tensor copy of a donated buffer shape "
                f"{normalize_shape(ins.shape)}"))

    metrics = {"aliased_param_count": len(aliased),
               "expected_donated": len(expected),
               "missing_donations": len(missing),
               "full_tensor_copies": full_copies}
    return findings, metrics


# ---------------------------------------------------------------------------
# pass 4: dispatch counts
# ---------------------------------------------------------------------------

def dispatch_count_pass(module: HloModule, expect: Dict[str, Any]
                        ) -> Tuple[List[Finding], Dict[str, Any]]:
    """GEMM dispatch sites and apply-time weight concats (subsumes the
    legacy ``gemm_dispatches`` / ``weight_concat_count`` guards).

    * a ``dot`` whose result's last dim equals ``gemm_out_cols`` is one
      dispatch site of the audited GEMM; with packed QKV the decode
      trace must contain exactly ONE (``expect_gemm_dispatches``);
    * a ``concatenate`` producing a weight-shaped result — trailing dims
      (d_model, n) — is the HLO signature of an apply-time wq/wk/wv
      concat (a per-step weight-shard copy the packed parameter exists
      to kill); ``expect_weight_concats`` is normally 0.

    Counts are STATIC dispatch sites (a dot inside a scanned group body
    appears once however many trips the loop runs) — the guard is about
    program structure, not executed-FLOP accounting (``analyze_hlo``
    does trip-scaled costs).
    """
    findings: List[Finding] = []
    out_cols = expect.get("gemm_out_cols")
    d_model = expect.get("d_model")

    dot_total = 0
    gemm_sites: List[str] = []
    concat_sites: List[str] = []
    for cname, ins in module.instructions():
        if ins.op == "dot":
            dot_total += 1
            dims = ins.dims
            if out_cols is not None and dims and dims[-1] == out_cols:
                gemm_sites.append(f"{cname}/{ins.name}")
        elif ins.op == "concatenate" and d_model is not None:
            dims = ins.dims
            if dims and len(dims) >= 2 and dims[-2] == d_model:
                concat_sites.append(f"{cname}/{ins.name}")

    want = expect.get("expect_gemm_dispatches")
    if want is not None and len(gemm_sites) != want:
        findings.append(Finding(
            "dispatch-count", "gemm-dispatch-count", "error",
            gemm_sites[0] if gemm_sites else module.entry or "?",
            f"{len(gemm_sites)} GEMM dispatch sites at out_cols="
            f"{out_cols}, contract requires {want} (packed-QKV single "
            f"dispatch)"))
    want_cc = expect.get("expect_weight_concats")
    if want_cc is not None and len(concat_sites) != want_cc:
        findings.append(Finding(
            "dispatch-count", "weight-concat", "error",
            concat_sites[0] if concat_sites else module.entry or "?",
            f"{len(concat_sites)} apply-time weight-shaped concatenates "
            f"at d_model={d_model}, contract requires {want_cc}"))

    metrics: Dict[str, Any] = {"dot_count": dot_total,
                               "weight_concat_count": len(concat_sites)}
    if out_cols is not None:
        metrics["gemm_dispatches"] = len(gemm_sites)
    return findings, metrics


# ---------------------------------------------------------------------------
# pass 5: epilogue fusion-scope auditor
# ---------------------------------------------------------------------------

_OP_NAME_RE = None


def _op_name(ins: Instruction) -> str:
    """The jax named_scope chain from the instruction's metadata.

    OPTIMIZED modules only: ``lowered.compile().as_text()`` carries
    ``metadata={op_name="jit(f)/.../scope/op"}``; the pre-optimization
    dialect drops it (measured, not documented) — contracts that enforce
    fusion-scope expectations must trace the optimized text."""
    global _OP_NAME_RE
    import re
    if _OP_NAME_RE is None:
        _OP_NAME_RE = re.compile(r'op_name="([^"]*)"')
    m = _OP_NAME_RE.search(ins.attrs_str)
    return m.group(1) if m else ""


def fusion_scope_pass(module: HloModule, expect: Dict[str, Any]
                      ) -> Tuple[List[Finding], Dict[str, Any]]:
    """Count where the v2 epilogue algebra's elementwise work actually
    landed, via the named_scope chains the model code plants:

    * ``rmsnorm``        — models.layers.rmsnorm (standalone norm)
    * ``fused_epilogue`` — kernels.epilogue.apply_epilogue (the GEMM
                           store-phase chain; a norm whose scope carries
                           BOTH markers is the rmsnorm-FUSED output)
    * ``quantize_rowwise``  — ops.quantize_rowwise (a standalone
                           activation quantize between GEMMs)
    * ``gate_mul_unfused`` — a deliberately-unfused ``silu(g) * u``
                           multiply (einsum MoE experts; anything else
                           carrying the tag is a regression)

    Site anchors are ops unique to each computation: ``rsqrt`` for a
    norm, round-to-nearest for a quantize, ``multiply`` for the gate
    tag.  Counts are static dispatch sites in the optimized module
    (fusion computations included), same counting discipline as
    ``dispatch_count_pass``.

    Expectations:
      expect_standalone_rmsnorm   exact standalone-norm site count (the
                                  entry norm + each block's pre-MLP ln2
                                  on a fully-folded trace) -> error
      forbid_unfused_gate_mul     any tagged unfused gate multiply is an
                                  error (fused-MLP production paths)
      expect_standalone_quantize  exact standalone rowwise-quantize site
                                  count (ONE per int8 MLP: the shared
                                  input quantize) -> error
    """
    findings: List[Finding] = []
    standalone_norm: List[str] = []
    fused_norm: List[str] = []
    standalone_quant: List[str] = []
    gate_unfused: List[str] = []
    for cname, ins in module.instructions():
        scope = _op_name(ins)
        if not scope:
            continue
        where = f"{cname}/{ins.name}"
        if ins.op == "rsqrt" and "rmsnorm" in scope:
            (fused_norm if "fused_epilogue" in scope
             else standalone_norm).append(where)
        elif ins.op.startswith("round-nearest") \
                and "quantize_rowwise" in scope \
                and "fused_epilogue" not in scope:
            standalone_quant.append(where)
        elif ins.op == "multiply" and "gate_mul_unfused" in scope:
            gate_unfused.append(where)

    want = expect.get("expect_standalone_rmsnorm")
    if want is not None and len(standalone_norm) != want:
        findings.append(Finding(
            "fusion-scope", "standalone-rmsnorm", "error",
            standalone_norm[0] if standalone_norm else module.entry or "?",
            f"{len(standalone_norm)} standalone rmsnorm sites, contract "
            f"requires {want} (every other norm must ride a down-GEMM's "
            f"fused epilogue)"))
    want = expect.get("expect_standalone_quantize")
    if want is not None and len(standalone_quant) != want:
        findings.append(Finding(
            "fusion-scope", "standalone-quantize", "error",
            standalone_quant[0] if standalone_quant
            else module.entry or "?",
            f"{len(standalone_quant)} standalone rowwise-quantize sites, "
            f"contract requires {want} (GEMM->GEMM int8 handoffs must "
            f"emit (q, scale) from the store phase)"))
    if expect.get("forbid_unfused_gate_mul") and gate_unfused:
        findings.append(Finding(
            "fusion-scope", "unfused-gate-mul", "error", gate_unfused[0],
            f"{len(gate_unfused)} unfused gate multiplies on a path "
            f"whose MLPs must run the two-operand gate epilogue"))

    metrics = {
        "standalone_rmsnorm_sites": len(standalone_norm),
        "fused_rmsnorm_sites": len(fused_norm),
        "standalone_quantize_sites": len(standalone_quant),
        "unfused_gate_mul_sites": len(gate_unfused),
    }
    return findings, metrics


PASSES = (collective_schedule_pass, dtype_flow_pass, donation_pass,
          dispatch_count_pass, fusion_scope_pass)


def run_passes(module: HloModule, expect: Dict[str, Any]
               ) -> Tuple[List[Finding], Dict[str, Any]]:
    """Run every pass; findings concatenated, metrics merged (disjoint
    keyspaces by construction)."""
    findings: List[Finding] = []
    metrics: Dict[str, Any] = {}
    for p in PASSES:
        f, m = p(module, expect)
        findings.extend(f)
        metrics.update(m)
    return findings, metrics
