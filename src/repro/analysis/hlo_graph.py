"""Typed HLO parser: the graph the analysis passes walk.

Replaces the per-detector regex scans of ``launch/hlo_analysis.py`` with
ONE parse producing instructions (with operand edges and def-use users),
computations (with parameter tables and roots), module-level
input/output aliasing (buffer donation), and while-loop trip counts.

Parsing is text-based on ``compiled.as_text()`` output — and also
accepts the pre-optimization dialect of ``lowered.as_text('hlo')``,
whose computation headers carry no signature and whose operand refs
carry no ``%`` sigil (the big-upcast audit runs there: backend dot
legalization inserts its own full-array converts post-optimization, so
only the unoptimized module shows what the PROGRAM asked for).  It is
deliberately forgiving: an unrecognized line is skipped, never fatal —
the passes running on top are CI gates, and a parser crash on an HLO
dialect quirk would block every PR.  What IS hardened (PR 7 satellite) is the
trip-count extraction: multi-digit and scientific-notation constants and
tuple-shaped constants all parse (the old ``_trip_count`` silently
returned 1 on a tuple-shaped condition constant, under-counting every
FLOP downstream).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s8": 1, "u8": 1, "pred": 1,
}

FLOAT_DTYPES = {"f16", "bf16", "f32", "f64"}
INT_DTYPES = {"s8", "u8", "s16", "u16", "s32", "u32", "s64", "u64"}

# a single array shape, optionally with a layout suffix: f32[4,16]{1,0}
_ONE_SHAPE = re.compile(r"(\w+?)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*->.*\{\s*$")
# pre-optimization dialect (``lowered.as_text(dialect='hlo')``): the
# computation header is just ``name.id {`` with no signature
_COMP_HDR_BARE = re.compile(r"^(?:ENTRY\s+)?([\w.\-]+)\s*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}:T()]+?)\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")
# operand refs in the pre-optimization dialect carry no ``%`` sigil:
# bare ``name.123`` identifiers, comma-separated (a leading letter
# keeps numeric literals of constant(...) out)
_OPERAND_BARE = re.compile(r"(?:^|[,(]\s*)([A-Za-z_][\w\-]*(?:\.\d+)?)")
_CALL_KEYS = ("calls", "to_apply", "body", "condition",
              "true_computation", "false_computation")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_ALIAS_HDR = re.compile(r"input_output_alias=\{(.*?)\}(?=,\s*\w+=|\s*$)")
_ALIAS_ENTRY = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{([\d,\s]*)\}")
# numeric literal inside a constant(...), incl. scientific notation
_NUMBER = re.compile(r"-?\d+(?:\.\d+)?(?:[eE][+-]?\d+)?")


def shape_dtype(shape: str) -> str:
    """Leading dtype of a (non-tuple) shape string, '' for tuples."""
    s = shape.lstrip("%(")
    m = _ONE_SHAPE.match(s)
    return m.group(1) if m else ""


def shape_dims(shape: str) -> Optional[List[int]]:
    """Result dims of a non-tuple shape, None if unparseable."""
    m = _ONE_SHAPE.match(shape.lstrip("%"))
    if not m:
        return None
    return [int(x) for x in m.group(2).split(",")] if m.group(2) else []


def shape_info(shape: str) -> Tuple[float, int]:
    """(total bytes, element count) over every array in a shape string
    (tuples contribute the sum of their members)."""
    total_b, total_n = 0.0, 0
    for dt, dims in _ONE_SHAPE.findall(shape):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_b += n * DTYPE_BYTES[dt]
        total_n += n
    return total_b, total_n


def normalize_shape(shape: str) -> str:
    """Shape string with layout annotations ({1,0} / {:T(...)}) stripped —
    the form to compare parameter and copy shapes in."""
    return re.sub(r"\{[^}]*\}", "", shape).replace(" ", "")


@dataclasses.dataclass
class Instruction:
    name: str
    shape: str                      # raw result-shape string
    op: str                         # e.g. 'dot', 'collective-permute'
    args_str: str                   # text inside the operand parentheses
    attrs_str: str                  # text after the operand parentheses
    operands: Tuple[str, ...]       # operand instruction names (def edges)
    is_root: bool = False

    @property
    def dtype(self) -> str:
        return shape_dtype(self.shape)

    @property
    def dims(self) -> Optional[List[int]]:
        return shape_dims(self.shape)

    def attr(self, key: str) -> Optional[str]:
        m = re.search(rf"{key}=(\{{.*?\}}+|\[[^\]]*\]<=\[\d+\]|[^,\s]+)",
                      self.attrs_str)
        return m.group(1) if m else None

    @property
    def called(self) -> Tuple[str, ...]:
        """Names of computations this instruction calls (body, condition,
        to_apply, fusion calls, conditional branches)."""
        out: List[str] = []
        for key in _CALL_KEYS:
            m = re.search(rf"{key}=%?([\w.\-]+)", self.attrs_str)
            if m:
                out.append(m.group(1))
        m = _BRANCHES.search(self.attrs_str)
        if m:
            out.extend(t.strip().lstrip("%") for t in m.group(1).split(",")
                       if t.strip())
        return tuple(out)

    @property
    def body_and_calls(self) -> Tuple[str, ...]:
        """Called computations EXCLUDING the while condition (the
        condition runs trips+1 times but carries no cost model weight —
        matches the legacy analyzer's recursion set)."""
        cond = self.condition
        return tuple(c for c in self.called if c != cond)

    @property
    def condition(self) -> Optional[str]:
        m = re.search(r"condition=%?([\w.\-]+)", self.attrs_str)
        return m.group(1) if m else None

    @property
    def source_target_pairs(self) -> Optional[List[Tuple[int, int]]]:
        """Parsed source_target_pairs of a collective-permute."""
        m = re.search(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}",
                      self.attrs_str)
        if not m:
            return None
        return [(int(a), int(b))
                for a, b in re.findall(r"\{(\d+),(\d+)\}", m.group(1))]

    @property
    def replica_group_size(self) -> int:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[", self.attrs_str)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([^}]*)\}", self.attrs_str)
        if m:
            return max(1, len([t for t in m.group(1).split(",")
                               if t.strip()]))
        return 1

    @property
    def parameter_number(self) -> Optional[int]:
        if self.op != "parameter":
            return None
        m = re.match(r"(\d+)\)", self.args_str + ")")
        return int(m.group(1)) if m else None

    def constant_values(self) -> List[float]:
        """Numeric literals of a ``constant`` instruction (handles
        multi-digit ints, scientific notation, and tuple-shaped constants
        — the PR 7 trip-count hardening)."""
        if self.op != "constant":
            return []
        # args_str holds the literal up to the closing paren, e.g.
        # '128)', '1e+06)', '(5, 1.5))' — strip trailing attr text
        lit = self.args_str
        return [float(t) for t in _NUMBER.findall(lit)]


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    is_entry: bool = False

    def __post_init__(self):
        self.by_name: Dict[str, Instruction] = {
            i.name: i for i in self.instructions}
        self.params: Dict[int, Instruction] = {}
        for ins in self.instructions:
            pn = ins.parameter_number
            if pn is not None:
                self.params[pn] = ins
        # def-use edges: users[name] = instructions consuming it
        self.users: Dict[str, List[Instruction]] = {}
        for ins in self.instructions:
            for o in ins.operands:
                self.users.setdefault(o, []).append(ins)

    @property
    def root(self) -> Optional[Instruction]:
        for ins in self.instructions:
            if ins.is_root:
                return ins
        return self.instructions[-1] if self.instructions else None

    def shape_of(self, operand: str) -> Optional[str]:
        ins = self.by_name.get(operand)
        return ins.shape if ins is not None else None


@dataclasses.dataclass
class HloModule:
    name: str
    computations: Dict[str, Computation]
    entry: Optional[str]
    # donation metadata: output tuple index -> (parameter number, index)
    input_output_alias: Dict[Tuple[int, ...], Tuple[int, Tuple[int, ...]]]

    @property
    def entry_computation(self) -> Optional[Computation]:
        return self.computations.get(self.entry) if self.entry else None

    def aliased_parameters(self) -> Dict[int, Tuple[int, ...]]:
        """parameter number -> output index it aliases (donated buffers)."""
        return {param: out for out, (param, _idx)
                in self.input_output_alias.items()}

    def instructions(self) -> Iterable[Tuple[str, Instruction]]:
        for cname, comp in self.computations.items():
            for ins in comp.instructions:
                yield cname, ins

    def trip_count(self, while_instr: Instruction) -> int:
        cond = while_instr.condition
        if cond is None or cond not in self.computations:
            return 1
        return condition_trip_count(self.computations[cond])


def condition_trip_count(cond: Computation) -> int:
    """Trip count of a scan/fori loop from its condition computation.

    The loop bound is the comparison constant; it may be a scalar integer
    constant, a float constant holding an integral value (fori over a
    float carry prints ``f32[] constant(1e+06)``), or an element of a
    tuple-shaped constant the compare reads through a get-tuple-element.
    We take the max integral constant value of the region — the other
    condition constants are 0/1 steps — with 1 as the floor.  The legacy
    parser only accepted ``s32[] constant(<digits>)`` and silently
    returned 1 for everything else.
    """
    best = 1.0
    for ins in cond.instructions:
        if ins.op != "constant":
            continue
        for v in ins.constant_values():
            # trip counts are integral; tolerate float-typed bounds but
            # ignore tolerances (1e-6) and negative sentinels
            if v > best and float(v).is_integer():
                best = v
    return int(best)


def _split_operands(rest: str) -> Tuple[str, str, str]:
    """Split the text after ``op(`` into (operand text, attr text) by
    matching the closing paren at depth 0."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:], rest
    return rest, "", rest


def parse_hlo(text: str) -> HloModule:
    """Parse optimized-HLO text into the typed module graph."""
    mod_name = ""
    alias: Dict[Tuple[int, ...], Tuple[int, Tuple[int, ...]]] = {}
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None

    cur_name: Optional[str] = None
    cur_instrs: List[Instruction] = []
    cur_is_entry = False

    for line in text.splitlines():
        stripped = line.strip()
        if stripped.startswith("HloModule"):
            mod_name = stripped.split(",")[0].split()[-1]
            m = _ALIAS_HDR.search(stripped)
            if m:
                for out_idx, param, par_idx in _ALIAS_ENTRY.findall(
                        m.group(1)):
                    key = tuple(int(t) for t in out_idx.split(",")
                                if t.strip())
                    pidx = tuple(int(t) for t in par_idx.split(",")
                                 if t.strip())
                    alias[key] = (int(param), pidx)
            continue
        if cur_name is None:
            m = None
            if "{" in line and "->" in line:
                m = _COMP_HDR.match(stripped)
            elif stripped.endswith("{"):
                m = _COMP_HDR_BARE.match(stripped)
            if m:
                cur_name = m.group(1)
                cur_instrs = []
                cur_is_entry = stripped.startswith("ENTRY")
            continue
        if stripped == "}":
            comps[cur_name] = Computation(cur_name, cur_instrs,
                                          cur_is_entry)
            if cur_is_entry:
                entry = cur_name
            cur_name = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, shape, op, rest = m.groups()
        args, attrs, _ = _split_operands(rest)
        operands = tuple(_OPERAND.findall(args))
        if not operands and op not in ("parameter", "constant"):
            operands = tuple(_OPERAND_BARE.findall(args))
        cur_instrs.append(Instruction(
            name=name, shape=shape, op=op, args_str=args, attrs_str=attrs,
            operands=operands, is_root=stripped.startswith("ROOT")))
    if cur_name is not None:  # unterminated trailing computation
        comps[cur_name] = Computation(cur_name, cur_instrs, cur_is_entry)
        if cur_is_entry:
            entry = cur_name
    return HloModule(mod_name, comps, entry, alias)
