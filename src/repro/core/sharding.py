"""Sharding helpers: logical axes -> PartitionSpec, mesh utilities.

Also hosts the JAX version-compatibility shims (``make_mesh_compat``,
``use_mesh``, ``shard_map_compat``): the codebase targets the current
mesh/shard_map APIs (``jax.sharding.AxisType``, ``jax.set_mesh``,
``jax.shard_map``) but must run on older installs where those live under
different names (``jax.experimental.shard_map``, mesh-as-context-manager)
or do not exist at all.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# version-compat shims
# ---------------------------------------------------------------------------

def make_mesh_compat(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them.

    Auto types are required when mixing GSPMD-constrained jit code with
    explicit shard_map blocks (the XYZ matmul) on new JAX; older versions
    have no axis types and every axis is implicitly Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes)
            )
        except TypeError:
            pass  # make_mesh predates the axis_types kwarg
    return jax.make_mesh(shape, axes)


def use_mesh(mesh: Mesh):
    """Context manager installing ``mesh`` as the ambient mesh:
    ``jax.set_mesh`` on new JAX, ``jax.sharding.use_mesh`` on mid-vintage,
    and the mesh's own context manager on old installs."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    um = getattr(jax.sharding, "use_mesh", None)
    if um is not None:
        return um(mesh)
    return mesh  # Mesh is itself a context manager


def shard_map_compat(body, mesh: Mesh, in_specs, out_specs):
    """``jax.shard_map`` across API generations: new JAX spells the
    replication check ``check_vma``, older ``check_rep``, and oldest only
    ships it as ``jax.experimental.shard_map.shard_map``."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:  # older spelling
        return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel axes: every mesh axis that is not the model axis.
    On the multi-pod mesh this is ('pod', 'data')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_size(mesh: Mesh) -> int:
    sizes = mesh_axis_sizes(mesh)
    out = 1
    for a in dp_axes(mesh):
        out *= sizes[a]
    return out


def model_size(mesh: Mesh) -> int:
    return mesh_axis_sizes(mesh).get("model", 1)


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> P:
    """[B, ...] with B over all data axes."""
    return P(dp_axes(mesh), *([None] * extra_dims))


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def shard_batch_dim(mesh: Mesh, ndim: int, dim: int = 0) -> P:
    parts: list = [None] * ndim
    parts[dim] = dp_axes(mesh)
    return P(*parts)


def row_axes(mesh: Mesh, batch: int):
    """Data axes for sharding a batch dim, or None when the batch does not
    divide them (e.g. the batch-1 long-context cells)."""
    dpx = dp_axes(mesh)
    n = dp_size(mesh)
    if n > 1 and batch % n == 0 and batch >= n:
        return dpx
    return None


def constrain(x, mesh: Mesh, spec: P):
    """with_sharding_constraint that is a no-op on 1-device meshes (keeps
    small CPU tests free of sharding noise)."""
    if mesh.devices.size == 1:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
