"""Sharding helpers: logical axes -> PartitionSpec, mesh utilities."""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel axes: every mesh axis that is not the model axis.
    On the multi-pod mesh this is ('pod', 'data')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_size(mesh: Mesh) -> int:
    sizes = mesh_axis_sizes(mesh)
    out = 1
    for a in dp_axes(mesh):
        out *= sizes[a]
    return out


def model_size(mesh: Mesh) -> int:
    return mesh_axis_sizes(mesh).get("model", 1)


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> P:
    """[B, ...] with B over all data axes."""
    return P(dp_axes(mesh), *([None] * extra_dims))


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def shard_batch_dim(mesh: Mesh, ndim: int, dim: int = 0) -> P:
    parts: list = [None] * ndim
    parts[dim] = dp_axes(mesh)
    return P(*parts)


def row_axes(mesh: Mesh, batch: int):
    """Data axes for sharding a batch dim, or None when the batch does not
    divide them (e.g. the batch-1 long-context cells)."""
    dpx = dp_axes(mesh)
    n = dp_size(mesh)
    if n > 1 and batch % n == 0 and batch >= n:
        return dpx
    return None


def constrain(x, mesh: Mesh, spec: P):
    """with_sharding_constraint that is a no-op on 1-device meshes (keeps
    small CPU tests free of sharding noise)."""
    if mesh.devices.size == 1:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
