"""Sharded MaxEVA matmul: the paper's X x Y x Z array mapping on a TPU mesh.

Terminology (paper §IV-A/B):
  X — shards of the M dimension (activation rows). On TPU this is the
      data-parallel sharding of the batch and is fixed by the mesh.
  Y — shards of the contraction dimension K.  Partial products are reduced
      *on the array* by the adder tree; here by ``psum``/``psum_scatter``
      over Y-subgroups of the model axis (``axis_index_groups``).
  Z — shards of the N dimension (output columns), i.e. column parallelism.
  broadcast — A tiles are broadcast to their Z consumers; here the
      activation is either already replicated over the model axis (the
      in_spec performs the broadcast) or all-gathered over Z-subgroups when
      it arrives K-sharded from the previous layer.

The model axis of size ``model`` is factored as ``Y * Z = model`` with the
device's model-axis index decomposed z-major: ``y = md % Y, z = md // Y``.

Layout convention (makes consecutive layers compose with ZERO resharding):
  * All K/N chunking is at ``model`` granularity: dimension D is split into
    ``model`` chunks of D/model.
  * Output: device ``md`` emits N-chunk ``md`` — natural order.
  * K blocks are interleaved: Y-block ``y`` = chunks {c : c % Y == y}
    (ordered by c).  Consequently a previous layer's natural-order output
    (K-chunk md on device md) is exactly what the z-subgroup all-gather
    assembles for this layer's Y-block — the neighbour-memory-sharing
    analogue: data is already where the next kernel needs it.
  * Weights are stored pre-sharded in "xyz layout" ``[model, K/Y, N/Z]``
    (sharded on dim 0), the analogue of MaxEVA pinning each kernel's
    buffers at compile time.

Reduction schedules (placement-pattern analogues, §IV-D):
  'allreduce'       — P1 analogue: one heavy reduction; every y-replica
                      materializes the full N/Z block, then keeps its slice.
  'reduce_scatter'  — P2 analogue: strictly fewer wire bytes ((Y-1)/Y vs
                      2(Y-1)/Y) and the output lands pre-sliced.
  'ring'            — beyond-paper: chunked ring reduce-scatter built from
                      ppermute so XLA can overlap each hop with the next
                      partial-GEMM chunk (collective matmul).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.sharding import dp_axes, model_size
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class XYZConfig:
    """Per-GEMM plan consumed by ``xyz_matmul``."""

    y: int = 1                        # K shards (adder-tree width)
    schedule: str = "reduce_scatter"  # 'allreduce' | 'reduce_scatter' | 'ring'
    x_layout: str = "replicated"      # 'replicated' (broadcast) | 'ksharded'
    out_dtype: Optional[jnp.dtype] = None

    def z(self, model: int) -> int:
        assert model % self.y == 0, (model, self.y)
        return model // self.y


def _y_groups(model: int, y: int) -> Optional[Sequence[Sequence[int]]]:
    """Devices sharing z (the adder-tree groups): [[z*Y+y for y] for z]."""
    if y == model:
        return None  # full-axis collective
    z = model // y
    return [[zz * y + yy for yy in range(y)] for zz in range(z)]


def _z_groups(model: int, y: int) -> Optional[Sequence[Sequence[int]]]:
    """Devices sharing y (the broadcast groups): [[z*Y+y for z] for y]."""
    z = model // y
    if z == model:
        return None
    return [[zz * y + yy for zz in range(z)] for yy in range(y)]


def shard_weight_xyz(w: jnp.ndarray, model: int, y: int) -> jnp.ndarray:
    """Repack a [K, N] weight into xyz layout [model, K/Y, N/Z].

    Device md = z*Y+y holds K-chunks {c : c % Y == y} (ordered) of the
    contiguous N-block z."""
    k, n = w.shape
    z = model // y
    assert k % model == 0 and n % z == 0, (w.shape, model, y)
    # (kz, ky, krow, nz, ncol): K-chunk c = kz*Y + ky
    w5 = w.reshape(z, y, k // model, z, n // z)
    # device md = nz*Y + ky  ->  [:, ky, :, nz, :]
    w_dev = jnp.transpose(w5, (3, 1, 0, 2, 4))  # (nz, ky, kz, krow, ncol)
    return w_dev.reshape(model, k // y, n // z)


def unshard_weight_xyz(w_xyz: jnp.ndarray, y: int) -> jnp.ndarray:
    """Inverse of ``shard_weight_xyz`` (checkpoints / elastic resharding)."""
    model, ky_rows, ncol = w_xyz.shape
    z = model // y
    k = ky_rows * y
    w_dev = w_xyz.reshape(z, y, z, k // model, ncol)   # (nz, ky, kz, krow, ncol)
    w5 = jnp.transpose(w_dev, (2, 1, 3, 0, 4))         # (kz, ky, krow, nz, ncol)
    return w5.reshape(k, z * ncol)


def xyz_weight_shape(k: int, n: int, model: int, y: int) -> Tuple[int, int, int]:
    return (model, k // y, n // (model // y))


def _slice_k_block(x2: jnp.ndarray, yid, y: int, model: int) -> jnp.ndarray:
    """From replicated x [rows, K], extract the interleaved Y-block ``yid``:
    K-chunks {c : c % Y == yid}, ordered by c."""
    if y == 1:
        return x2
    rows, k = x2.shape
    z = model // y
    x4 = x2.reshape(rows, z, y, k // model)   # chunk c = kz*Y + ky
    xb = jax.lax.dynamic_index_in_dim(x4, yid, axis=2, keepdims=False)
    return xb.reshape(rows, k // y)


def _local_matmul(x2d: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return kops.matmul(x2d, w, out_dtype=jnp.float32)


def _ring_reduce_scatter(partial: jnp.ndarray, axis: str, groups,
                         y: int) -> jnp.ndarray:
    """Chunked ring reduce-scatter over the y-subgroup via ppermute.

    ``partial`` is [rows, Nz]; returns [rows, Nz/Y] — the device's y-chunk,
    matching psum_scatter(..., tiled=True).  Chunk c starts at device
    position c+1, walks the ring accumulating, lands at position c.
    """
    md = jax.lax.axis_index(axis)
    yid = jax.lax.rem(md, y)
    nz = partial.shape[-1]
    chunk = nz // y
    chunks = jnp.stack(
        [jax.lax.dynamic_slice_in_dim(partial, c * chunk, chunk, axis=-1)
         for c in range(y)],
        axis=0,
    )  # [y, rows, chunk]

    if groups is None:
        pairs = [(i, (i + 1) % y) for i in range(y)]
    else:
        pairs = []
        for g in groups:
            for i, src in enumerate(g):
                pairs.append((src, g[(i + 1) % len(g)]))

    def take(idx):
        return jax.lax.dynamic_index_in_dim(chunks, idx, axis=0,
                                            keepdims=False)

    acc = take(jax.lax.rem(yid + y - 1, y))
    for step in range(1, y):
        acc = jax.lax.ppermute(acc, axis, pairs)
        acc = acc + take(jax.lax.rem(yid + 2 * y - 1 - step, y))
    return acc


def _shard_map(body, mesh, in_specs, out_specs):
    try:
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except TypeError:  # older spelling
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)


def xyz_matmul(
    x: jnp.ndarray,
    w_xyz: jnp.ndarray,
    *,
    mesh: Mesh,
    cfg: XYZConfig,
    batch_sharded: bool = True,
) -> jnp.ndarray:
    """out[..., N] = x[..., K] @ W, distributed per the XYZ plan.

    ``w_xyz`` is in xyz layout ([model, K/Y, N/Z], sharded on dim 0).
    Output is N-sharded over the model axis in natural chunk order; ``x``
    is row-sharded over the data axes (X) and either replicated over model
    ('replicated' — the broadcast) or K-sharded in natural order
    ('ksharded' — a previous layer's output).
    """
    model = model_size(mesh)
    if model == 1:
        w = unshard_weight_xyz(w_xyz, cfg.y)
        lead = x.shape[:-1]
        out = _local_matmul(x.reshape(-1, x.shape[-1]), w)
        return out.astype(cfg.out_dtype or x.dtype).reshape(*lead, -1)

    y, z = cfg.y, cfg.z(model)
    from repro.core.sharding import row_axes
    row_spec = row_axes(mesh, x.shape[0]) if batch_sharded else None
    mid = [None] * (x.ndim - 2)

    x_spec = P(row_spec, *mid,
               "model" if cfg.x_layout == "ksharded" else None)
    out_spec = P(row_spec, *mid, "model")

    ygroups = _y_groups(model, y)
    zgroups = _z_groups(model, y)

    def body(xl, wl):
        wl = wl[0]  # [K/Y, N/Z]
        md = jax.lax.axis_index("model")
        yid = jax.lax.rem(md, y)
        lead = xl.shape[:-1]
        x2 = xl.reshape(-1, xl.shape[-1])

        if cfg.x_layout == "replicated":
            x2 = _slice_k_block(x2, yid, y, model)
        elif z > 1:
            # assemble the Y-block from natural-order K shards: gather over
            # the z-subgroup concatenates chunks {y, Y+y, ...} in order —
            # exactly the interleaved block the weight layout expects.
            x2 = jax.lax.all_gather(x2, "model", axis_index_groups=zgroups,
                                    axis=1, tiled=True)

        # cast to the output dtype BEFORE the reduction: the collective's
        # wire format (and its AD transpose buffers) stay 16-bit; XLA's
        # all-reduce promotion still accumulates in fp32 internally.
        partial = _local_matmul(x2, wl).astype(cfg.out_dtype or x.dtype)

        nz = wl.shape[-1]
        if y == 1:
            out = partial
        elif cfg.schedule == "allreduce":
            red = jax.lax.psum(partial, "model", axis_index_groups=ygroups)
            out = jax.lax.dynamic_slice_in_dim(red, yid * (nz // y), nz // y,
                                               axis=-1)
        elif cfg.schedule == "reduce_scatter":
            out = jax.lax.psum_scatter(
                partial, "model", scatter_dimension=partial.ndim - 1,
                axis_index_groups=ygroups, tiled=True)
        elif cfg.schedule == "ring":
            out = _ring_reduce_scatter(partial, "model", ygroups, y)
        else:
            raise ValueError(cfg.schedule)

        out = out.astype(cfg.out_dtype or x.dtype)
        return out.reshape(*lead, -1)

    return _shard_map(body, mesh, (x_spec, P("model", None, None)),
                      out_spec)(x, w_xyz)


def xyz_matmul_replicated_out(
    x: jnp.ndarray,
    w_xyz: jnp.ndarray,
    *,
    mesh: Mesh,
    cfg: XYZConfig,
    batch_sharded: bool = True,
) -> jnp.ndarray:
    """Row-parallel variant with fully replicated (over model) output:
    Y = model, Z = 1, one psum/ring-allreduce — the classic Megatron
    down-projection.  Used when the next op needs the full feature
    dimension on every device (residual adds on replicated activations)."""
    model = model_size(mesh)
    if model == 1:
        return xyz_matmul(x, w_xyz, mesh=mesh, cfg=cfg,
                          batch_sharded=batch_sharded)
    assert cfg.y == model, "replicated-out requires Y == model"
    from repro.core.sharding import row_axes
    row_spec = row_axes(mesh, x.shape[0]) if batch_sharded else None
    mid = [None] * (x.ndim - 2)
    x_spec = P(row_spec, *mid,
               "model" if cfg.x_layout == "ksharded" else None)
    out_spec = P(row_spec, *mid, None)

    def body(xl, wl):
        wl = wl[0]
        md = jax.lax.axis_index("model")
        lead = xl.shape[:-1]
        x2 = xl.reshape(-1, xl.shape[-1])
        if cfg.x_layout == "replicated":
            x2 = _slice_k_block(x2, md, model, model)
        partial = _local_matmul(x2, wl).astype(cfg.out_dtype or x.dtype)
        out = jax.lax.psum(partial, "model")
        return out.reshape(*lead, -1)

    return _shard_map(body, mesh, (x_spec, P("model", None, None)),
                      out_spec)(x, w_xyz)
