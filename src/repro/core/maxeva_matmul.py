"""Sharded MaxEVA matmul: the paper's X x Y x Z array mapping on a TPU mesh.

Terminology (paper §IV-A/B):
  X — shards of the M dimension (activation rows). On TPU this is the
      data-parallel sharding of the batch and is fixed by the mesh.
  Y — shards of the contraction dimension K.  Partial products are reduced
      *on the array* by the adder tree; here by ``psum``/``psum_scatter``
      over Y-subgroups of the model axis (``axis_index_groups``).
  Z — shards of the N dimension (output columns), i.e. column parallelism.
  broadcast — A tiles are broadcast to their Z consumers; here the
      activation is either already replicated over the model axis (the
      in_spec performs the broadcast) or all-gathered over Z-subgroups when
      it arrives K-sharded from the previous layer.

The model axis of size ``model`` is factored as ``Y * Z = model`` with the
device's model-axis index decomposed z-major: ``y = md % Y, z = md // Y``.

Layout convention (makes consecutive layers compose with ZERO resharding):
  * All K/N chunking is at ``model`` granularity: dimension D is split into
    ``model`` chunks of D/model.
  * Output: device ``md`` emits N-chunk ``md`` — natural order.
  * K blocks are interleaved: Y-block ``y`` = chunks {c : c % Y == y}
    (ordered by c).  Consequently a previous layer's natural-order output
    (K-chunk md on device md) is exactly what the z-subgroup all-gather
    assembles for this layer's Y-block — the neighbour-memory-sharing
    analogue: data is already where the next kernel needs it.
  * Weights are stored pre-sharded in "xyz layout" ``[model, K/Y, N/Z]``
    (sharded on dim 0), the analogue of MaxEVA pinning each kernel's
    buffers at compile time.

Reduction schedules (placement-pattern analogues, §IV-D):
  'allreduce'       — P1 analogue: one heavy reduction; every y-replica
                      materializes the full N/Z block, then keeps its slice.
  'reduce_scatter'  — P2 analogue: strictly fewer wire bytes ((Y-1)/Y vs
                      2(Y-1)/Y) and the output lands pre-sliced.
  'ring'            — beyond-paper: a TRUE collective matmul.  The local
                      GEMM is split into Y N-chunk GEMMs and each chunk's
                      ppermute hop is interleaved with the next chunk's
                      GEMM, so XLA's latency-hiding scheduler overlaps
                      compute with communication (§IV-C ping-pong applied
                      to the wire).
  'bidir_ring'      — the overlapped ring with each N-chunk split into two
                      half-chunks shipped by two OPPOSITE rotation ppermute
                      sets.  Total wire bytes equal 'ring', but each
                      direction's links carry half of them, so on a
                      full-duplex torus the wire time halves (the per-link
                      traffic-balancing lesson of the Versal GEMM energy
                      study applied to the ICI ring).

Determinism contract (extends across ALL FOUR schedules): every y>1
schedule builds its local partial from the SAME per-N-chunk GEMMs (the
shared ``chunk_fn``) and reduces contributions in ascending y-position
order, so the schedule choice never changes numerics — 'ring' and
'bidir_ring' match 'reduce_scatter' bit-for-bit at fp32 (the split-chunk
merge concatenates the rank-order-reduced half-chunk buffers, an
elementwise-identical association), and the planner is free to switch
schedules step-to-step (the placement-pattern analogue: P1 and P2 compute
identical results).  ``tests/test_schedule_equivalence.py`` sweeps the
full (schedule x x_layout x Y x Z x epilogue) grid and asserts it.

Overlapped all-gather (``x_layout='ksharded'``, Z > 1, Y > 1): instead of
a barrier ``all_gather`` of A before the local GEMM, the gather is
CHUNKED — each z-subgroup peer's K-piece arrives by its own rotation
ppermute and is consumed immediately by that piece's GEMM against the
matching weight row-block, so the gather hops hide behind the MXU work
(GotoBLAS2-on-Versal packing/compute overlap applied to the gather side).
The per-piece products are reduced in ascending global K-piece order at
fp32 by EVERY schedule on this path, which keeps the cross-schedule
bitwise contract intact (the K-piece association differs from the
monolithic-GEMM accumulation of the replicated path, so 'ksharded' Z>1
numerics are layout-specific but schedule-independent).  The Y == 1 path
keeps the barrier gather: there is no chunk GEMM to overlap with, and the
whole epilogue stays fused in the kernel's store phase.

Fused epilogues: ``XYZConfig.epilogue`` (a ``kernels.epilogue.Epilogue``)
runs bias/activation/residual/cast/quantize on the GEMM output without an
extra HBM round trip.  With Y == 1 the epilogue runs inside the Pallas
kernel's store phase; with Y > 1 the nonlinear steps must follow the
adder-tree reduction, so they run on the reduced shard inside the same
shard_map body (XLA fuses them into the collective's consumer).  Bias is
passed replicated ``[N]`` and sliced per shard; residual matches the
OUTPUT sharding.  ``quantize`` emits per-N-shard rowwise scales:
``(q [..., N], scale [..., model])``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.sharding import dp_axes, model_size
from repro.kernels import ops as kops
from repro.kernels.epilogue import Epilogue, apply_epilogue


SCHEDULES = ("allreduce", "reduce_scatter", "ring", "bidir_ring")
X_LAYOUTS = ("replicated", "ksharded")


@dataclasses.dataclass(frozen=True)
class XYZConfig:
    """Per-GEMM plan consumed by ``xyz_matmul``."""

    y: int = 1                        # K shards (adder-tree width)
    schedule: str = "reduce_scatter"  # one of SCHEDULES
    x_layout: str = "replicated"      # 'replicated' (broadcast) | 'ksharded'
    out_dtype: Optional[jnp.dtype] = None
    epilogue: Optional[Epilogue] = None   # fused store-phase epilogue

    def __post_init__(self):
        # fail LOUDLY on typos ('ring ' / 'reduce-scatter' / ...): an
        # unknown string silently running some default schedule is exactly
        # the failure mode the determinism contract exists to prevent.
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"unknown schedule {self.schedule!r}; valid schedules are "
                f"{SCHEDULES}")
        if self.x_layout not in X_LAYOUTS:
            raise ValueError(
                f"unknown x_layout {self.x_layout!r}; valid layouts are "
                f"{X_LAYOUTS}")

    def z(self, model: int) -> int:
        assert model % self.y == 0, (model, self.y)
        return model // self.y


def _y_groups(model: int, y: int) -> Optional[Sequence[Sequence[int]]]:
    """Devices sharing z (the adder-tree groups): [[z*Y+y for y] for z]."""
    if y == model:
        return None  # full-axis collective
    z = model // y
    return [[zz * y + yy for yy in range(y)] for zz in range(z)]


def _z_groups(model: int, y: int) -> Optional[Sequence[Sequence[int]]]:
    """Devices sharing y (the broadcast groups): [[z*Y+y for z] for y]."""
    z = model // y
    if z == model:
        return None
    return [[zz * y + yy for zz in range(z)] for yy in range(y)]


def schedule_wire_ops(cfg: XYZConfig, model: int) -> Tuple[str, ...]:
    """Collective HLO ops the XYZ plan prices for one forward GEMM — the
    contract auditor's allowed set (``repro.analysis``): any OTHER
    collective in the traced module is a barrier the overlap model never
    accounted for.

    Derived from the same branch structure as ``xyz_matmul``'s body:

    * Y > 1 reductions: 'allreduce' -> all-reduce, 'reduce_scatter' ->
      reduce-scatter, 'ring'/'bidir_ring' -> collective-permute hops;
    * ksharded X with Z > 1: Y > 1 overlaps the gather as ppermute hops
      (collective-permute), Y == 1 keeps the barrier all-gather ON
      PURPOSE (no chunk GEMMs to hide it under — see the Y == 1 branch).
    """
    y, z = cfg.y, cfg.z(model)
    ops = set()
    if y > 1:
        ops.add({"allreduce": "all-reduce",
                 "reduce_scatter": "reduce-scatter",
                 "ring": "collective-permute",
                 "bidir_ring": "collective-permute"}[cfg.schedule])
    if cfg.x_layout == "ksharded" and z > 1:
        ops.add("collective-permute" if y > 1 else "all-gather")
    return tuple(sorted(ops))


def shard_weight_xyz(w: jnp.ndarray, model: int, y: int) -> jnp.ndarray:
    """Repack a [K, N] weight into xyz layout [model, K/Y, N/Z].

    Device md = z*Y+y holds K-chunks {c : c % Y == y} (ordered) of the
    contiguous N-block z."""
    k, n = w.shape
    z = model // y
    assert k % model == 0 and n % z == 0, (w.shape, model, y)
    # (kz, ky, krow, nz, ncol): K-chunk c = kz*Y + ky
    w5 = w.reshape(z, y, k // model, z, n // z)
    # device md = nz*Y + ky  ->  [:, ky, :, nz, :]
    w_dev = jnp.transpose(w5, (3, 1, 0, 2, 4))  # (nz, ky, kz, krow, ncol)
    return w_dev.reshape(model, k // y, n // z)


def unshard_weight_xyz(w_xyz: jnp.ndarray, y: int) -> jnp.ndarray:
    """Inverse of ``shard_weight_xyz`` (checkpoints / elastic resharding)."""
    model, ky_rows, ncol = w_xyz.shape
    z = model // y
    k = ky_rows * y
    w_dev = w_xyz.reshape(z, y, z, k // model, ncol)   # (nz, ky, kz, krow, ncol)
    w5 = jnp.transpose(w_dev, (2, 1, 3, 0, 4))         # (kz, ky, krow, nz, ncol)
    return w5.reshape(k, z * ncol)


def xyz_weight_shape(k: int, n: int, model: int, y: int) -> Tuple[int, int, int]:
    return (model, k // y, n // (model // y))


def _slice_k_block(x2: jnp.ndarray, yid, y: int, model: int) -> jnp.ndarray:
    """From replicated x [rows, K], extract the interleaved Y-block ``yid``:
    K-chunks {c : c % Y == yid}, ordered by c."""
    if y == 1:
        return x2
    rows, k = x2.shape
    z = model // y
    x4 = x2.reshape(rows, z, y, k // model)   # chunk c = kz*Y + ky
    xb = jax.lax.dynamic_index_in_dim(x4, yid, axis=2, keepdims=False)
    return xb.reshape(rows, k // y)


def _local_matmul(x2d: jnp.ndarray, w: jnp.ndarray, *,
                  out_dtype=jnp.float32, epilogue: Optional[Epilogue] = None,
                  bias=None, residual=None, operand2=None, norm_scale=None):
    return kops.matmul(x2d, w, out_dtype=out_dtype, epilogue=epilogue,
                       bias=bias, residual=residual, operand2=operand2,
                       norm_scale=norm_scale)


def _chunk_gemm(x2: jnp.ndarray, wl: jnp.ndarray, c, chunk: int,
                wire_dtype) -> jnp.ndarray:
    """GEMM against N-chunk ``c`` of the local weight shard; the wire cast
    is fused into the kernel's store phase (bitwise identical to casting
    the fp32 accumulator afterwards).  ``c`` may be traced."""
    wc = jax.lax.dynamic_slice_in_dim(wl, c * chunk, chunk, axis=-1)
    return kops.matmul(x2, wc, out_dtype=wire_dtype)


def _partial_from_chunks(chunk_fn, y: int) -> jnp.ndarray:
    """The local partial as a concat of per-N-chunk GEMMs — the SAME chunk
    GEMMs the ring schedules issue, so every schedule sees bitwise
    identical local contributions (cross-schedule determinism)."""
    return jnp.concatenate([chunk_fn(c) for c in range(y)], axis=-1)


def _rotation_pairs(groups, y: int, s: int):
    """ppermute pairs rotating each subgroup by ``s`` positions (``s`` may
    be negative: the opposite ring direction)."""
    if groups is None:
        return [(i, (i + s) % y) for i in range(y)]
    pairs = []
    for g in groups:
        for i, src in enumerate(g):
            pairs.append((src, g[(i + s) % len(g)]))
    return pairs


def _rank_order_sum(buf: jnp.ndarray, wire_dtype) -> jnp.ndarray:
    """Reduce stacked contributions over axis 0 in ascending rank order at
    fp32 — the association XLA's reduce-scatter uses, shared by every
    schedule so the reduction never depends on the wire pattern."""
    acc = buf[0].astype(jnp.float32)
    for i in range(1, buf.shape[0]):
        acc = acc + buf[i].astype(jnp.float32)
    return acc.astype(wire_dtype)


def _ring_collective_matmul(chunk_fn, yid, axis: str, groups, y: int,
                            rows: int, chunk: int,
                            wire_dtype) -> jnp.ndarray:
    """Overlapped collective matmul (the 'ring' schedule).

    The local [rows, Nz] GEMM is split into Y N-chunks.  In round ``s``
    (s = 1..y-1) each device ships its GEMM for chunk ``yid + s`` straight
    to that chunk's owner (a rotation-by-s ppermute within the y-subgroup)
    and issues the NEXT chunk's GEMM before consuming the hop, so the
    compiler can overlap the wire transfer with the MXU work — the §IV-C
    ping-pong discipline applied to inter-chip traffic.  Wire bytes equal
    the classic ring reduce-scatter: (Y-1)/Y of the partial.

    The owner buffers contributions by source y-position and reduces in
    ascending rank order — the association XLA's reduce-scatter uses — so
    the result matches 'reduce_scatter' bit-for-bit at fp32.

    ``chunk_fn(c) -> [rows, chunk]`` is the SHARED per-N-chunk GEMM (or a
    slice of the shared gather-overlap partial on the ksharded path); ``c``
    may be traced.
    """
    buf = jnp.zeros((y, rows, chunk), wire_dtype)
    # own contribution to the chunk this device keeps (no hop)
    buf = jax.lax.dynamic_update_index_in_dim(buf, chunk_fn(yid), yid, 0)
    send = chunk_fn(jax.lax.rem(yid + 1, y))
    for s in range(1, y):
        recv = jax.lax.ppermute(send, axis, _rotation_pairs(groups, y, s))
        if s + 1 < y:
            # issue round s+1's GEMM before consuming round s's hop: the
            # chunk GEMM has no data dependence on the in-flight permute
            send = chunk_fn(jax.lax.rem(yid + s + 1, y))
        buf = jax.lax.dynamic_update_index_in_dim(
            buf, recv, jax.lax.rem(yid - s + y, y), 0)
    return _rank_order_sum(buf, wire_dtype)


def _bidir_ring_collective_matmul(chunk_fn, yid, axis: str, groups, y: int,
                                  rows: int, chunk: int,
                                  wire_dtype) -> jnp.ndarray:
    """Bidirectional overlapped collective matmul ('bidir_ring').

    Each N-chunk GEMM is computed ONCE (same ``chunk_fn`` as 'ring') and
    split into two half-chunks: the low half rides the forward rotation
    set (+s) to the chunk's owner, the high half rides the SECOND,
    opposite rotation set (-s).  Total wire bytes match 'ring', but each
    direction's links carry half of them — on a full-duplex torus both
    directions progress simultaneously and per-link time halves (the
    planner's ``reduction_wire_bytes_per_link`` models exactly this).

    Split-chunk merge: the owner buffers half-chunks by source y-position
    and rank-order-reduces each half independently, then concatenates.
    fp32 addition is elementwise, so reduce-then-concat is bitwise
    identical to 'ring's concat-then-reduce — the determinism contract
    extends to this schedule with no new numeric cases.
    """
    half = chunk // 2
    if half == 0:
        # a 1-column chunk cannot be split; the unidirectional ring is
        # bitwise identical (shared chunk_fn + shared rank-order merge)
        return _ring_collective_matmul(chunk_fn, yid, axis, groups, y,
                                       rows, chunk, wire_dtype)
    gemms = {}

    def g(d: int) -> jnp.ndarray:
        # chunk GEMM at y-offset ``d``, cached: offset d's low half ships
        # in round s=d, its high half in round s=y-d — one GEMM feeds both
        if d not in gemms:
            gemms[d] = chunk_fn(jax.lax.rem(yid + d, y))
        return gemms[d]

    buf_lo = jnp.zeros((y, rows, half), wire_dtype)
    buf_hi = jnp.zeros((y, rows, chunk - half), wire_dtype)
    own = g(0)
    buf_lo = jax.lax.dynamic_update_index_in_dim(buf_lo, own[:, :half],
                                                 yid, 0)
    buf_hi = jax.lax.dynamic_update_index_in_dim(buf_hi, own[:, half:],
                                                 yid, 0)
    for s in range(1, y):
        # forward hop: low half of the chunk owned s positions ahead;
        # backward hop: high half of the chunk owned s positions behind.
        # Neither send depends on any earlier hop, so the second ppermute
        # set overlaps both the first set and the remaining chunk GEMMs.
        recv_lo = jax.lax.ppermute(g(s)[:, :half], axis,
                                   _rotation_pairs(groups, y, s))
        recv_hi = jax.lax.ppermute(g(y - s)[:, half:], axis,
                                   _rotation_pairs(groups, y, -s))
        buf_lo = jax.lax.dynamic_update_index_in_dim(
            buf_lo, recv_lo, jax.lax.rem(yid - s + y, y), 0)
        buf_hi = jax.lax.dynamic_update_index_in_dim(
            buf_hi, recv_hi, jax.lax.rem(yid + s, y), 0)
    return jnp.concatenate([_rank_order_sum(buf_lo, wire_dtype),
                            _rank_order_sum(buf_hi, wire_dtype)], axis=-1)


def _overlapped_gather_partial(x2: jnp.ndarray, wl: jnp.ndarray, axis: str,
                               zgroups, z: int, y: int,
                               wire_dtype) -> jnp.ndarray:
    """Chunked all-gather of A overlapped with the local GEMMs (the
    'ksharded' Z>1, Y>1 path).

    Each z-subgroup peer's natural-order K-piece arrives by its own
    rotation ppermute of the ORIGINAL local piece — no hop depends on an
    earlier hop, so every transfer is in flight while the already-arrived
    pieces' GEMMs run (the GotoBLAS2-on-Versal pack/compute overlap on the
    gather side; the barrier ``all_gather`` + monolithic GEMM this
    replaces serialized the whole gather before the first MAC).

    Every arriving piece is multiplied against its matching weight
    row-block immediately; products are buffered by GLOBAL K-piece
    position and reduced in ascending order at fp32.  All schedules build
    their partial from this ONE helper on this path, so the K-piece
    association is layout-specific but schedule-independent — the bitwise
    cross-schedule contract survives.
    """
    md = jax.lax.axis_index(axis)
    zz = md // y                  # z-position within the gather subgroup
    rows, kloc = x2.shape         # kloc = K/model (one natural-order piece)
    nz = wl.shape[-1]

    def piece_gemm(piece, j):
        # global K-piece j multiplies weight rows [j*kloc, (j+1)*kloc): the
        # interleaved Y-block keeps pieces in ascending z-position order
        wj = jax.lax.dynamic_slice_in_dim(wl, j * kloc, kloc, axis=0)
        return kops.matmul(piece, wj, out_dtype=jnp.float32)

    buf = jnp.zeros((z, rows, nz), jnp.float32)
    buf = jax.lax.dynamic_update_index_in_dim(buf, piece_gemm(x2, zz), zz, 0)
    for s in range(1, z):
        recv = jax.lax.ppermute(x2, axis, _rotation_pairs(zgroups, z, s))
        src = jax.lax.rem(zz - s + z, z)
        buf = jax.lax.dynamic_update_index_in_dim(
            buf, piece_gemm(recv, src), src, 0)
    return _rank_order_sum(buf, wire_dtype)


def _shard_map(body, mesh, in_specs, out_specs):
    from repro.core.sharding import shard_map_compat
    return shard_map_compat(body, mesh, in_specs, out_specs)


def _check_epilogue_operands(ep: Optional[Epilogue], bias, residual,
                             operand2=None, norm_scale=None):
    """Fail fast (outside the shard_map trace) on spec/operand mismatch."""
    if ep is None:
        assert bias is None and residual is None and operand2 is None \
            and norm_scale is None, (
                "bias/residual/operand2/norm_scale operands require an "
                "XYZConfig.epilogue")
        return
    if ep.bias:
        assert bias is not None, "epilogue.bias set but no bias operand"
    if ep.residual:
        assert residual is not None, (
            "epilogue.residual set but no residual operand")
    if ep.gate != "none":
        assert operand2 is not None, (
            "epilogue.gate set but no operand2")
    if ep.norm != "none":
        assert norm_scale is not None, (
            "epilogue.norm set but no norm_scale operand")


def xyz_matmul(
    x: jnp.ndarray,
    w_xyz: jnp.ndarray,
    *,
    mesh: Mesh,
    cfg: XYZConfig,
    batch_sharded: bool = True,
    bias: Optional[jnp.ndarray] = None,
    residual: Optional[jnp.ndarray] = None,
    operand2: Optional[jnp.ndarray] = None,
    norm_scale: Optional[jnp.ndarray] = None,
):
    """out[..., N] = epilogue(x[..., K] @ W), distributed per the XYZ plan.

    ``w_xyz`` is in xyz layout ([model, K/Y, N/Z], sharded on dim 0).
    Output is N-sharded over the model axis in natural chunk order; ``x``
    is row-sharded over the data axes (X) and either replicated over model
    ('replicated' — the broadcast) or K-sharded in natural order
    ('ksharded' — a previous layer's output).

    ``bias`` is replicated ``[N]``; ``residual`` and ``operand2`` (the
    gate epilogue's second tensor) match the OUTPUT (N-sharded over
    model).  With ``cfg.epilogue.quantize`` the return is ``(q [..., N]
    int8, scale [..., model] f32)`` with per-N-shard rowwise scales.

    ``norm='rmsnorm'`` epilogues need the FULL output row for the mean of
    squares, which an N-sharded output never holds — they are valid here
    only on a model==1 mesh; multi-shard callers use
    ``xyz_matmul_replicated_out`` (full N on every replica after the
    psum) instead.
    """
    model = model_size(mesh)
    ep = cfg.epilogue
    _check_epilogue_operands(ep, bias, residual, operand2, norm_scale)
    if ep is not None and ep.norm != "none" and model > 1:
        raise ValueError(
            "norm epilogues need the full output row; xyz_matmul shards N "
            "over the model axis — use xyz_matmul_replicated_out "
            "(Y == model) or fall back to a standalone norm")
    if model == 1:
        from repro.kernels.quantize import QuantizedWeight
        if isinstance(w_xyz, QuantizedWeight):
            # int8 serving path: the single-shard xyz layout [1, K, N] is
            # consumed as the quantized matrix directly (kops.matmul
            # quantizes x rowwise and folds both scales into the store
            # phase — no dequantized fp32 weight ever materializes)
            w = w_xyz
        else:
            w = unshard_weight_xyz(w_xyz, cfg.y)
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        if ep is None:
            out = _local_matmul(x2, w)
            return out.astype(cfg.out_dtype or x.dtype).reshape(*lead, -1)
        ep1 = dataclasses.replace(
            ep, out_dtype=ep.out_dtype or cfg.out_dtype or x.dtype)
        res2 = residual.reshape(-1, residual.shape[-1]) \
            if residual is not None else None
        o2 = operand2.reshape(-1, operand2.shape[-1]) \
            if operand2 is not None else None
        out = _local_matmul(x2, w, epilogue=ep1, bias=bias, residual=res2,
                            operand2=o2, norm_scale=norm_scale)
        if ep1.quantize:
            q, s = out
            return (q.reshape(*lead, -1), s.reshape(*lead, -1))
        if ep1.norm != "none":
            value, normed = out
            return (value.reshape(*lead, -1), normed.reshape(*lead, -1))
        return out.reshape(*lead, -1)

    y, z = cfg.y, cfg.z(model)
    from repro.core.sharding import row_axes
    row_spec = row_axes(mesh, x.shape[0]) if batch_sharded else None
    mid = [None] * (x.ndim - 2)

    x_spec = P(row_spec, *mid,
               "model" if cfg.x_layout == "ksharded" else None)
    out_spec = P(row_spec, *mid, "model")

    ygroups = _y_groups(model, y)
    zgroups = _z_groups(model, y)
    wire_dtype = cfg.out_dtype or x.dtype
    n_total = w_xyz.shape[-1] * z          # global N
    nloc_out = n_total // model            # every device emits N-chunk md

    def _finish(out2, md, res2, o2):
        """Post-reduction epilogue on the device's [rows, N/model] shard.
        Elementwise per output element (gate included — operand2 is
        sharded exactly like the output), so applying it after ANY of the
        four reductions preserves the bitwise cross-schedule contract."""
        if ep is None or (ep.is_identity and ep.out_dtype is None):
            return out2.astype(wire_dtype)
        b_loc = jax.lax.dynamic_slice_in_dim(
            bias, md * nloc_out, nloc_out, axis=-1) if ep.bias else None
        return apply_epilogue(out2, dataclasses.replace(
            ep, out_dtype=ep.out_dtype or wire_dtype), bias=b_loc,
            residual=res2, operand2=o2)

    def body(*args):
        xl, wl = args[0], args[1]
        pos = 2
        res_l = None
        if ep is not None and ep.residual:
            res_l = args[pos]
            pos += 1
        op2_l = None
        if ep is not None and ep.gate != "none":
            op2_l = args[pos]
            pos += 1
        wl = wl[0]  # [K/Y, N/Z]
        md = jax.lax.axis_index("model")
        yid = jax.lax.rem(md, y)
        lead = xl.shape[:-1]
        x2 = xl.reshape(-1, xl.shape[-1])
        res2 = res_l.reshape(-1, res_l.shape[-1]) if res_l is not None \
            else None
        o2 = op2_l.reshape(-1, op2_l.shape[-1]) if op2_l is not None \
            else None

        gather_partial = None
        if cfg.x_layout == "replicated":
            x2 = _slice_k_block(x2, yid, y, model)
        elif z > 1 and y > 1:
            # overlapped all-gather: the Y-block is never materialized —
            # each natural-order K-piece hops in by ppermute and is
            # consumed by its GEMM immediately (every schedule shares this
            # partial, keeping numerics schedule-independent)
            gather_partial = _overlapped_gather_partial(
                x2, wl, "model", zgroups, z, y, wire_dtype)
        elif z > 1:
            # Y == 1: no chunk GEMMs to overlap with — barrier-gather the
            # Y-block from natural-order K shards (the z-subgroup gather
            # concatenates chunks {y, Y+y, ...} in order, exactly the
            # interleaved block the weight layout expects) so the whole
            # epilogue stays fused in the kernel's store phase below.
            x2 = jax.lax.all_gather(x2, "model", axis_index_groups=zgroups,
                                    axis=1, tiled=True)

        nz = wl.shape[-1]
        if y == 1:
            # no reduction: the WHOLE epilogue fuses into the kernel's
            # store phase (bias sliced to this device's N-block).
            if ep is None:
                out = _local_matmul(x2, wl, out_dtype=wire_dtype)
            else:
                ep1 = dataclasses.replace(
                    ep, out_dtype=ep.out_dtype or wire_dtype)
                b_loc = jax.lax.dynamic_slice_in_dim(
                    bias, md * nloc_out, nloc_out, axis=-1) \
                    if ep.bias else None
                out = _local_matmul(x2, wl, epilogue=ep1, bias=b_loc,
                                    residual=res2, operand2=o2)
        else:
            # the wire format (and its AD transpose buffers) stays 16-bit
            # when out_dtype says so; the rank-order reduction upcasts.
            assert nz % y == 0, (nz, y)  # else chunking silently drops cols
            chunk = nz // y
            if gather_partial is not None:
                # ksharded Z>1: the GEMM work already ran inside the
                # overlapped gather — chunks are slices of ONE partial
                def chunk_fn(c):
                    return jax.lax.dynamic_slice_in_dim(
                        gather_partial, c * chunk, chunk, axis=-1)
            else:
                def chunk_fn(c):
                    return _chunk_gemm(x2, wl, c, chunk, wire_dtype)
            rows2 = x2.shape[0]
            if cfg.schedule == "allreduce":
                partial = _partial_from_chunks(chunk_fn, y)
                red = jax.lax.psum(partial, "model",
                                   axis_index_groups=ygroups)
                out = jax.lax.dynamic_slice_in_dim(
                    red, yid * chunk, chunk, axis=-1)
            elif cfg.schedule == "reduce_scatter":
                partial = _partial_from_chunks(chunk_fn, y)
                out = jax.lax.psum_scatter(
                    partial, "model", scatter_dimension=partial.ndim - 1,
                    axis_index_groups=ygroups, tiled=True)
            elif cfg.schedule == "ring":
                out = _ring_collective_matmul(chunk_fn, yid, "model",
                                              ygroups, y, rows2, chunk,
                                              wire_dtype)
            elif cfg.schedule == "bidir_ring":
                out = _bidir_ring_collective_matmul(chunk_fn, yid, "model",
                                                    ygroups, y, rows2,
                                                    chunk, wire_dtype)
            else:  # unreachable: XYZConfig.__post_init__ validates
                raise ValueError(cfg.schedule)
            if ep is not None:
                out = _finish(out, md, res2, o2)

        if ep is not None and ep.quantize:
            q, s = out
            return (q.reshape(*lead, -1), s.reshape(*lead, -1))
        out = out.astype(ep.out_dtype if ep is not None and ep.out_dtype
                         else wire_dtype)
        return out.reshape(*lead, -1)

    in_specs = [x_spec, P("model", None, None)]
    operands = [x, w_xyz]
    if ep is not None and ep.residual:
        assert residual is not None
        in_specs.append(P(row_spec, *mid, "model"))
        operands.append(residual)
    if ep is not None and ep.gate != "none":
        assert operand2 is not None
        # the gate operand is an [.., N] tensor matching the OUTPUT
        # sharding (the gated MLP's g matches the up GEMM's output)
        in_specs.append(P(row_spec, *mid, "model"))
        operands.append(operand2)
    if ep is not None and ep.quantize:
        out_specs = (out_spec, P(row_spec, *mid, "model"))
    else:
        out_specs = out_spec
    return _shard_map(body, mesh, tuple(in_specs), out_specs)(*operands)


def xyz_matmul_replicated_out(
    x: jnp.ndarray,
    w_xyz: jnp.ndarray,
    *,
    mesh: Mesh,
    cfg: XYZConfig,
    batch_sharded: bool = True,
    bias: Optional[jnp.ndarray] = None,
    residual: Optional[jnp.ndarray] = None,
    operand2: Optional[jnp.ndarray] = None,
    norm_scale: Optional[jnp.ndarray] = None,
):
    """Row-parallel variant with fully replicated (over model) output:
    Y = model, Z = 1, one psum/ring-allreduce — the classic Megatron
    down-projection.  Used when the next op needs the full feature
    dimension on every device (residual adds on replicated activations).

    The epilogue (bias [N], residual / operand2 [.., N] replicated) is
    applied after the psum on every replica — still inside the shard_map
    body, so XLA fuses it into the all-reduce consumer.  Because every
    replica holds the FULL feature row post-psum, this is the multi-shard
    home of the ``norm='rmsnorm'`` epilogue: the down-projection emits
    ``(h_new, rmsnorm(h_new))`` and the next block's input norm never
    re-reads the residual stream."""
    model = model_size(mesh)
    ep = cfg.epilogue
    _check_epilogue_operands(ep, bias, residual, operand2, norm_scale)
    if model == 1:
        return xyz_matmul(x, w_xyz, mesh=mesh, cfg=cfg,
                          batch_sharded=batch_sharded, bias=bias,
                          residual=residual, operand2=operand2,
                          norm_scale=norm_scale)
    assert cfg.y == model, "replicated-out requires Y == model"
    from repro.core.sharding import row_axes
    row_spec = row_axes(mesh, x.shape[0]) if batch_sharded else None
    mid = [None] * (x.ndim - 2)
    x_spec = P(row_spec, *mid,
               "model" if cfg.x_layout == "ksharded" else None)
    wire_dtype = cfg.out_dtype or x.dtype

    def body(*args):
        xl, wl = args[0], args[1]
        pos = 2
        res_l = None
        if ep is not None and ep.residual:
            res_l = args[pos]
            pos += 1
        op2_l = None
        if ep is not None and ep.gate != "none":
            op2_l = args[pos]
            pos += 1
        ns_l = None
        if ep is not None and ep.norm != "none":
            ns_l = args[pos]
            pos += 1
        wl = wl[0]
        md = jax.lax.axis_index("model")
        lead = xl.shape[:-1]
        x2 = xl.reshape(-1, xl.shape[-1])
        if cfg.x_layout == "replicated":
            x2 = _slice_k_block(x2, md, model, model)
        # wire cast fused into the kernel's store phase
        partial = _local_matmul(x2, wl, out_dtype=wire_dtype)
        out = jax.lax.psum(partial, "model")
        if ep is not None:
            res2 = res_l.reshape(-1, res_l.shape[-1]) if res_l is not None \
                else None
            o2 = op2_l.reshape(-1, op2_l.shape[-1]) if op2_l is not None \
                else None
            out = apply_epilogue(out, dataclasses.replace(
                ep, out_dtype=ep.out_dtype or wire_dtype), bias=bias,
                residual=res2, operand2=o2, norm_scale=ns_l)
            if ep.quantize:
                q, s = out
                return (q.reshape(*lead, -1), s.reshape(*lead, -1))
            if ep.norm != "none":
                value, normed = out
                return (value.reshape(*lead, -1),
                        normed.reshape(*lead, -1))
        return out.reshape(*lead, -1)

    in_specs = [x_spec, P("model", None, None)]
    operands = [x, w_xyz]
    if ep is not None and ep.residual:
        assert residual is not None
        in_specs.append(P(row_spec, *mid, None))
        operands.append(residual)
    if ep is not None and ep.gate != "none":
        assert operand2 is not None
        in_specs.append(P(row_spec, *mid, None))
        operands.append(operand2)
    if ep is not None and ep.norm != "none":
        assert norm_scale is not None
        in_specs.append(P(None))
        operands.append(norm_scale)
    if ep is not None and (ep.quantize or ep.norm != "none"):
        out_specs = (P(row_spec, *mid, None), P(row_spec, *mid, None))
    else:
        out_specs = P(row_spec, *mid, None)
    return _shard_map(body, mesh, tuple(in_specs), out_specs)(*operands)
