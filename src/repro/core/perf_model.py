"""Analytical performance/power model reproducing the paper's evaluation.

The model is calibrated exactly the way the paper calibrates its own
analytical optimization (§IV-C, §V): single-kernel latencies anchor to the
AIE-simulator measurements of Table I; the array-level efficiency is a
per-(precision, placement-pattern) constant fitted once to the simulator
results (the paper attributes the array-level loss to lock/stream overhead
and PnR buffer decisions — §V-B3); core power is linear in the number of
MatMul-kernel cores and adder-tree cores (exact to <0.4% on all 12
reported rows); memory banks / memory power are PnR+XPE measurements and
are kept as per-config lookups for the reported rows.

Everything here is validated against the paper in
``tests/test_perf_model.py`` and surfaced in ``benchmarks/table*.py``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.core.device_model import AIE_VC1902, AIEDevice
from repro.core.planner import ArrayConfig, KernelTile

# ---------------------------------------------------------------------------
# Single-kernel latency model (anchored to Table I)
# ---------------------------------------------------------------------------

# Fixed pipeline fill / loop-setup overhead cycles, calibrated on Table I.
_MATMUL_OVERHEAD_CYC = {"int8": 51, "fp32": 233}
_ADD_OVERHEAD_CYC = {"int8": 36, "fp32": 39}
_ADD_PEAK_OPS = 8  # vector add lanes counted as "MACs/cyc" in Table I


def matmul_kernel_cycles(tile: KernelTile, precision: str,
                         device: AIEDevice = AIE_VC1902) -> int:
    ideal = tile.macs / device.peak_macs[precision]
    return int(round(ideal + _MATMUL_OVERHEAD_CYC[precision]))


def matmul_kernel_efficiency(tile: KernelTile, precision: str,
                             device: AIEDevice = AIE_VC1902) -> float:
    cyc = matmul_kernel_cycles(tile, precision, device)
    return (tile.macs / cyc) / device.peak_macs[precision]


def add_kernel_cycles(m: int, n: int, precision: str) -> int:
    return int(round(m * n / _ADD_PEAK_OPS + _ADD_OVERHEAD_CYC[precision]))


def add_kernel_efficiency(m: int, n: int, precision: str) -> float:
    return (m * n / add_kernel_cycles(m, n, precision)) / _ADD_PEAK_OPS


def adder_tree_cycles(y: int, m: int, n: int, precision: str) -> int:
    """(Y-1) sequential Add kernels on one AIE core (paper §IV-B)."""
    return (y - 1) * add_kernel_cycles(m, n, precision)


# ---------------------------------------------------------------------------
# Array-level throughput model (anchored to Tables II/III)
# ---------------------------------------------------------------------------

# Array efficiency: fraction of aggregate single-kernel throughput realized
# by the full-array design.  Fitted per (precision, pattern); the paper's
# six reported configs deviate from these means by <0.6%.
_ARRAY_EFF = {
    ("fp32", "P1"): 0.92462,
    ("fp32", "P2"): 0.95617,
    ("int8", "P1"): 0.80912,
    ("int8", "P2"): 0.82914,
}

# Core power: watts per MatMul-kernel core and per adder-tree core, fitted
# on Tables II/III (fp32 max error 0.27%, int8 max error 0.32%).
_CORE_POWER_W = {
    "fp32": {"matmul": 0.072636, "adder": 0.037917},
    "int8": {"matmul": 0.150096, "adder": 0.023333},
}

# PnR/XPE measurements for the paper's reported configs: (precision, X, Y, Z)
# -> (memory_banks, dma_banks, memory_power_W).  These come from the AIE
# place-and-route + XPE tools and are not analytically derivable.
_REPORTED_MEMORY = {
    ("fp32", 13, 4, 6): (3138, 18, 18.21),
    ("fp32", 10, 3, 10): (3190, 0, 19.12),
    ("fp32", 11, 4, 7): (3106, 18, 18.65),
    ("fp32", 11, 3, 9): (3176, 0, 18.78),
    ("fp32", 12, 4, 6): (2934, 16, 16.91),
    ("fp32", 12, 3, 8): (3092, 0, 17.60),
    ("int8", 13, 4, 6): (3112, 18, 18.18),
    ("int8", 10, 3, 10): (3194, 0, 19.08),
    ("int8", 11, 4, 7): (3096, 18, 18.62),
    ("int8", 11, 3, 9): (3178, 0, 18.79),
    ("int8", 12, 4, 6): (2918, 16, 16.98),
    ("int8", 12, 3, 8): (3080, 0, 17.53),
}

# Paper-reported throughput rows (ground truth for validation).
PAPER_THROUGHPUT = {
    ("fp32", 13, 4, 6): 5442.11,  # GFLOPs
    ("fp32", 10, 3, 10): 5405.33,
    ("fp32", 11, 4, 7): 5414.39,
    ("fp32", 11, 3, 9): 5382.27,
    ("fp32", 12, 4, 6): 5031.19,
    ("fp32", 12, 3, 8): 5225.05,
    ("int8", 13, 4, 6): 77.01,    # TOPs
    ("int8", 10, 3, 10): 76.08,
    ("int8", 11, 4, 7): 75.67,
    ("int8", 11, 3, 9): 74.66,
    ("int8", 12, 4, 6): 71.25,
    ("int8", 12, 3, 8): 72.93,
}

PAPER_TOTAL_POWER_W = {
    ("fp32", 13, 4, 6): 43.83,
    ("fp32", 10, 3, 10): 44.66,
    ("fp32", 11, 4, 7): 44.01,
    ("fp32", 11, 3, 9): 44.13,
    ("fp32", 12, 4, 6): 40.68,
    ("fp32", 12, 3, 8): 42.28,
    ("int8", 13, 4, 6): 66.83,
    ("int8", 10, 3, 10): 65.52,
    ("int8", 11, 4, 7): 66.79,
    ("int8", 11, 3, 9): 65.83,
    ("int8", 12, 4, 6): 62.13,
    ("int8", 12, 3, 8): 63.24,
}

# State-of-the-art CHARM [19], [34] reference points (paper §V-B).
CHARM = {
    "fp32": {
        "throughput_gflops": 4504.46,
        "power_w": 43.69,
        "energy_eff": 103.10,
        "matmul_kernels": 384,
        "cores": 384,
        "memory_banks": 3086,
        "plios": 80,
    },
    "int8": {
        # 28.15 TOPs reported at 1 GHz in [34]; scaled to 1.25 GHz (§V-B2).
        "throughput_tops_1ghz": 28.15,
        "throughput_tops": 28.15 * 1.25,
        "cores": 192,
    },
    "mlp_fp32": {
        # §V-B4: MLP inference, CHARM scaled to 1.25 GHz vs MaxEVA.
        "charm_gflops": 3670.88,
        "maxeva_gflops": 4735.94,
    },
}

_TILES = {
    "int8": KernelTile(32, 128, 32, 32 * 128 * 32, 12288),
    "fp32": KernelTile(32, 32, 32, 32 * 32 * 32, 12288),
}


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    precision: str
    cfg: ArrayConfig
    tile: KernelTile
    throughput: float            # GFLOPs for fp32, TOPs for int8
    core_power_w: float
    memory_power_w: Optional[float]
    total_power_w: Optional[float]
    energy_eff: Optional[float]  # GFLOPs/W or TOPs/W
    memory_banks: Optional[int]
    dma_banks: int
    plios: int


def kernel_tile(precision: str) -> KernelTile:
    return _TILES[precision]


def design_throughput(cfg: ArrayConfig, precision: str,
                      device: AIEDevice = AIE_VC1902,
                      tile: Optional[KernelTile] = None) -> float:
    """Array throughput in GFLOPs (fp32) / TOPs (int8)."""
    tile = tile or _TILES[precision]
    cyc = matmul_kernel_cycles(tile, precision, device)
    per_kernel_ops = 2.0 * tile.macs / cyc * device.freq_hz
    eff = _ARRAY_EFF[(precision, cfg.pattern)]
    total = cfg.matmul_kernels * per_kernel_ops * eff
    return total / 1e9 if precision == "fp32" else total / 1e12


def design_core_power(cfg: ArrayConfig, precision: str) -> float:
    p = _CORE_POWER_W[precision]
    return cfg.matmul_kernels * p["matmul"] + cfg.adder_cores * p["adder"]


def evaluate_design(cfg: ArrayConfig, precision: str,
                    device: AIEDevice = AIE_VC1902) -> DesignPoint:
    tile = _TILES[precision]
    tput = design_throughput(cfg, precision, device, tile)
    core_p = design_core_power(cfg, precision)
    mem = _REPORTED_MEMORY.get((precision, cfg.x, cfg.y, cfg.z))
    if mem is not None:
        banks, dma, mem_p = mem
        total = core_p + mem_p
        # energy eff in GFLOPs/W (fp32) or TOPs/W (int8)
        eff = tput / total
        return DesignPoint(precision, cfg, tile, tput, core_p, mem_p, total,
                           eff, banks, dma, cfg.plio_in + cfg.plio_out)
    return DesignPoint(precision, cfg, tile, tput, core_p, None, None, None,
                       None, cfg.dma_banks, cfg.plio_in + cfg.plio_out)


# ---------------------------------------------------------------------------
# Fig. 8: performance vs. (square) matrix size, with zero-padding
# ---------------------------------------------------------------------------


def padded(v: int, multiple: int) -> int:
    return multiple * math.ceil(v / multiple)


def throughput_vs_size(size: int, cfg: ArrayConfig, precision: str,
                       device: AIEDevice = AIE_VC1902) -> float:
    """Effective throughput for a square ``size^3`` MatMul, assuming PL-side
    tiling with zero padding to the design's native macro-tile (paper
    §V-B4)."""
    tile = _TILES[precision]
    mm = cfg.x * tile.m
    kk = cfg.y * tile.k
    nn = cfg.z * tile.n
    useful = float(size) ** 3
    padded_work = float(padded(size, mm)) * padded(size, kk) * padded(size, nn)
    return design_throughput(cfg, precision, device, tile) * useful / padded_work


# ---------------------------------------------------------------------------
# TPU-mode: fused-epilogue HBM savings (the §IV-C ping-pong analogue)
# ---------------------------------------------------------------------------


def fused_epilogue_savings(m: int, n: int, epilogue,
                           device=None) -> Dict[str, float]:
    """Bytes and roofline seconds the fused epilogue saves for an [m, n]
    GEMM output vs. the unfused write + read-back + write sequence.

    The paper's single-kernel efficiency rests on partials never touching
    slow memory (§IV-C ping-pong buffers, §IV-B on-array adder tree); the
    TPU analogue is the fp32 accumulator round trip through HBM that the
    ``Epilogue`` spec deletes.  Consumed by ``core.planner`` when scoring
    blocks/schedules and surfaced by ``benchmarks/fused_epilogue.py``.
    """
    from repro.core.device_model import TPU_V5E
    from repro.core.planner import epilogue_hbm_bytes
    device = device or TPU_V5E
    unfused = epilogue_hbm_bytes(m, n, epilogue, fused=False)
    fused = epilogue_hbm_bytes(m, n, epilogue, fused=True)
    saved = unfused - fused
    return {
        "bytes_unfused": float(unfused),
        "bytes_fused": float(fused),
        "bytes_saved": float(saved),
        "seconds_saved": saved / device.hbm_bw,
        "savings_frac": saved / max(unfused, 1),
    }


def collective_overlap_savings(m_loc: int, n_loc: int, y: int,
                               z: int = 1, a_bytes: int = 0,
                               device=None) -> Dict[str, float]:
    """Per-link wire economics of the reduction schedules for one
    ``[m_loc, n_loc]`` fp32 partial, plus the gather side.

    The MaxEVA lesson priced here: throughput is won by overlapping data
    movement with compute and balancing traffic across the interconnect.
    'bidir_ring' splits every chunk across the two ring directions, so
    each full-duplex link carries HALF the bytes of 'ring'
    (``bidir_link_ratio`` ~ 0.5); the overlapped chunked gather moves the
    same bytes as the barrier ``all_gather`` but off the critical path
    (``gather_s_serial`` is what the overlap deletes from the step).
    Consumed by ``benchmarks/fused_epilogue.py`` derived columns and
    asserted in ``tests/test_planner.py``.
    """
    from repro.core.device_model import TPU_V5E
    from repro.core.planner import (gather_wire_bytes_per_link,
                                    reduction_wire_bytes_per_link)
    device = device or TPU_V5E
    c_bytes = m_loc * n_loc * 4
    out: Dict[str, float] = {}
    for sched in ("allreduce", "reduce_scatter", "ring", "bidir_ring"):
        out[f"link_bytes_{sched}"] = reduction_wire_bytes_per_link(
            c_bytes, y, sched)
    out["bidir_link_ratio"] = (out["link_bytes_bidir_ring"]
                               / max(out["link_bytes_ring"], 1e-9))
    out["wire_s_ring"] = out["link_bytes_ring"] / device.ici_bw_per_link
    out["wire_s_bidir_ring"] = (out["link_bytes_bidir_ring"]
                                / device.ici_bw_per_link)
    out["link_bytes_gather"] = gather_wire_bytes_per_link(a_bytes, z)
    out["gather_s_serial"] = (out["link_bytes_gather"]
                              / device.ici_bw_per_link)
    return out


def gemm_arithmetic_intensity(m: int, k: int, n: int, dtype: str = "bf16",
                              out_itemsize: Optional[int] = None) -> float:
    """FLOPs per HBM byte of an ``[m, k] x [k, n]`` GEMM at the given
    precision (the roofline x-coordinate).  int8 operands quadruple the
    intensity of the same shape vs fp32 — the reason the paper's int8
    pipeline reaches 14x fp32 throughput only while tensors STAY int8
    between GEMMs."""
    from repro.core.device_model import DTYPE_BYTES
    eb = DTYPE_BYTES[dtype]
    ob = eb if out_itemsize is None else out_itemsize
    by = (m * k + k * n) * eb + m * n * ob
    if dtype == "int8":
        by += 4 * (m + n)  # row/col scale vectors
    return 2.0 * m * k * n / by


def int8_serving_savings(m: int, k: int, n: int,
                         device=None) -> Dict[str, float]:
    """What the end-to-end int8 GEMM buys over the fp32-bounce baseline
    for one ``[m, k] x [k, n]`` projection (serving decode: m = batch).

    ``bytes_*``/``seconds_saved`` follow ``planner.int8_gemm_hbm_bytes``:
    the fused path streams int8 operands + scale vectors once; the bounce
    path dequantizes both operands through HBM and round-trips the fp32
    result.  ``compute_speedup`` is the MXU-rate ratio (int8 runs the
    systolic array at twice bf16, 8x fp32 on v5e); decode is
    bandwidth-bound, so the byte ratio is the one that shows up in step
    time.  Consumed by ``benchmarks/int8_decode.py`` and the planner
    tests."""
    from repro.core.device_model import TPU_V5E
    from repro.core.planner import int8_gemm_hbm_bytes
    device = device or TPU_V5E
    fused = int8_gemm_hbm_bytes(m, k, n, fused=True)
    bounced = int8_gemm_hbm_bytes(m, k, n, fused=False)
    return {
        "bytes_int8_fused": float(fused),
        "bytes_fp32_bounce": float(bounced),
        "bytes_saved": float(bounced - fused),
        "seconds_saved": (bounced - fused) / device.hbm_bw,
        "hbm_speedup": bounced / max(fused, 1),
        "compute_speedup": (device.peak_flops["int8"]
                            / device.peak_flops["fp32"]),
        "intensity_int8": gemm_arithmetic_intensity(m, k, n, "int8",
                                                    out_itemsize=1),
        "intensity_fp32": gemm_arithmetic_intensity(m, k, n, "fp32"),
    }


def mlp_inference_gflops(layer_dims: List[int], batch: int,
                         cfg: ArrayConfig, precision: str = "fp32") -> float:
    """End-to-end MLP MatMul throughput under the Fig. 8 padding model.
    Used to reproduce the §V-B4 MLP claim (+29% over CHARM)."""
    tile = _TILES[precision]
    mm, kk, nn = cfg.x * tile.m, cfg.y * tile.k, cfg.z * tile.n
    peak = design_throughput(cfg, precision, AIE_VC1902, tile)
    useful = 0.0
    padded_work = 0.0
    for d_in, d_out in zip(layer_dims[:-1], layer_dims[1:]):
        useful += float(batch) * d_in * d_out
        padded_work += float(padded(batch, mm)) * padded(d_in, kk) * padded(d_out, nn)
    return peak * useful / padded_work
