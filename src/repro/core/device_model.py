"""Hardware device models for the MaxEVA planner.

Two device families are modelled:

* ``AIEDevice`` — the paper's target (AMD/Xilinx Versal AIE, VC1902 on the
  VCK190 board).  Used verbatim to reproduce the paper's analytical results
  (Tables I-III, Fig. 8) and to validate our implementation of the paper's
  optimization model (eq. 1-9).

* ``TPUDevice`` — the adaptation target (TPU v5e).  The same constraint
  *structure* (compute-rate bound, I/O-bandwidth bound, local-memory bound,
  array-level port/bandwidth bounds) is re-instantiated with the TPU memory
  hierarchy: HBM -> VMEM -> MXU, and ICI links replacing PLIO ports.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class AIEDevice:
    """Versal AIE device model (paper §III, §IV-C)."""

    name: str = "VC1902"
    rows: int = 8
    cols: int = 50
    freq_hz: float = 1.25e9
    # Peak MACs/cycle of one AIE core, per precision (paper §IV-C1).
    peak_macs: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"int8": 128, "fp32": 8}
    )
    # Stream / PLIO bandwidth in bytes per AIE cycle (paper eq. 2).
    bw_io_bytes_per_cyc: float = 4.0
    # Local data memory: 32KB in 8 x 4KB banks; 1 bank reserved for
    # stack/heap; remaining 28KB double-buffered -> 14KB per buffer set
    # (paper eq. 6).
    mem_bank_bytes: int = 4096
    mem_banks: int = 8
    usable_buffer_bytes: int = 14 * 1024
    # Array-level resources (paper eq. 7-9; VC1902 / VCK190).
    n_cores: int = 400
    plio_in: int = 78
    plio_out: int = 117

    # element sizes: accumulation is always 32-bit (paper §IV-C1).
    @staticmethod
    def sizeof_in(precision: str) -> int:
        return {"int8": 1, "fp32": 4}[precision]

    @staticmethod
    def sizeof_out(precision: str) -> int:
        return 4  # int32 or fp32 accumulators


@dataclasses.dataclass(frozen=True)
class TPUDevice:
    """TPU v5e device model (per-chip), used by the TPU-mode planner and the
    roofline analysis.  Constants fixed by the assignment:
    197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s per ICI link."""

    name: str = "TPUv5e"
    peak_flops: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {
            "bf16": 197e12,
            "fp32": 197e12 / 4,  # fp32 runs through the MXU at 1/4 rate
            "int8": 394e12,
        }
    )
    hbm_bw: float = 819e9           # bytes/s
    hbm_bytes: int = 16 * 2 ** 30   # 16 GiB per chip
    ici_bw_per_link: float = 50e9   # bytes/s, per direction, per link
    ici_links: int = 4              # 2D torus: +/-x, +/-y
    vmem_bytes: int = 16 * 2 ** 20  # ~16 MiB of VMEM per core
    # Fraction of VMEM the planner lets one kernel's working set claim
    # (compiler scratch, semaphores, pipelining headroom take the rest).
    vmem_budget_frac: float = 0.75
    # MXU native tile granularity: the systolic array is 128x128; the
    # minimal fp32/bf16 tile is (8, 128).
    mxu_dim: int = 128
    sublane: int = 8

    @property
    def vmem_budget(self) -> int:
        return int(self.vmem_bytes * self.vmem_budget_frac)

    def ridge_flops_per_byte(self, dtype: str = "bf16") -> float:
        """Arithmetic-intensity ridge point of the HBM roofline."""
        return self.peak_flops[dtype] / self.hbm_bw


DTYPE_BYTES = {
    "bf16": 2,
    "fp32": 4,
    "f32": 4,
    "int8": 1,
    "s8": 1,
    "int32": 4,
    "s32": 4,
}

AIE_VC1902 = AIEDevice()
TPU_V5E = TPUDevice()

# Mesh-level constants for the production deployment (single pod = 16x16
# chips = 256; multi-pod = 2 pods = 512).  Used for roofline math.
CHIPS_PER_POD = 256
PODS = 2
