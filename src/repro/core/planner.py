"""MaxEVA analytical planner.

Two levels, mirroring the paper (§IV-C):

1. Single-kernel optimization — choose the per-core tile ``M x K x N``
   (paper eq. 1-6).  On the AIE this is the per-AIE-core kernel; on TPU it
   is the Pallas block ``(bm, bk, bn)`` pipelined through VMEM.

2. Array-level optimization — choose the spatial decomposition ``X x Y x Z``
   (paper eq. 7-9): X shards the M dimension, Y shards the contraction K
   (reduced on-array by the adder tree / ``psum``), Z shards N.  On the AIE
   the constraints are core count and PLIO ports; on TPU they are HBM
   capacity and ICI wire-time.

Both searches are exhaustive over powers of two, exactly as the paper
argues is sufficient (§IV-C, §V-A).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.device_model import AIE_VC1902, TPU_V5E, AIEDevice, TPUDevice, DTYPE_BYTES


def _pow2_range(lo: int, hi: int) -> List[int]:
    out = []
    v = 1
    while v <= hi:
        if v >= lo:
            out.append(v)
        v *= 2
    return out


# ---------------------------------------------------------------------------
# 1. Single-kernel level (paper eq. 1-6)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelTile:
    m: int
    k: int
    n: int
    macs: int
    buffer_bytes: int

    def as_tuple(self) -> Tuple[int, int, int]:
        return (self.m, self.k, self.n)


def solve_aie_kernel_tiles(
    precision: str,
    device: AIEDevice = AIE_VC1902,
    eff_lb: float = 0.95,
    max_dim: int = 1024,
) -> List[KernelTile]:
    """Exhaustive power-of-two IP for M, K, N (paper eq. 3-6).

    Returns all maximal-MAC feasible tiles, sorted by (-macs, m, k, n).
    For int8 the paper reports a unique solution 32x128x32; for fp32 a
    family of ties at 32768 MACs including 32x32x32.
    """
    peak = device.peak_macs[precision]
    sa = device.sizeof_in(precision)
    sb = device.sizeof_in(precision)
    sc = device.sizeof_out(precision)
    bw = device.bw_io_bytes_per_cyc

    # eq. 3-5: I/O-bandwidth lower bounds on N, M, K.
    n_min = eff_lb * peak * sa / bw
    m_min = eff_lb * peak * sb / bw
    k_min = eff_lb * peak * sc / bw

    feas: List[KernelTile] = []
    for m, k, n in itertools.product(_pow2_range(1, max_dim), repeat=3):
        if n < n_min or m < m_min or k < k_min:
            continue
        # eq. 6: double-buffered working set fits the usable local memory.
        buf = m * k * sa + k * n * sb + m * n * sc
        if buf > device.usable_buffer_bytes:
            continue
        feas.append(KernelTile(m, k, n, m * k * n, buf))
    feas.sort(key=lambda t: (-t.macs, t.m, t.k, t.n))
    if not feas:
        return []
    best = feas[0].macs
    return [t for t in feas if t.macs == best]


# ---------------------------------------------------------------------------
# 2. Array level (paper eq. 7-9)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArrayConfig:
    x: int
    y: int
    z: int

    @property
    def matmul_kernels(self) -> int:
        return self.x * self.y * self.z

    @property
    def adder_cores(self) -> int:
        # One core runs the whole (Y-1)-kernel adder tree of each (x, z)
        # group (paper §IV-B); Y == 1 needs no reduction at all.
        return self.x * self.z if self.y > 1 else 0

    @property
    def total_cores(self) -> int:
        return self.matmul_kernels + self.adder_cores

    @property
    def plio_in(self) -> int:
        return self.x * self.y + self.y * self.z

    @property
    def plio_out(self) -> int:
        return self.x * self.z

    @property
    def pattern(self) -> str:
        # Placement patterns are proposed for Y=3 (P2, DMA-free) and Y=4
        # (P1, T-shapes with a little DMA) — paper §IV-D / Fig. 7.
        return {3: "P2", 4: "P1"}.get(self.y, "P?")

    @property
    def dma_banks(self) -> int:
        # P1's T-shapes spill one MatMul output buffer (double-buffered,
        # 4KB each half => 2 banks) per T-shape.  Fitted to the paper's
        # reported 16-18 banks for X=12..13: one T-shape per 'column pair'
        # of groups, empirically ~ceil(x*z/9) shapes. We model DMA banks
        # as reported by the paper for its configs (see perf_model tables)
        # and approximate 2*ceil(x*z/9) elsewhere.
        if self.pattern != "P1":
            return 0
        return 2 * math.ceil(self.x * self.z / 9)


def solve_aie_array(
    device: AIEDevice = AIE_VC1902,
    y_values: Sequence[int] = (2, 3, 4, 5, 6, 7, 8),
    top: int = 10,
    require_placement_pattern: bool = False,
) -> List[ArrayConfig]:
    """Exhaustive search of X, Y, Z maximizing MatMul kernels (eq. 7-9).

    ``require_placement_pattern`` restricts to Y in {3, 4}, the values for
    which the paper provides placement patterns (§IV-D).
    """
    cands: List[ArrayConfig] = []
    seen = set()
    for y in y_values:
        if require_placement_pattern and y not in (3, 4):
            continue
        for x in range(1, device.n_cores + 1):
            for z in range(1, device.n_cores // max(1, x * y) + 2):
                cfg = ArrayConfig(x, y, z)
                if cfg.total_cores > device.n_cores:
                    continue
                if cfg.plio_in > device.plio_in:
                    continue
                if cfg.plio_out > device.plio_out:
                    continue
                # X<->Z mirror images are identical designs (same kernel
                # count, same port usage); keep the X >= Z representative,
                # matching the paper's reporting.
                key = (y, min(x, z), max(x, z))
                if key in seen:
                    continue
                seen.add(key)
                cands.append(ArrayConfig(max(x, z), y, min(x, z)))
    cands.sort(key=lambda c: (-c.matmul_kernels, c.adder_cores, c.x))
    return cands[:top]


def pnr_feasible(cfg: ArrayConfig, device: AIEDevice = AIE_VC1902) -> bool:
    """Routability model calibrated on the paper's account (§V-B1): the
    MAC-maximal 10x4x8 point fails AIE place-and-route because it combines
    100% core utilization with pattern-P1 DMA routing; P2 designs route
    even at 100% utilization (10x3x10)."""
    full = cfg.total_cores >= device.n_cores
    return not (full and cfg.dma_banks > 0)


# ---------------------------------------------------------------------------
# 3. TPU-mode planner (hardware adaptation of eq. 1-9)
# ---------------------------------------------------------------------------


def epilogue_hbm_bytes(m: int, n: int, epilogue=None,
                       fused: bool = True) -> int:
    """HBM bytes the GEMM's output stage moves for an ``[m, n]`` result.

    fused:   the kernel stores the finished epilogue output once (plus the
             quantize scale column and any bias/residual operand reads).
    unfused: the kernel writes the fp32 accumulator, and a separate
             elementwise op reads it back and writes the final output —
             the 2 * 4 * m * n round trip the fusion deletes (the paper's
             §IV-C discipline of never letting partials touch slow
             memory, applied to the epilogue).

    The v2 algebra's stages price per their operand traffic: the gate
    stage reads a second ``[m, n]`` tensor either way, but unfused it
    also re-reads the GEMM output and re-writes the product (a whole
    extra elementwise pass); the rmsnorm stage writes a second ``[m, n]``
    output (the normed stream) plus the ``[n]`` scale either way, but
    unfused it re-reads the just-stored value and the standalone add +
    norm round-trips the residual stream once more — the read + write
    per block the fold deletes.
    """
    if epilogue is None:
        return 4 * m * n if fused else 3 * 4 * m * n
    item = epilogue.out_itemsize()
    gate = getattr(epilogue, "gate", "none") != "none"
    norm = getattr(epilogue, "norm", "none") != "none"
    out_b = m * n * item
    if epilogue.quantize:
        # scale vector: one f32 per row ('row') or per column ('col')
        out_b += (m if getattr(epilogue, "quantize_axis", "row") == "row"
                  else n) * 4
    operand_b = (n * 4 if epilogue.bias else 0) + (
        m * n * item if epilogue.residual else 0) + (
        m * n * item if gate else 0)
    if norm:
        out_b += m * n * item + 4 * n      # normed stream + scale vector
    if fused:
        return out_b + operand_b
    unfused = 2 * 4 * m * n + out_b + operand_b
    if gate:
        # standalone silu(g) * u: re-read the GEMM output, re-write the
        # product (the g read is already in operand_b)
        unfused += 2 * m * n * item
    if norm:
        # standalone add + rmsnorm: the residual stream's extra read +
        # write between the down projection and the next block
        unfused += 2 * m * n * item
    return unfused


def int8_gemm_hbm_bytes(m: int, k: int, n: int, fused: bool = True,
                        out_itemsize: int = 2) -> int:
    """HBM bytes of the serving int8 GEMM ``[m, k] x [k, n]``.

    fused:   the paper's pipeline (§IV-C1) — int8 operands stream in with
             their f32 row/col scale vectors, the int32 accumulator never
             leaves VMEM, scales are re-applied in the store phase, and
             ONE finished output is written.
    unfused: the fp32 *bounce* the serving path must avoid — both
             operands are dequantized to fp32 (int8 read + fp32 write +
             fp32 read-back each), the GEMM runs on 4-byte operands, and
             the fp32 result round-trips once more before the output
             store.  ``hlo_analysis.int8_bounce_count`` is the HLO-level
             guard against exactly this pattern.
    """
    a_b, w_b, o_b = m * k, k * n, m * n
    scales = 4 * (m + n)
    if fused:
        return a_b + w_b + scales + out_itemsize * o_b
    dequant = (a_b + 4 * a_b + 4 * a_b) + (w_b + 4 * w_b + 4 * w_b)
    return dequant + scales + 2 * 4 * o_b + out_itemsize * o_b


@dataclasses.dataclass(frozen=True)
class TPUBlockPlan:
    """Pallas block choice for one GEMM executed per-chip."""

    bm: int
    bk: int
    bn: int
    vmem_bytes: int
    macs: int
    # amortized HBM bytes moved per output element with this blocking
    hbm_bytes_per_flop: float


def reduction_wire_bytes_per_link(c_bytes: int, y: int,
                                  schedule: str) -> float:
    """Per-link wire bytes of the Y-subgroup reduction of a ``c_bytes``
    partial (the PLIO-port traffic analog, per ICI link).

    'allreduce' pays the RS+AG decomposition (2(Y-1)/Y); 'reduce_scatter'
    and 'ring' ship (Y-1)/Y of the partial over each link; 'bidir_ring'
    moves the SAME total bytes but splits every chunk across the two ring
    directions, so each (full-duplex) link carries half — the per-link
    traffic halving the Versal torus energy study identifies as the
    efficiency headroom.
    """
    if y <= 1 or schedule == "none":
        return 0.0
    if schedule == "allreduce":
        return 2.0 * (y - 1) / y * c_bytes
    if schedule in ("reduce_scatter", "ring"):
        return (y - 1) / y * c_bytes
    if schedule == "bidir_ring":
        return (y - 1) / (2.0 * y) * c_bytes
    raise ValueError(f"unknown schedule {schedule!r}")


def gather_wire_bytes_per_link(a_bytes: int, z: int) -> float:
    """Per-link bytes of (all-)gathering A over a Z-subgroup: each link
    carries (Z-1)/Z of the gathered block, whether the gather is the
    barrier ``all_gather`` (Y == 1) or the chunked ppermute ring the
    overlapped path uses (Y > 1) — the overlap changes WHEN the bytes
    move, not how many."""
    if z <= 1:
        return 0.0
    return (z - 1) / z * a_bytes


@dataclasses.dataclass(frozen=True)
class XYZShardPlan:
    """Array-level decomposition of one GEMM over mesh axes.

    x_shards: shards of M (data-parallel axis)
    y_shards: shards of K (contraction; needs on-array reduction = psum)
    z_shards: shards of N (column-parallel)
    schedule: 'allreduce' (P1 analog) | 'reduce_scatter' (P2 analog)
              | 'ring' / 'bidir_ring' (beyond-paper overlapped collective
                matmuls; bidir halves per-link reduction bytes)
              | 'none' (y_shards == 1)
    est_gather_s: per-link seconds of gathering a model-sharded A over the
                  Z-subgroup (0 when A is replicated or Z == 1)
    """

    x_shards: int
    y_shards: int
    z_shards: int
    schedule: str
    est_collective_s: float
    est_compute_s: float
    est_hbm_s: float
    est_gather_s: float = 0.0

    @property
    def est_step_s(self) -> float:
        """Step time under the schedule's overlap model: the overlapped
        collective matmuls ('ring' / 'bidir_ring') interleave chunk GEMMs
        with ppermute hops AND overlap the chunked gather of A, so compute
        and wire overlap (max; reduction and gather share the ICI links,
        so their times add inside the wire term).  The barrier reductions
        serialize the collective after the local GEMM, but their partial
        GEMMs still ride the chunked gather (max with compute).  Y == 1
        ('none') keeps the serial barrier gather before its single GEMM.
        """
        if self.y_shards <= 1 or self.schedule == "none":
            # no reduction AND no chunk GEMMs to hide the gather behind:
            # xyz_matmul keeps the serial barrier gather at Y == 1
            # whatever the schedule string says
            return max(self.est_hbm_s,
                       self.est_compute_s + self.est_gather_s)
        if self.schedule in ("ring", "bidir_ring"):
            return max(self.est_compute_s, self.est_hbm_s,
                       self.est_collective_s + self.est_gather_s)
        return max(self.est_hbm_s,
                   max(self.est_compute_s, self.est_gather_s)
                   + self.est_collective_s)


@dataclasses.dataclass(frozen=True)
class MatmulPlan:
    m: int
    k: int
    n: int
    dtype: str
    block: TPUBlockPlan
    shard: XYZShardPlan


def plan_tpu_block(
    m: int,
    k: int,
    n: int,
    dtype: str = "bf16",
    device: TPUDevice = TPU_V5E,
    accum_bytes: int = 4,
    epilogue=None,
) -> TPUBlockPlan:
    """Single-kernel level on TPU: pick the Pallas block (bm, bk, bn).

    The constraint structure mirrors eq. 1-6:
      * eq. 1 (efficiency bound)  -> MXU alignment: bm, bn, bk multiples of
        the systolic tile so the MXU runs at rated throughput;
      * eq. 2 (I/O bound)         -> HBM-bandwidth bound: streaming the A
        and B blocks must not take longer than the MXU needs for the block,
        i.e. bn, bm >= peak_flops * sizeof / (2 * hbm_bw)  (the roofline
        ridge point expressed per-dimension);
      * eq. 6 (local memory)      -> double-buffered A, B blocks plus the
        fp32 accumulator tile fit the VMEM budget.
    Objective identical to the paper: maximize MACs per block (data reuse
    in registers/VMEM), tie-break to the squarest block.
    """
    ebytes = DTYPE_BYTES[dtype]
    flops = device.peak_flops[dtype]
    # ridge-point lower bound (eq. 2 analog). 240 for bf16 on v5e.
    io_min = flops * ebytes / (2.0 * device.hbm_bw)

    def align_floor(v: int, a: int) -> int:
        return max(a, (v // a) * a)

    best: Optional[TPUBlockPlan] = None
    dim_cap = 4096
    full_row = epilogue is not None and epilogue.quantize
    if full_row:
        # rowwise scale needs the whole row in one block: the kernel runs
        # with bn = ceil128(n) regardless of the plan, so plan (and
        # account VMEM for) exactly that block.  With the block covering
        # all of N, A-reuse is maximal and eq. 2's bm bound is waived —
        # the fp32 accumulator row dominates VMEM instead.
        bn_candidates = [max(device.mxu_dim, 128 * ((n + 127) // 128))]
    else:
        bn_candidates = [bn for bn in _pow2_range(device.mxu_dim, dim_cap)
                         if bn <= max(n, device.mxu_dim) * 2]
    for bm in _pow2_range(device.sublane, dim_cap):
        if bm > max(m, device.sublane) * 2:
            continue
        for bn in bn_candidates:
            for bk in _pow2_range(device.mxu_dim, dim_cap):
                if bk > max(k, device.mxu_dim) * 2:
                    continue
                # eq. 2 analog: HBM streaming must keep up with the MXU,
                # unless the dimension is exhausted (block covers it).
                if bn < min(io_min, n) or (not full_row
                                           and bm < min(io_min, m)):
                    continue
                # eq. 6 analog: double-buffered in-blocks + accumulator.
                vmem = 2 * (bm * bk + bk * bn) * ebytes + bm * bn * accum_bytes
                if epilogue is not None:
                    # fused-epilogue operands share the pipeline: a bias
                    # row and/or a double-buffered residual tile join the
                    # working set (the store phase reads them in VMEM).
                    if epilogue.bias:
                        vmem += bn * 4
                    if epilogue.residual:
                        vmem += 2 * bm * bn * ebytes
                if vmem > device.vmem_budget:
                    continue
                macs = bm * bk * bn
                cand = TPUBlockPlan(
                    bm, bk, bn, vmem, macs,
                    hbm_bytes_per_flop=(bm * bk + bk * bn) * ebytes / (2 * macs),
                )
                key = (macs, -abs(math.log2(bm) - math.log2(bn)), -vmem)
                if best is None or key > (
                    best.macs,
                    -abs(math.log2(best.bm) - math.log2(best.bn)),
                    -best.vmem_bytes,
                ):
                    best = cand
    assert best is not None, "no feasible TPU block plan"
    return best


def _ring_collective_s(bytes_total: int, shards: int, device: TPUDevice) -> float:
    """Ring all-reduce time over one mesh axis (2(n-1)/n, the RS+AG
    decomposition) — kept for callers that want the schedule-agnostic
    upper bound; the planner itself now prices each schedule via
    ``reduction_wire_bytes_per_link``."""
    if shards <= 1 or bytes_total == 0:
        return 0.0
    return 2.0 * (shards - 1) / shards * bytes_total / device.ici_bw_per_link


def plan_tpu_shard(
    m: int,
    k: int,
    n: int,
    dtype: str,
    mesh_axes: Dict[str, int],
    device: TPUDevice = TPU_V5E,
    batch_axis: str = "data",
    model_axis: str = "model",
    a_sharded_on_model: bool = False,
    prefer_schedule: Optional[str] = None,
    epilogue=None,
) -> XYZShardPlan:
    """Array-level XYZ search on TPU (eq. 7-9 analog).

    X is fixed by the batch axis (M is activation rows).  The search is
    over the factorization of the model axis into Y (K-shards, reduced by
    the adder-tree analog: psum/psum_scatter) times Z (N-shards), plus the
    reduction schedule.  Constraints: per-device weight shard must fit a
    HBM fraction; objective: minimize the max of compute / HBM / wire time
    (the paper maximizes MatMul kernels subject to port limits; with fixed
    chip count the dual is minimizing the bottleneck term).
    """
    ebytes = DTYPE_BYTES[dtype]
    x = mesh_axes.get(batch_axis, 1)
    model = mesh_axes.get(model_axis, 1)
    flops = device.peak_flops[dtype]

    best: Optional[XYZShardPlan] = None
    y = 1
    while y <= model:
        z = model // y
        if y * z == model:
            m_loc = max(1, m // x)
            # per-device compute (eq. 1 analog at array scale)
            comp = 2.0 * m_loc * (k // y) * (n // z) / flops
            # per-device HBM traffic: activation in + weight shard, plus
            # the output stage.  A fused epilogue writes the finished
            # output once; the unfused baseline would round-trip the fp32
            # accumulator (epilogue_hbm_bytes accounts for the savings).
            in_bytes = (m_loc * (k // y) + (k // y) * (n // z)) * ebytes
            if dtype == "int8":
                # the quantized pipeline streams f32 scale vectors next to
                # the 1-byte operands (rowwise for A, colwise for W)
                in_bytes += 4 * (m_loc + n // z)
            out_bytes = epilogue_hbm_bytes(m_loc, n // z, epilogue,
                                           fused=True) \
                if epilogue is not None else m_loc * (n // z) * ebytes
            hbm = (in_bytes + out_bytes) / device.hbm_bw
            # wire bytes (PLIO analog):
            #  * A gathered over Z (paper: A_{x,y} broadcast Z times) --
            #    charged only if A arrives sharded over the model axis;
            #  * partial-C reduction over Y (the adder tree), priced
            #    per-link per schedule.
            a_bytes = m_loc * (k // y) * ebytes
            c_bytes = m_loc * (n // z) * 4  # 32-bit partials (fp32/int32)
            gather_s = 0.0
            if a_sharded_on_model:
                gather_s = gather_wire_bytes_per_link(a_bytes, z) \
                    / device.ici_bw_per_link
            if prefer_schedule is not None:
                scheds = [prefer_schedule]
            elif y == 1:
                scheds = ["none"]
            else:
                scheds = ["allreduce", "reduce_scatter", "ring",
                          "bidir_ring"]
            for sched in scheds:
                coll_s = reduction_wire_bytes_per_link(c_bytes, y, sched) \
                    / device.ici_bw_per_link
                cand = XYZShardPlan(x, y, z, sched, coll_s, comp, hbm,
                                    gather_s)
                # ties (compute- or HBM-bound points) break toward the
                # fewest per-link wire bytes, so 'bidir_ring' wins over
                # 'ring'/'reduce_scatter' exactly when wire cost is moot
                key = (cand.est_step_s, coll_s + gather_s)
                if best is None or key < (best.est_step_s,
                                          best.est_collective_s
                                          + best.est_gather_s):
                    best = cand
        y *= 2
    assert best is not None
    return best


def plan_tpu_matmul(
    m: int,
    k: int,
    n: int,
    dtype: str = "bf16",
    mesh_axes: Optional[Dict[str, int]] = None,
    device: TPUDevice = TPU_V5E,
    **shard_kwargs,
) -> MatmulPlan:
    mesh_axes = mesh_axes or {"data": 1, "model": 1}
    shard = plan_tpu_shard(m, k, n, dtype, mesh_axes, device, **shard_kwargs)
    # the per-device local GEMM that the Pallas block plan tiles
    m_loc = max(1, m // shard.x_shards)
    k_loc = max(1, k // shard.y_shards)
    n_loc = max(1, n // shard.z_shards)
    block = plan_tpu_block(m_loc, k_loc, n_loc, dtype, device,
                           epilogue=shard_kwargs.get("epilogue"))
    return MatmulPlan(m, k, n, dtype, block, shard)
