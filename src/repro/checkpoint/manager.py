"""Sharded, async, atomic checkpointing with elastic restore.

Layout per step:
    <dir>/step_000123.tmp/ ... -> atomically renamed to <dir>/step_000123/
        manifest.json   (tree structure, shapes, dtypes, hashes)
        arr_<n>.npy     (one file per leaf, logical/unsharded values)

Properties a 1000-node job needs:
  * ATOMIC: a crash mid-write leaves only a .tmp dir, never a truncated
    checkpoint; restore scans for the newest COMPLETE step.
  * ASYNC: serialization happens on a background thread from host copies,
    off the training thread.
  * INTEGRITY: per-leaf crc32 in the manifest, verified at restore.
  * ELASTIC: leaves are stored LOGICALLY (unsharded).  Restore takes the
    *target* mesh + specs and re-places every leaf — the job can come back
    on fewer/more devices, a different mesh shape, or a different
    partitioning (xyz-layout weights round-trip through
    ``unshard_weight_xyz`` if the Y factorization changes).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


def _flatten(tree: Any) -> Tuple[List[Any], Any]:
    return jax.tree.flatten(tree)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        leaves, treedef = _flatten(tree)
        # host copies first (cheap on CPU; device->host on TPU) so training
        # can proceed while the writer thread serializes
        host = [np.asarray(x) for x in leaves]
        self.wait()

        def write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "treedef": str(treedef), "leaves": []}
            for i, arr in enumerate(host):
                path = os.path.join(tmp, f"arr_{i}.npy")
                np.save(path, arr)
                manifest["leaves"].append({
                    "file": f"arr_{i}.npy",
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
                })
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            os.rename(tmp, final)  # atomic commit
            self._gc()

        if self.async_save and not blocking:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int], like: Any,
                mesh: Optional[Mesh] = None,
                specs: Optional[Any] = None,
                defs: Optional[Any] = None) -> Tuple[int, Any]:
        """Restore onto the CURRENT mesh/partitioning (elastic).

        ``like`` provides the tree structure; ``specs`` (PartitionSpec tree)
        + ``mesh`` re-place each leaf.  Returns (step, tree).

        ``defs`` (the model's ParamDef tree) additionally enables legacy
        migration: a checkpoint written with packed params stored as their
        separate views (e.g. wq/wk/wv instead of wqkv) is detected by its
        leaf count and packed in place, so pre-packing checkpoints restore
        transparently onto the packed schema.
        """
        if step is None:
            step = self.latest_step()
        assert step is not None, "no checkpoint found"
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = _flatten(like)
        if len(manifest["leaves"]) != len(leaves_like):
            assert defs is not None, (
                f"checkpoint at step {step} has "
                f"{len(manifest['leaves'])} leaves but the target tree "
                f"has {len(leaves_like)} — if this is a pre-packing "
                "(separate wq/wk/wv) checkpoint, pass defs=<ParamDef "
                "tree> to migrate it (Trainer/ServeEngine do this for "
                "fp32 optimizer state; packed_qkv=False on the config "
                "is the schema escape hatch)")
            return step, self._restore_legacy(d, manifest, like, mesh,
                                              specs, defs)
        spec_leaves = self._spec_leaves(specs, len(leaves_like))
        assert len(manifest["leaves"]) == len(leaves_like) == \
            len(spec_leaves), (len(manifest["leaves"]), len(leaves_like),
                               len(spec_leaves))
        out = []
        for meta, like_leaf, spec in zip(manifest["leaves"], leaves_like,
                                         spec_leaves):
            arr = self._load_leaf(d, meta)
            out.append(self._place(arr, mesh, spec))
        return step, jax.tree.unflatten(treedef, out)

    # -- legacy (unpacked-view) migration --------------------------------------

    def _restore_legacy(self, d: str, manifest, like: Any,
                        mesh: Optional[Mesh], specs: Optional[Any],
                        defs: Any):
        """Load a checkpoint whose packed params are stored as separate
        view leaves (the pre-``wqkv`` layout) and pack them in place."""
        from repro.models import param as pm
        legacy_like = pm.unpack_like(defs)
        legacy_leaves, legacy_def = _flatten(legacy_like)
        assert len(manifest["leaves"]) == len(legacy_leaves), (
            "checkpoint matches neither the packed nor the legacy schema",
            len(manifest["leaves"]), len(legacy_leaves))
        for meta, leaf in zip(manifest["leaves"], legacy_leaves):
            if isinstance(leaf, pm._PassThrough):
                continue  # non-ParamDef entry (e.g. optimizer step)
            assert tuple(meta["shape"]) == tuple(leaf.shape), (
                "legacy leaf shape mismatch (flatten-order drift?)",
                meta["file"], meta["shape"], leaf.shape)
        host = [self._load_leaf(d, meta) for meta in manifest["leaves"]]
        packed = pm.pack_tree(defs, jax.tree.unflatten(legacy_def, host))
        leaves, treedef = _flatten(packed)
        assert treedef == _flatten(like)[1], "migrated tree shape mismatch"
        spec_leaves = self._spec_leaves(specs, len(leaves))
        assert len(spec_leaves) == len(leaves), (len(spec_leaves),
                                                 len(leaves))
        out = [self._place(np.asarray(leaf), mesh, spec)
               for leaf, spec in zip(leaves, spec_leaves)]
        return jax.tree.unflatten(treedef, out)

    def export_legacy(self, step: int, tree: Any, defs: Any,
                      blocking: bool = True) -> None:
        """Reverse migration: save with every packed param split into its
        legacy view leaves (wqkv -> wq/wk/wv), for pre-packing tooling."""
        from repro.models import param as pm
        self.save(step, pm.split_tree(defs, tree), blocking=blocking)

    def _spec_leaves(self, specs: Optional[Any], n: int) -> List[Any]:
        from jax.sharding import PartitionSpec
        if specs is None:
            return [None] * n
        return jax.tree.leaves(
            specs,
            is_leaf=lambda s: s is None or isinstance(s, PartitionSpec))

    def _load_leaf(self, d: str, meta) -> np.ndarray:
        arr = np.load(os.path.join(d, meta["file"]))
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != meta["crc32"]:
            raise IOError(f"checkpoint corruption in {meta['file']}")
        return arr

    def _place(self, arr, mesh: Optional[Mesh], spec):
        if mesh is not None and spec is not None and mesh.devices.size > 1:
            return jax.device_put(arr, NamedSharding(mesh, spec))
        return jax.numpy.asarray(arr)
