"""Sharded, async, atomic, DURABLE checkpointing with elastic restore.

Layout per step:
    <dir>/step_000123.tmp/ ... -> atomically renamed to <dir>/step_000123/
        manifest.json   (tree structure, per-leaf shapes, dtypes, crc32s,
                         and the parameter's tree path)
        arr_<n>.npy     (one file per leaf, logical/unsharded values)

Properties a 1000-node job needs:
  * ATOMIC + DURABLE: every leaf and the manifest are fsync'd, the tmp
    directory is fsync'd before the rename and the parent directory
    after — a crash mid-write leaves only a .tmp dir (never a truncated
    checkpoint) and a crash right after ``save`` returns cannot lose a
    committed step to the page cache.  Restore scans for the newest
    COMPLETE step.
  * ASYNC with LOUD failures: serialization happens on a background
    thread from host copies, off the training thread — and a writer
    exception is stored and re-raised at the next synchronization point
    (``wait()`` or the next ``save()``), never dropped on the floor to be
    discovered at restore time.
  * INTEGRITY: per-leaf crc32 + shape/dtype in the manifest, verified at
    restore; any mismatch raises ``CheckpointCorruptionError`` naming the
    corrupted PARAMETER (its tree path), and ``restore(...,
    fallback=True)`` falls back to the newest earlier intact step instead
    of dying (the serving engine's default — stale weights beat no
    weights).
  * GC SAFETY: retention (``keep``) never deletes a step whose save is
    still in flight (pending steps are tracked and skipped).
  * ELASTIC: leaves are stored LOGICALLY (unsharded).  Restore takes the
    *target* mesh + specs and re-places every leaf — the job can come back
    on fewer/more devices, a different mesh shape, or a different
    partitioning (xyz-layout weights round-trip through
    ``unshard_weight_xyz`` if the Y factorization changes).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


class CheckpointCorruptionError(IOError):
    """A step failed integrity verification at restore.  ``param`` is the
    tree path of the corrupted parameter (or ``manifest.json``), so the
    operator knows WHAT is damaged, not just that numpy choked."""

    def __init__(self, step: int, param: str, reason: str):
        super().__init__(
            f"checkpoint step {step} corrupted at {param!r}: {reason}")
        self.step = step
        self.param = param
        self.reason = reason


def _flatten(tree: Any) -> Tuple[List[Any], Any]:
    return jax.tree.flatten(tree)


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_leaf(path: str, arr: np.ndarray) -> None:
    with open(path, "wb") as f:
        np.save(f, arr)
        f.flush()
        os.fsync(f.fileno())


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._pending: set = set()      # steps with a save in flight
        self._error: Optional[BaseException] = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        kp_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
        paths = [jax.tree_util.keystr(kp) for kp, _ in kp_leaves]
        # host copies first (cheap on CPU; device->host on TPU) so training
        # can proceed while the writer thread serializes
        host = [np.asarray(x) for _, x in kp_leaves]
        self.wait()  # serializes writers AND re-raises a prior async failure
        with self._lock:
            self._pending.add(step)

        def write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            try:
                os.makedirs(tmp, exist_ok=True)
                manifest = {"step": step, "treedef": str(treedef),
                            "leaves": []}
                for i, arr in enumerate(host):
                    _write_leaf(os.path.join(tmp, f"arr_{i}.npy"), arr)
                    manifest["leaves"].append({
                        "file": f"arr_{i}.npy",
                        "param": paths[i],
                        "shape": list(arr.shape),
                        "dtype": str(arr.dtype),
                        "crc32": zlib.crc32(
                            np.ascontiguousarray(arr).tobytes()),
                    })
                mpath = os.path.join(tmp, "manifest.json")
                with open(mpath, "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                _fsync_dir(tmp)
                os.rename(tmp, final)  # atomic commit
                _fsync_dir(self.dir)   # the rename itself must survive
            except BaseException as e:  # noqa: BLE001 — must not vanish
                with self._lock:
                    if self._error is None:  # keep the FIRST failure
                        self._error = e
                    self._pending.discard(step)
                shutil.rmtree(tmp, ignore_errors=True)
                return
            # durable from here on: the step may leave the pending set
            # (and is immediately eligible for its own retention policy)
            with self._lock:
                self._pending.discard(step)
            self._gc()

        if self.async_save and not blocking:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
            self._raise_pending_error()

    def wait(self):
        """Join an in-flight async save and re-raise its failure, if any.
        The stored exception is raised ONCE (the first sync point after
        the failure) and then cleared."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending_error()

    def _raise_pending_error(self):
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    def _gc(self):
        with self._lock:
            pending = set(self._pending)
        # a step whose save is still in flight must never be deleted, and
        # is excluded from the retention window entirely (it does not
        # count as one of the `keep` durable steps either)
        steps = [s for s in self.all_steps() if s not in pending]
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int], like: Any,
                mesh: Optional[Mesh] = None,
                specs: Optional[Any] = None,
                defs: Optional[Any] = None,
                fallback: bool = False) -> Tuple[int, Any]:
        """Restore onto the CURRENT mesh/partitioning (elastic).

        ``like`` provides the tree structure; ``specs`` (PartitionSpec tree)
        + ``mesh`` re-place each leaf.  Returns (step, tree).

        Integrity: every leaf is verified (crc32 + shape/dtype) against
        the manifest; corruption raises ``CheckpointCorruptionError``
        naming the damaged parameter.  With ``fallback=True`` a corrupted
        step is reported loudly and the newest EARLIER intact step is
        restored instead; the error is raised only when no intact step
        remains.

        ``defs`` (the model's ParamDef tree) additionally enables legacy
        migration: a checkpoint written with packed params stored as their
        separate views (e.g. wq/wk/wv instead of wqkv) is detected by its
        leaf count and packed in place, so pre-packing checkpoints restore
        transparently onto the packed schema.
        """
        steps = self.all_steps()
        if step is None:
            assert steps, "no checkpoint found"
            candidates = list(reversed(steps))
        else:
            candidates = [step] + (
                [s for s in reversed(steps) if s < step] if fallback else [])
        last_err: Optional[CheckpointCorruptionError] = None
        for s in candidates:
            try:
                return s, self._restore_step(s, like, mesh, specs, defs)
            except CheckpointCorruptionError as e:
                last_err = e
                if not fallback:
                    raise
                print(f"checkpoint: {e}; falling back to the previous "
                      f"intact step")
        assert last_err is not None
        raise CheckpointCorruptionError(
            last_err.step, last_err.param,
            f"{last_err.reason} (and no earlier intact step to fall "
            f"back to)")

    def _restore_step(self, step: int, like: Any, mesh: Optional[Mesh],
                      specs: Optional[Any], defs: Optional[Any]) -> Any:
        d = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, UnicodeDecodeError, json.JSONDecodeError) as e:
            raise CheckpointCorruptionError(
                step, "manifest.json",
                f"unreadable manifest ({type(e).__name__}: {e})") from e
        leaves_like, treedef = _flatten(like)
        if len(manifest["leaves"]) != len(leaves_like):
            assert defs is not None, (
                f"checkpoint at step {step} has "
                f"{len(manifest['leaves'])} leaves but the target tree "
                f"has {len(leaves_like)} — if this is a pre-packing "
                "(separate wq/wk/wv) checkpoint, pass defs=<ParamDef "
                "tree> to migrate it (Trainer/ServeEngine do this for "
                "fp32 optimizer state; packed_qkv=False on the config "
                "is the schema escape hatch)")
            return self._restore_legacy(d, step, manifest, like, mesh,
                                        specs, defs)
        spec_leaves = self._spec_leaves(specs, len(leaves_like))
        assert len(manifest["leaves"]) == len(leaves_like) == \
            len(spec_leaves), (len(manifest["leaves"]), len(leaves_like),
                               len(spec_leaves))
        out = []
        for meta, like_leaf, spec in zip(manifest["leaves"], leaves_like,
                                         spec_leaves):
            arr = self._load_leaf(d, meta, step)
            out.append(self._place(arr, mesh, spec))
        return jax.tree.unflatten(treedef, out)

    # -- legacy (unpacked-view) migration --------------------------------------

    def _restore_legacy(self, d: str, step: int, manifest, like: Any,
                        mesh: Optional[Mesh], specs: Optional[Any],
                        defs: Any):
        """Load a checkpoint whose packed params are stored as separate
        view leaves (the pre-``wqkv`` layout) and pack them in place."""
        from repro.models import param as pm
        legacy_like = pm.unpack_like(defs)
        legacy_leaves, legacy_def = _flatten(legacy_like)
        assert len(manifest["leaves"]) == len(legacy_leaves), (
            "checkpoint matches neither the packed nor the legacy schema",
            len(manifest["leaves"]), len(legacy_leaves))
        for meta, leaf in zip(manifest["leaves"], legacy_leaves):
            if isinstance(leaf, pm._PassThrough):
                continue  # non-ParamDef entry (e.g. optimizer step)
            assert tuple(meta["shape"]) == tuple(leaf.shape), (
                "legacy leaf shape mismatch (flatten-order drift?)",
                meta["file"], meta["shape"], leaf.shape)
        host = [self._load_leaf(d, meta, step)
                for meta in manifest["leaves"]]
        packed = pm.pack_tree(defs, jax.tree.unflatten(legacy_def, host))
        leaves, treedef = _flatten(packed)
        assert treedef == _flatten(like)[1], "migrated tree shape mismatch"
        spec_leaves = self._spec_leaves(specs, len(leaves))
        assert len(spec_leaves) == len(leaves), (len(spec_leaves),
                                                 len(leaves))
        out = [self._place(np.asarray(leaf), mesh, spec)
               for leaf, spec in zip(leaves, spec_leaves)]
        return jax.tree.unflatten(treedef, out)

    def export_legacy(self, step: int, tree: Any, defs: Any,
                      blocking: bool = True) -> None:
        """Reverse migration: save with every packed param split into its
        legacy view leaves (wqkv -> wq/wk/wv), for pre-packing tooling."""
        from repro.models import param as pm
        self.save(step, pm.split_tree(defs, tree), blocking=blocking)

    def _spec_leaves(self, specs: Optional[Any], n: int) -> List[Any]:
        from jax.sharding import PartitionSpec
        if specs is None:
            return [None] * n
        return jax.tree.leaves(
            specs,
            is_leaf=lambda s: s is None or isinstance(s, PartitionSpec))

    def _load_leaf(self, d: str, meta, step: int) -> np.ndarray:
        name = meta.get("param", meta["file"])
        try:
            arr = np.load(os.path.join(d, meta["file"]))
        except Exception as e:  # truncated/torn .npy: parser-level failure
            raise CheckpointCorruptionError(
                step, name,
                f"unreadable leaf file {meta['file']} "
                f"({type(e).__name__}: {e})") from e
        if list(arr.shape) != list(meta["shape"]) \
                or str(arr.dtype) != meta["dtype"]:
            raise CheckpointCorruptionError(
                step, name,
                f"shape/dtype mismatch: manifest says "
                f"{meta['shape']}/{meta['dtype']}, file holds "
                f"{list(arr.shape)}/{arr.dtype}")
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != meta["crc32"]:
            raise CheckpointCorruptionError(
                step, name,
                f"crc32 mismatch in {meta['file']} (expected "
                f"{meta['crc32']}, got {crc})")
        return arr

    def _place(self, arr, mesh: Optional[Mesh], spec):
        if mesh is not None and spec is not None and mesh.devices.size > 1:
            return jax.device_put(arr, NamedSharding(mesh, spec))
        return jax.numpy.asarray(arr)
