"""Sharded, async, atomic checkpointing with elastic restore.

Layout per step:
    <dir>/step_000123.tmp/ ... -> atomically renamed to <dir>/step_000123/
        manifest.json   (tree structure, shapes, dtypes, hashes)
        arr_<n>.npy     (one file per leaf, logical/unsharded values)

Properties a 1000-node job needs:
  * ATOMIC: a crash mid-write leaves only a .tmp dir, never a truncated
    checkpoint; restore scans for the newest COMPLETE step.
  * ASYNC: serialization happens on a background thread from host copies,
    off the training thread.
  * INTEGRITY: per-leaf crc32 in the manifest, verified at restore.
  * ELASTIC: leaves are stored LOGICALLY (unsharded).  Restore takes the
    *target* mesh + specs and re-places every leaf — the job can come back
    on fewer/more devices, a different mesh shape, or a different
    partitioning (xyz-layout weights round-trip through
    ``unshard_weight_xyz`` if the Y factorization changes).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


def _flatten(tree: Any) -> Tuple[List[Any], Any]:
    return jax.tree.flatten(tree)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        leaves, treedef = _flatten(tree)
        # host copies first (cheap on CPU; device->host on TPU) so training
        # can proceed while the writer thread serializes
        host = [np.asarray(x) for x in leaves]
        self.wait()

        def write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "treedef": str(treedef), "leaves": []}
            for i, arr in enumerate(host):
                path = os.path.join(tmp, f"arr_{i}.npy")
                np.save(path, arr)
                manifest["leaves"].append({
                    "file": f"arr_{i}.npy",
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
                })
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            os.rename(tmp, final)  # atomic commit
            self._gc()

        if self.async_save and not blocking:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int], like: Any,
                mesh: Optional[Mesh] = None,
                specs: Optional[Any] = None) -> Tuple[int, Any]:
        """Restore onto the CURRENT mesh/partitioning (elastic).

        ``like`` provides the tree structure; ``specs`` (PartitionSpec tree)
        + ``mesh`` re-place each leaf.  Returns (step, tree).
        """
        if step is None:
            step = self.latest_step()
        assert step is not None, "no checkpoint found"
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        from jax.sharding import PartitionSpec
        leaves_like, treedef = _flatten(like)
        spec_leaves = (jax.tree.leaves(
            specs,
            is_leaf=lambda s: s is None or isinstance(s, PartitionSpec))
            if specs is not None else [None] * len(leaves_like))
        assert len(manifest["leaves"]) == len(leaves_like) == \
            len(spec_leaves), (len(manifest["leaves"]), len(leaves_like),
                               len(spec_leaves))
        out = []
        for meta, like_leaf, spec in zip(manifest["leaves"], leaves_like,
                                         spec_leaves):
            arr = np.load(os.path.join(d, meta["file"]))
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checkpoint corruption in {meta['file']}")
            if mesh is not None and spec is not None \
                    and mesh.devices.size > 1:
                out.append(jax.device_put(arr, NamedSharding(mesh, spec)))
            else:
                out.append(jax.numpy.asarray(arr))
        return step, jax.tree.unflatten(treedef, out)
