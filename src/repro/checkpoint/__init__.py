from repro.checkpoint.manager import (
    CheckpointCorruptionError,
    CheckpointManager,
)

__all__ = ["CheckpointManager", "CheckpointCorruptionError"]
