from repro.optim.adamw import (
    AdamWConfig,
    abstract_opt_state,
    adamw_update,
    global_norm,
    init_opt_state,
    opt_state_specs,
)
from repro.optim.schedule import constant, warmup_cosine

__all__ = [
    "AdamWConfig", "adamw_update", "init_opt_state", "abstract_opt_state",
    "opt_state_specs", "global_norm", "warmup_cosine", "constant",
]
