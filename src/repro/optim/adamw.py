"""AdamW with optional 8-bit (row-block-quantized) moment states.

The int8 state mode reuses the paper's int8 pipeline idea (int8 storage,
32-bit arithmetic): moments are stored as int8 with one fp32 scale per
trailing row, dequantized, updated in fp32, and requantized each step.
For the 314B-parameter MoE this cuts optimizer-state HBM by 4x vs fp32
(recorded in the dry-run memory analysis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_mode: str = "fp32"     # 'fp32' | 'int8'
    schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None


# -- int8 moment codecs -------------------------------------------------------

def _q8(x: jnp.ndarray, sqrt_scale: bool = False) -> Dict[str, jnp.ndarray]:
    """Row-wise int8.  ``sqrt_scale`` stores sqrt(x) (x >= 0): linear
    quantization of the SECOND moment rounds small entries to zero, and
    m/(sqrt(0)+eps) then explodes — the sqrt codec compresses v's dynamic
    range so small entries survive (the 8-bit-Adam trick)."""
    xe = jnp.sqrt(jnp.maximum(x, 0.0)) if sqrt_scale else x
    absmax = jnp.max(jnp.abs(xe), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(xe / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def _dq8(p: Dict[str, jnp.ndarray], sqrt_scale: bool = False) -> jnp.ndarray:
    x = p["q"].astype(jnp.float32) * p["s"]
    return x * x if sqrt_scale else x


def _is_q8(x: Any) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "s"}


def _encode(x: jnp.ndarray, mode: str, sqrt_scale: bool = False):
    if mode == "int8" and x.ndim >= 1 and x.size > 1:
        return _q8(x, sqrt_scale)
    return x.astype(jnp.float32)


def _decode(x, sqrt_scale: bool = False) -> jnp.ndarray:
    return _dq8(x, sqrt_scale) if _is_q8(x) else x


# -- API ----------------------------------------------------------------------

def init_opt_state(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    def zeros_like_enc(p, sqrt_scale=False):
        z = jnp.zeros(p.shape, jnp.float32)
        return _encode(z, cfg.state_mode, sqrt_scale)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like_enc, params),
        "v": jax.tree.map(lambda p: zeros_like_enc(p, True), params),
    }


def abstract_opt_state(abstract_params: Any, cfg: AdamWConfig):
    """ShapeDtypeStruct pytree of the optimizer state (dry-run)."""
    def enc_struct(p):
        if cfg.state_mode == "int8" and len(p.shape) >= 1:
            n = 1
            for d in p.shape:
                n *= d
            if n > 1:
                return {
                    "q": jax.ShapeDtypeStruct(p.shape, jnp.int8),
                    "s": jax.ShapeDtypeStruct((*p.shape[:-1], 1),
                                              jnp.float32),
                }
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)

    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree.map(enc_struct, abstract_params),
        "v": jax.tree.map(enc_struct, abstract_params),
    }


def opt_state_specs(param_specs: Any, cfg: AdamWConfig):
    from jax.sharding import PartitionSpec as P

    def enc_spec(s):
        if cfg.state_mode == "int8":
            # the row scale has a trailing singleton dim: drop any sharding
            # of the last axis
            parts = list(s) if len(s) else []
            if parts:
                parts[-1] = None
            return {"q": s, "s": P(*parts)}
        return s

    return {
        "step": P(),
        "m": jax.tree.map(enc_spec, param_specs),
        "v": jax.tree.map(enc_spec, param_specs),
    }


def _barrier_on(x: jnp.ndarray, token: jnp.ndarray) -> jnp.ndarray:
    """Make ``x`` depend on ``token`` without changing its value."""
    x, _ = jax.lax.optimization_barrier((x, token))
    return x


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 cfg: AdamWConfig) -> Tuple[Any, Dict[str, Any]]:
    step = state["step"] + 1
    lr = cfg.schedule(step) if cfg.schedule is not None else cfg.lr

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd_core(p, g, m_enc, v_enc):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * _decode(m_enc) + (1 - cfg.b1) * g
        v = cfg.b2 * _decode(v_enc, True) + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return (pf.astype(p.dtype), _encode(m, cfg.state_mode),
                _encode(v, cfg.state_mode, True))

    upd = upd_core

    is_leaf = _is_q8
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"], is_leaf=is_leaf)
    flat_v = jax.tree.leaves(state["v"], is_leaf=is_leaf)
    # Chain big-leaf updates with optimization barriers: XLA's latency
    # scheduler otherwise runs many leaves' fp32 decode/update chains
    # concurrently (measured ~10 GB of optimizer temporaries on the 314B
    # MoE); serializing keeps one leaf's working set live at a time.
    out = []
    token = None
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        if token is not None and p.size > (1 << 24):
            g = _barrier_on(g, token)
        res = upd(p, g, m, v)
        if p.size > (1 << 24):
            token = res[0]
        out.append(res)
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}
