"""int8 error-feedback gradient compression for data-parallel reduction.

The wire format follows the paper's int8 pipeline: int8-quantized values,
wider accumulation.  A shared (pmax'd) scale makes the values
sum-compatible; the TRANSPORT is int16 so the psum is exact for up to 256
DP shards (127 * 256 < 2^15) — a 2x wire-byte reduction vs fp32 that XLA's
collective layer honors (an int32 transport is promoted to 4 bytes and
saves nothing; a true 1-byte ring needs per-hop requantization, traded off
in DESIGN.md).  The quantization residual is fed back into the next step's
gradient (error feedback), keeping optimization intact — validated in
tests by training the same model with and without compression.

``make_dp_train_step`` builds the whole data-parallel training step as one
shard_map: per-shard grads -> compressed psum -> replicated AdamW update.
The error-feedback residual is genuinely per-device state and is carried
with a leading device axis.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.maxeva_matmul import _shard_map
from repro.optim.adamw import AdamWConfig, adamw_update


def compressed_psum_mean(x: jnp.ndarray, axis,
                         err: Optional[jnp.ndarray] = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean over ``axis`` of (x + err), int8 on the wire (inside shard_map).
    Returns (mean, new_local_err)."""
    xf = x.astype(jnp.float32)
    if err is not None:
        xf = xf + err
    absmax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(jax.lax.pmax(absmax, axis), 1e-20) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_err = xf - q.astype(jnp.float32) * scale
    # int16 transport: exact sum for <= 256 shards, half the fp32 bytes
    total = jax.lax.psum(q.astype(jnp.int16), axis)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    return (total.astype(jnp.float32) * scale / n.astype(jnp.float32),
            new_err)


def init_error_state(params: Any, n_shards: int) -> Any:
    """Per-device EF residuals, leading device axis."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_shards, *p.shape), jnp.float32), params)


def make_dp_train_step(
    loss_fn: Callable[[Any, Any], jnp.ndarray],
    opt_cfg: AdamWConfig,
    mesh: Mesh,
    axis: str = "data",
    compression: str = "int8_ef",   # 'none' | 'int8_ef'
):
    """Pure-DP training step: params replicated, batch sharded over ``axis``.

    step(params, opt_state, err, batch) -> (loss, params, opt_state, err)
    """

    def body(params, opt_state, err, batch_l):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch_l)
        loss = jax.lax.pmean(loss, axis)
        if compression == "int8_ef":
            err_l = jax.tree.map(lambda e: e[0], err)
            synced = jax.tree.map(
                lambda g, e: compressed_psum_mean(g, axis, e), grads, err_l)
            grads = jax.tree.map(lambda t: t[0], synced,
                                 is_leaf=lambda t: isinstance(t, tuple))
            new_err = jax.tree.map(
                lambda t: t[1][None], synced,
                is_leaf=lambda t: isinstance(t, tuple))
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
            new_err = err
        params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
        return loss, params, opt_state, new_err

    rep = lambda tree: jax.tree.map(lambda _: P(), tree)
    dev0 = lambda tree: jax.tree.map(lambda _: P(axis), tree)

    def step(params, opt_state, err, batch):
        batch_specs = jax.tree.map(lambda _: P(axis), batch)
        return _shard_map(
            body, mesh,
            (rep(params), rep(opt_state), dev0(err), batch_specs),
            (P(), rep(params), rep(opt_state), dev0(err)),
        )(params, opt_state, err, batch)

    return jax.jit(step, donate_argnums=(0, 1, 2))
