"""Historical HLO-guard API, now thin shims over ``repro.analysis``.

``weight_concat_count`` / ``gemm_dispatches`` / ``int8_bounce_count``
were born as per-detector regex scans; PR 7 replaced the scanning with
the typed parser + pass framework in ``src/repro/analysis/`` (one parse,
def-use edges, hardened trip counts, donation metadata).  The functions
keep their exact signatures and semantics — every existing guard call
site (tests, benchmarks, the bench gate) works unchanged — and now share
one code path with the contract auditor (``launch/audit.py``).

``analyze_hlo`` remains the trip-count-aware cost analysis: XLA's
``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scanned program (layers, flash chunks, loss chunks) under-reports FLOPs,
bytes, and collective traffic by the trip counts.  It aggregates

    flops       — dot ops: 2 * prod(result dims) * prod(contracting dims)
    hbm bytes   — per instruction: result + operand bytes (post-fusion
                  this matches XLA's own traffic model)
    wire bytes  — per collective, ring-factor adjusted by replica-group
                  size

recursively: cost(comp) = local + sum over calls of trips * cost(callee),
with trip counts from the hardened ``condition_trip_count`` (multi-digit,
scientific-notation, and tuple-shaped condition constants all parse; the
old parser silently returned 1 for anything but ``s32[] constant(N)``).

Validated against unrolled-vs-scanned equivalence in tests.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from repro.analysis.hlo_graph import (
    HloModule,
    condition_trip_count,
    parse_hlo,
    shape_dims,
    shape_info,
)
from repro.analysis.passes import (
    _taint_dequants,
    dispatch_count_pass,
)

_SKIP_BYTES_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "iota", "after-all", "partition-id", "replica-id",
}

# ops a TPU compiler fuses into neighbours (standalone on CPU HLO): their
# traffic is excluded from the calibrated "fused" byte count
_FUSABLE_OPS = {
    "convert", "reshape", "transpose", "broadcast", "slice", "copy",
    "concatenate", "pad", "select", "compare", "add", "subtract",
    "multiply", "divide", "exponential", "tanh", "maximum", "minimum",
    "negate", "rsqrt", "sqrt", "reduce", "map",
}

_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all"}

# the cost model follows ONE callee per call site — the fusion/call/loop
# body — never the while condition (it carries no modeled cost)
_COST_CALLEE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_RECURSIVE_OPS = ("while", "fusion", "call", "conditional", "reduce",
                  "map", "sort", "scatter", "reduce-window",
                  "select-and-scatter", "custom-call", "async-start")


def weight_concat_count(text: str, d_model: int) -> int:
    """Count ``concatenate`` instructions that produce a weight-shaped
    result — trailing dims (d_model, n) — anywhere in the module.  This is
    the HLO signature of an apply-time wq/wk/wv concat: the packed-QKV
    path must report ZERO (the packed parameter is GEMM'd as stored, no
    per-step weight-shard copy).  Shim over the dispatch-count pass."""
    _, metrics = dispatch_count_pass(parse_hlo(text), {"d_model": d_model})
    return metrics["weight_concat_count"]


def gemm_dispatches(text: str, out_cols: int) -> int:
    """Count ``dot`` instructions whose result's last dim is ``out_cols``.
    With packed QKV, ``gemm_dispatches(hlo, q_dim + 2*kv_dim)`` == number
    of attention applies traced (one QKV GEMM dispatch each).  Shim over
    the dispatch-count pass."""
    _, metrics = dispatch_count_pass(parse_hlo(text),
                                     {"gemm_out_cols": out_cols})
    return metrics["gemm_dispatches"]


def int8_bounce_count(text: str) -> int:
    """Count GEMMs fed by a dequantized int8 tensor — the fp32 bounce the
    end-to-end int8 serving path must not contain.

    A *bounce* is an ``s8 -> float`` ``convert`` whose value (propagated
    through elementwise ops, fusions, calls and loops) reaches a ``dot``:
    either a quantized weight/activation dequantized back to fp for a
    float GEMM (the naive "quantize weights, dequantize to matmul"
    implementation), or a dequant -> requant round trip between
    consecutive GEMMs.  The clean int8 pipeline keeps GEMM inputs in int8
    (XLA widens them to ``s32`` for the int32-accumulating dot — an
    integer convert, not counted) and re-applies scales on the int32
    accumulator AFTER the dot, so a traced int8 decode must report ZERO.

    Shim over the dtype-flow taint pass: the same conservative
    cross-computation fixpoint (any tainted operand taints every callee
    parameter; a dirty callee taints the call-site result), which can
    only over-count — safe for a zero-bounce gate.
    """
    return len(_taint_dequants(parse_hlo(text)))


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0        # all instruction result+operand bytes
    bytes_fused: float = 0.0  # excluding ops a TPU compiler would fuse
    wire: Dict[str, float] = dataclasses.field(default_factory=dict)


def analyze_hlo(text: str) -> Dict[str, float]:
    module: HloModule = parse_hlo(text)
    comps = module.computations
    memo: Dict[str, CompCost] = {}

    def cost_of(cname: str, stack=()) -> CompCost:
        if cname in memo:
            return memo[cname]
        if cname in stack or cname not in comps:
            return CompCost()
        comp = comps[cname]
        total = CompCost()
        for ins in comp.instructions:
            shp_b, shp_n = shape_info(ins.shape)
            # -- bytes ---------------------------------------------------------
            if ins.op not in _SKIP_BYTES_OPS and ins.op != "while":
                b = shp_b
                for o in ins.operands:
                    os = comp.shape_of(o)
                    if os is not None:
                        b += shape_info(os)[0]
                total.bytes += b
                if ins.op not in _FUSABLE_OPS:
                    total.bytes_fused += b
            # -- flops ----------------------------------------------------------
            if ins.op == "dot":
                cd = _CONTRACT.search(ins.attrs_str)
                k = 1
                if cd and ins.operands:
                    lhs_dims = shape_dims(comp.shape_of(ins.operands[0])
                                          or "")
                    if lhs_dims:
                        for di in (cd.group(1).split(",")
                                   if cd.group(1) else []):
                            k *= lhs_dims[int(di)]
                total.flops += 2.0 * shp_n * k
            # -- collectives -----------------------------------------------------
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                g = ins.replica_group_size
                if base == "all-reduce":
                    w = 2.0 * (g - 1) / g * shp_b
                elif base == "all-gather":
                    w = (g - 1) / g * shp_b
                elif base == "reduce-scatter":
                    w = float(g - 1) * shp_b
                elif base in ("all-to-all", "ragged-all-to-all"):
                    w = (g - 1) / g * shp_b
                else:  # collective-permute
                    w = shp_b
                total.wire[base] = total.wire.get(base, 0.0) + w
            # -- nested computations ----------------------------------------------
            sub = _COST_CALLEE.search(ins.attrs_str)
            if sub and ins.op in _RECURSIVE_OPS:
                trips = 1
                if ins.op == "while":
                    cond = ins.condition
                    if cond in comps:
                        trips = condition_trip_count(comps[cond])
                sc = cost_of(sub.group(1), stack + (cname,))
                total.flops += trips * sc.flops
                # fusion/reduce internals live in registers; their HBM
                # traffic is the call site's result+operand bytes (already
                # counted above).  Loop/call bodies DO hit HBM each trip.
                if ins.op in ("while", "call", "conditional"):
                    total.bytes += trips * sc.bytes
                    total.bytes_fused += trips * sc.bytes_fused
                for k2, v in sc.wire.items():
                    total.wire[k2] = total.wire.get(k2, 0.0) + trips * v
        memo[cname] = total
        return total

    # Count only from the entry; nested computations are reached via calls,
    # which avoids double counting.
    entry = cost_of(module.entry or "")
    out = {"flops": entry.flops, "bytes": entry.bytes,
           "bytes_fused": entry.bytes_fused}
    for k, v in entry.wire.items():
        out[f"wire_{k}"] = v
    out["total_wire_bytes"] = sum(entry.wire.values())
    return out
