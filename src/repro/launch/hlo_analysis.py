"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scanned program (layers, flash chunks, loss chunks) under-reports FLOPs,
bytes, and collective traffic by the trip counts.  This analyzer walks the
optimized HLO text, builds a per-computation symbol table, extracts loop
trip counts from the loop-condition comparison constant, and aggregates

    flops       — dot ops: 2 * prod(result dims) * prod(contracting dims)
    hbm bytes   — per instruction: result + operand bytes (post-fusion this
                  matches XLA's own traffic model)
    wire bytes  — per collective, ring-factor adjusted by replica-group size

recursively: cost(comp) = local + sum over calls of trips * cost(callee).

Validated against unrolled-vs-scanned equivalence in tests.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"^(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}]+?)\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONSTANT = re.compile(r"constant\((\d+)\)")

_SKIP_BYTES_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "iota", "after-all", "partition-id", "replica-id",
}

# ops a TPU compiler fuses into neighbours (standalone on CPU HLO): their
# traffic is excluded from the calibrated "fused" byte count
_FUSABLE_OPS = {
    "convert", "reshape", "transpose", "broadcast", "slice", "copy",
    "concatenate", "pad", "select", "compare", "add", "subtract",
    "multiply", "divide", "exponential", "tanh", "maximum", "minimum",
    "negate", "rsqrt", "sqrt", "reduce", "map",
}

_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all"}


def _shape_info(s: str) -> Tuple[float, int]:
    """(total bytes, element count) for a shape or tuple-of-shapes string."""
    total_b = 0.0
    total_n = 0
    for dt, dims in re.findall(r"(\w+?)\[([\d,]*)\]", s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_b += n * _DTYPE_BYTES[dt]
        total_n += n
    return total_b, total_n


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str
    operands: List[str]


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0        # all instruction result+operand bytes
    bytes_fused: float = 0.0  # excluding ops a TPU compiler would fuse
    wire: Dict[str, float] = dataclasses.field(default_factory=dict)


def _parse_computations(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip()) if "{" in line else None
            if m and ("->" in line):
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            name, shape, op, rest = m.groups()
            # operands: %refs inside the first parenthesis group
            depth, i, args = 1, 0, rest
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        args = rest[:i]
                        break
            operands = _OPERAND.findall(args)
            comps[cur].append(Instr(name, shape, op, rest, operands))
    if entry is not None and entry != "__entry__":
        comps["__entry__"] = comps[entry]
    return comps


def _trip_count(cond_instrs: List[Instr]) -> int:
    """Scan/fori loops compare the induction var against the trip-count
    constant; the comparison may be hidden inside a wrapped computation, so
    take the max s32 scalar constant of the condition region (other
    condition constants are 0/1 steps)."""
    best = 1
    for ins in cond_instrs:
        if ins.op == "constant" and ins.shape.replace("%", "").startswith(
                "s32[]"):
            m = re.match(r"(\d+)\)", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _result_dims(shape: str) -> Optional[List[int]]:
    m = _SHAPE_RE.match(shape)
    if not m:
        return None
    return [int(x) for x in m.group(2).split(",")] if m.group(2) else []


def _iter_instrs(text: str):
    comps = _parse_computations(text)
    for cname, instrs in comps.items():
        if cname == "__entry__":  # alias of the entry computation
            continue
        for ins in instrs:
            yield ins


def weight_concat_count(text: str, d_model: int) -> int:
    """Count ``concatenate`` instructions that produce a weight-shaped
    result — trailing dims (d_model, n) — anywhere in the module.  This is
    the HLO signature of an apply-time wq/wk/wv concat: the packed-QKV
    path must report ZERO (the packed parameter is GEMM'd as stored, no
    per-step weight-shard copy)."""
    count = 0
    for ins in _iter_instrs(text):
        if ins.op != "concatenate":
            continue
        dims = _result_dims(ins.shape)
        if dims and len(dims) >= 2 and dims[-2] == d_model:
            count += 1
    return count


def gemm_dispatches(text: str, out_cols: int) -> int:
    """Count ``dot`` instructions whose result's last dim is ``out_cols``.
    With packed QKV, ``gemm_dispatches(hlo, q_dim + 2*kv_dim)`` == number
    of attention applies traced (one QKV GEMM dispatch each)."""
    count = 0
    for ins in _iter_instrs(text):
        if ins.op != "dot":
            continue
        dims = _result_dims(ins.shape)
        if dims and dims[-1] == out_cols:
            count += 1
    return count


def _dtype_of(shape: str) -> str:
    m = _SHAPE_RE.match(shape.replace("%", ""))
    return m.group(1) if m else ""


_FLOAT_DTYPES = {"f16", "bf16", "f32", "f64"}


def int8_bounce_count(text: str) -> int:
    """Count GEMMs fed by a dequantized int8 tensor — the fp32 bounce the
    end-to-end int8 serving path must not contain.

    A *bounce* is an ``s8 -> float`` ``convert`` whose value (propagated
    through elementwise ops, fusions, calls and loops) reaches a ``dot``:
    either a quantized weight/activation dequantized back to fp for a
    float GEMM (the naive "quantize weights, dequantize to matmul"
    implementation), or a dequant -> requant round trip between
    consecutive GEMMs.  The clean int8 pipeline keeps GEMM inputs in int8
    (XLA widens them to ``s32`` for the int32-accumulating dot — an
    integer convert, not counted) and re-applies scales on the int32
    accumulator AFTER the dot, so a traced int8 decode must report ZERO.

    Taint propagation is conservative across called computations (any
    tainted operand taints every parameter of the callee; a callee with
    any tainted instruction taints the call-site result), which can only
    over-count — safe for a zero-bounce gate.
    """
    comps = _parse_computations(text)
    table: Dict[str, Dict[str, str]] = {
        c: {i.name: i.shape for i in instrs} for c, instrs in comps.items()
    }
    real = [c for c in comps if c != "__entry__"]
    tainted: Dict[str, set] = {c: set() for c in comps}
    comp_dirty: Dict[str, bool] = {c: False for c in comps}

    # parameter index -> instruction name, per computation
    params_of: Dict[str, Dict[int, str]] = {}
    for c in real:
        d: Dict[int, str] = {}
        for ins in comps[c]:
            if ins.op == "parameter":
                m = re.match(r"(\d+)\)", ins.rest)
                if m:
                    d[int(m.group(1))] = ins.name
        params_of[c] = d

    bounces = set()
    changed = True
    while changed:
        changed = False
        for c in real:
            for ins in comps[c]:
                if ins.name in tainted[c]:
                    hit = True
                else:
                    hit = False
                    # seed: dequantization of an int8 tensor
                    if (ins.op == "convert"
                            and _dtype_of(ins.shape) in _FLOAT_DTYPES):
                        opshape = table[c].get(
                            ins.operands[0]) if ins.operands else None
                        if opshape is not None and _dtype_of(opshape) == "s8":
                            hit = True
                    # propagate: any tainted operand taints the result
                    if not hit and any(o in tainted[c]
                                       for o in ins.operands):
                        hit = True
                    # a callee holding tainted values taints the call site
                    sub = _CALLS.search(ins.rest)
                    if not hit and sub and comp_dirty.get(sub.group(1)):
                        hit = True
                    if hit:
                        tainted[c].add(ins.name)
                        comp_dirty[c] = True
                        changed = True
                # cross-computation: tainted operands taint callee params
                sub = _CALLS.search(ins.rest)
                if sub and sub.group(1) in params_of and any(
                        o in tainted[c] for o in ins.operands):
                    callee = sub.group(1)
                    for pname in params_of[callee].values():
                        if pname not in tainted[callee]:
                            tainted[callee].add(pname)
                            comp_dirty[callee] = True
                            changed = True
                if ins.op == "dot" and any(o in tainted[c]
                                           for o in ins.operands):
                    bounces.add((c, ins.name))
    return len(bounces)


def analyze_hlo(text: str) -> Dict[str, float]:
    comps = _parse_computations(text)
    table: Dict[str, Dict[str, str]] = {
        c: {i.name: i.shape for i in instrs} for c, instrs in comps.items()
    }

    memo: Dict[str, CompCost] = {}

    def cost_of(cname: str, stack=()) -> CompCost:
        if cname in memo:
            return memo[cname]
        if cname in stack or cname not in comps:
            return CompCost()
        total = CompCost()
        for ins in comps[cname]:
            shp_b, shp_n = _shape_info(ins.shape)
            # -- bytes ---------------------------------------------------------
            if ins.op not in _SKIP_BYTES_OPS and ins.op != "while":
                b = shp_b
                for o in ins.operands:
                    os = table[cname].get(o)
                    if os is not None:
                        b += _shape_info(os)[0]
                total.bytes += b
                if ins.op not in _FUSABLE_OPS:
                    total.bytes_fused += b
            # -- flops ----------------------------------------------------------
            if ins.op == "dot":
                cd = _CONTRACT.search(ins.rest)
                k = 1
                if cd and ins.operands:
                    lhs = table[cname].get(ins.operands[0], "")
                    m2 = _SHAPE_RE.match(lhs)
                    if m2 and m2.group(2):
                        dims = [int(d) for d in m2.group(2).split(",")]
                        for di in (cd.group(1).split(",")
                                   if cd.group(1) else []):
                            k *= dims[int(di)]
                total.flops += 2.0 * shp_n * k
            # -- collectives -----------------------------------------------------
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                g = 1
                m2 = _GROUPS_IOTA.search(ins.rest)
                if m2:
                    g = int(m2.group(2))
                else:
                    m3 = _GROUPS_LIST.search(ins.rest)
                    if m3:
                        g = max(1, len([t for t in m3.group(1).split(",")
                                        if t.strip()]))
                if base == "all-reduce":
                    w = 2.0 * (g - 1) / g * shp_b
                elif base == "all-gather":
                    w = (g - 1) / g * shp_b
                elif base == "reduce-scatter":
                    w = float(g - 1) * shp_b
                elif base in ("all-to-all", "ragged-all-to-all"):
                    w = (g - 1) / g * shp_b
                else:  # collective-permute
                    w = shp_b
                total.wire[base] = total.wire.get(base, 0.0) + w
            # -- nested computations ----------------------------------------------
            sub = _CALLS.search(ins.rest)
            if sub and ins.op in ("while", "fusion", "call", "conditional",
                                  "reduce", "map", "sort", "scatter",
                                  "reduce-window", "select-and-scatter",
                                  "custom-call", "async-start"):
                trips = 1
                if ins.op == "while":
                    cm = _COND.search(ins.rest)
                    if cm and cm.group(1) in comps:
                        trips = _trip_count(comps[cm.group(1)])
                sc = cost_of(sub.group(1), stack + (cname,))
                total.flops += trips * sc.flops
                # fusion/reduce internals live in registers; their HBM
                # traffic is the call site's result+operand bytes (already
                # counted above).  Loop/call bodies DO hit HBM each trip.
                if ins.op in ("while", "call", "conditional"):
                    total.bytes += trips * sc.bytes
                    total.bytes_fused += trips * sc.bytes_fused
                for k2, v in sc.wire.items():
                    total.wire[k2] = total.wire.get(k2, 0.0) + trips * v
        memo[cname] = total
        return total

    # Count only from the entry; nested computations are reached via calls,
    # which avoids double counting.
    entry = cost_of("__entry__")
    out = {"flops": entry.flops, "bytes": entry.bytes,
           "bytes_fused": entry.bytes_fused}
    for k, v in entry.wire.items():
        out[f"wire_{k}"] = v
    out["total_wire_bytes"] = sum(entry.wire.values())
    return out
