import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the
# device count at first init).  Do not move them.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config, runnable_cells  # noqa: E402
from repro.core.sharding import use_mesh  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import collective_wire_bytes, roofline_terms  # noqa: E402
from repro.launch.specs import input_specs, param_io_specs  # noqa: E402
from repro.models.lm import Model  # noqa: E402
from repro.optim import AdamWConfig, abstract_opt_state, opt_state_specs  # noqa: E402
from repro.train.step import batch_specs, make_train_step  # noqa: E402

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell
against ShapeDtypeStruct inputs on the production mesh and record
memory_analysis / cost_analysis / collective wire bytes for the roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree)


def _mem_analysis_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(ma)
    if "argument_size_in_bytes" in out:
        out["total_per_device_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    return out


def model_flops_global(cfg, cell) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D for inference, with N the
    active (per-token) parameter count."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch  # decode: one token per sequence


def run_cell(arch: str, shape: str, multi_pod: bool,
             save_hlo: Optional[str] = None) -> Dict[str, Any]:
    t0 = time.time()
    cfg = get_config(arch)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg, mesh)
    opt_cfg = AdamWConfig(state_mode=cfg.opt_state_mode)

    with use_mesh(mesh):
        aparams, pspecs = param_io_specs(model)
        if cell.kind == "train":
            fn = make_train_step(model, opt_cfg)
            aopt = abstract_opt_state(aparams, opt_cfg)
            abatch = input_specs(cfg, shape)
            in_sh = (_ns(mesh, pspecs),
                     _ns(mesh, opt_state_specs(pspecs, opt_cfg)),
                     _ns(mesh, batch_specs(cfg, mesh, "train")))
            jf = jax.jit(fn, in_shardings=in_sh, donate_argnums=(0, 1))
            lowered = jf.lower(aparams, aopt, abatch)
        elif cell.kind == "prefill":
            abatch = input_specs(cfg, shape)
            in_sh = (_ns(mesh, pspecs),
                     _ns(mesh, batch_specs(cfg, mesh, "prefill")))
            jf = jax.jit(model.prefill, in_shardings=in_sh)
            lowered = jf.lower(aparams, abatch)
        else:  # decode
            acache, atok, apos = input_specs(cfg, shape, model)
            from repro.core.sharding import dp_axes, dp_size
            b = cell.global_batch
            tok_spec = P(dp_axes(mesh), None) \
                if b % max(dp_size(mesh), 1) == 0 and b > 1 else P(None, None)
            in_sh = (_ns(mesh, pspecs),
                     _ns(mesh, model.cache_specs(b, cell.seq_len)),
                     NamedSharding(mesh, tok_spec), None)
            jf = jax.jit(model.decode_step, in_shardings=in_sh,
                         donate_argnums=(1,))
            lowered = jf.lower(aparams, acache, atok, apos)

        compiled = lowered.compile()

    raw_cost = dict(compiled.cost_analysis() or {})
    hlo = compiled.as_text()
    # trip-count-aware analysis: XLA's cost_analysis counts while bodies
    # once, which under-reports every scanned program (see hlo_analysis.py)
    from repro.launch.hlo_analysis import analyze_hlo
    an = analyze_hlo(hlo)
    # memory term from the fused-model bytes (ops a TPU compiler fuses are
    # excluded); the raw conservative count is recorded alongside.
    cost = {"flops": an["flops"], "bytes accessed": an["bytes_fused"],
            "bytes_conservative": an["bytes"]}
    wire = {k: v for k, v in an.items() if k.startswith("wire_")}
    wire["total_wire_bytes"] = an["total_wire_bytes"]
    wire["raw_once_counted"] = collective_wire_bytes(hlo)["total_wire_bytes"]
    mem = _mem_analysis_dict(compiled)
    n_dev = mesh.devices.size
    mf = model_flops_global(cfg, cell) / n_dev
    terms = roofline_terms(cost, wire, model_flops_per_device=mf)

    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    return {
        "arch": arch,
        "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev,
        "kind": cell.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "compile_s": round(time.time() - t0, 1),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "xla_cost_analysis_flops_once": float(raw_cost.get("flops", 0.0)),
        "collectives": wire,
        "memory": mem,
        "roofline": terms.as_dict(),
        "ok": True,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in runnable_cells(a)]
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (exists)")
                continue
            if shape in get_config(arch).skip_shapes:
                rec = {"arch": arch, "shape": shape,
                       "mesh": "multi" if mp else "single", "ok": True,
                       "skipped": True,
                       "reason": "see DESIGN.md shape-cell skips"}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                print(f"[skip-cell] {tag}")
                continue
            try:
                rec = run_cell(arch, shape, mp, save_hlo=args.save_hlo)
                print(f"[ok] {tag}: compile={rec['compile_s']}s "
                      f"flops/dev={rec['cost_analysis'].get('flops', 0):.3e} "
                      f"wire/dev={rec['collectives']['total_wire_bytes']:.3e} "
                      f"dominant={rec['roofline']['dominant']}")
            except Exception as e:
                failures += 1
                rec = {"arch": arch, "shape": shape,
                       "mesh": "multi" if mp else "single", "ok": False,
                       "error": str(e),
                       "traceback": traceback.format_exc()}
                print(f"[FAIL] {tag}: {e}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=2, default=str)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
