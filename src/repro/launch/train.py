"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --smoke --steps 50 --batch 8 --seq 128

Full-config runs on real hardware use the same entry point with the
production mesh; on this CPU container, --smoke selects the reduced config.
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokenSource, TokenPipeline
from repro.launch.mesh import make_mesh
from repro.models.lm import Model
from repro.optim import AdamWConfig
from repro.optim.schedule import warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-mesh", type=int, default=0,
                    help="data axis size (0 = all local devices)")
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    data_ax = args.data_mesh or (jax.device_count() // args.model_mesh)
    mesh = make_mesh(data_ax, args.model_mesh)
    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg, mesh)
    print(f"arch={cfg.name} params={model.n_params():,} mesh={mesh.shape}")

    opt_cfg = AdamWConfig(
        lr=args.lr, state_mode=cfg.opt_state_mode,
        schedule=warmup_cosine(args.lr, args.warmup, args.steps))
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir)

    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq,
                      seed=args.seed)
    src = SyntheticTokenSource(cfg.vocab, args.seed)

    def pipeline_factory(start_step):
        return TokenPipeline(src, dcfg, mesh, cfg, start_step=start_step)

    trainer = Trainer(model, opt_cfg, tcfg, pipeline_factory)
    trainer.run(args.seed)
    losses = [m["loss"] for m in trainer.metrics]
    if losses:
        print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")
        print(f"stragglers flagged: {len(trainer.watchdog.events)}")


if __name__ == "__main__":
    main()
