"""Serving launcher: batched greedy generation with a KV cache, health
guards, and optional fault-injection drills.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --smoke \
        --batch 4 --prompt-len 32 --max-new 16

Chaos drill (prove the guards on a live engine — lane 1 gets NaN logits
at step 2 and is quarantined while its peers finish):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --smoke \
        --inject-nan 2:1 --timeout-s 30
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models.lm import Model
from repro.robust import FaultPlan, LogitFault, StallFault, generate_with_retry
from repro.serve.engine import ServeConfig, ServeEngine


def _parse_faults(args) -> FaultPlan | None:
    logit_faults = []
    stalls = []
    for spec in args.inject_nan or ():
        step, lane = spec.split(":")
        logit_faults.append(LogitFault(step=int(step), lanes=(int(lane),),
                                       kind="nan"))
    for spec in args.inject_saturation or ():
        step, lane = spec.split(":")
        logit_faults.append(LogitFault(step=int(step), lanes=(int(lane),),
                                       kind="scale", scale=100.0))
    for spec in args.inject_stall or ():
        step, seconds = spec.split(":")
        stalls.append(StallFault(step=int(step), seconds=float(seconds)))
    if not (logit_faults or stalls or args.inject_transient):
        return None
    return FaultPlan(seed=args.seed, logit_faults=tuple(logit_faults),
                     stalls=tuple(stalls),
                     fail_first_generates=args.inject_transient)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--int8", action="store_true",
                    help="end-to-end int8 decode: one-shot column-wise "
                         "weight quantization, int8 GEMMs with scales "
                         "re-applied in the fused epilogues (single-shard)")
    ap.add_argument("--fp32-fallback", action="store_true",
                    help="with --int8: keep the fp32 weights and finish "
                         "saturation-degraded lanes on them")
    ap.add_argument("--no-guards", action="store_true",
                    help="disable per-lane numerical-health guards "
                         "(pre-hardening decode loop)")
    ap.add_argument("--timeout-s", type=float, default=None,
                    help="wall-clock budget per request; expired lanes "
                         "get a structured 'timeout' status")
    ap.add_argument("--max-lanes", type=int, default=None,
                    help="admission limit; surplus batch rows are shed "
                         "with a 'shed' status instead of decoded")
    ap.add_argument("--retries", type=int, default=2,
                    help="transient-failure retries (exponential backoff)")
    # fault-injection drills ("step:lane" / "step:seconds")
    ap.add_argument("--inject-nan", action="append", metavar="STEP:LANE")
    ap.add_argument("--inject-saturation", action="append",
                    metavar="STEP:LANE")
    ap.add_argument("--inject-stall", action="append",
                    metavar="STEP:SECONDS")
    ap.add_argument("--inject-transient", type=int, default=0,
                    help="fail the first N generate() calls with a "
                         "retryable error (exercises the retry wrapper)")
    args = ap.parse_args()

    mesh = make_mesh(jax.device_count(), 1)
    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg, mesh)
    params = model.init_params(args.seed)

    key = jax.random.PRNGKey(args.seed)
    text_len = args.prompt_len - (cfg.prefix_tokens or 0)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, text_len), 0, cfg.vocab, jnp.int32)}
    if cfg.prefix_tokens:
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.prefix_tokens, cfg.d_model), jnp.float32)
    if cfg.encdec:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.enc_frames, cfg.d_model), jnp.float32)

    eng = ServeEngine(model, params,
                      ServeConfig(max_new_tokens=args.max_new,
                                  int8=args.int8,
                                  fp32_fallback=args.fp32_fallback,
                                  guards=not args.no_guards,
                                  request_timeout_s=args.timeout_s,
                                  max_lanes=args.max_lanes))
    plan = _parse_faults(args)
    t0 = time.time()
    res = generate_with_retry(eng, batch, args.seed, retries=args.retries,
                              fault_plan=plan)
    dt = time.time() - t0
    print(f"generated {res.tokens.shape} tokens in {dt:.2f}s "
          f"({res.tokens.size / dt:.1f} tok/s), "
          f"{res.admitted}/{args.batch} lanes admitted"
          f"{', TIMED OUT' if res.timed_out else ''}")
    for lane, (st, fs) in enumerate(zip(res.status, res.fault_step)):
        extra = f" (at step {fs})" if fs >= 0 else ""
        print(f"  lane {lane}: {st}{extra}")
    print(res.tokens[:, :12])


if __name__ == "__main__":
    main()
