"""Serving launcher: batched greedy generation with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --smoke \
        --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models.lm import Model
from repro.serve.engine import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--int8", action="store_true",
                    help="end-to-end int8 decode: one-shot column-wise "
                         "weight quantization, int8 GEMMs with scales "
                         "re-applied in the fused epilogues (single-shard)")
    args = ap.parse_args()

    mesh = make_mesh(jax.device_count(), 1)
    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg, mesh)
    params = model.init_params(args.seed)

    key = jax.random.PRNGKey(args.seed)
    text_len = args.prompt_len - (cfg.prefix_tokens or 0)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, text_len), 0, cfg.vocab, jnp.int32)}
    if cfg.prefix_tokens:
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.prefix_tokens, cfg.d_model), jnp.float32)
    if cfg.encdec:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.enc_frames, cfg.d_model), jnp.float32)

    eng = ServeEngine(model, params,
                      ServeConfig(max_new_tokens=args.max_new,
                                  int8=args.int8))
    t0 = time.time()
    out = eng.generate(batch, args.seed)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
