"""HLO contract auditor CLI: trace every registered production path,
run the analysis passes, and gate against the committed baseline.

    PYTHONPATH=src python -m repro.launch.audit                  # gate
    PYTHONPATH=src python -m repro.launch.audit --update-baseline
    PYTHONPATH=src python -m repro.launch.audit --only decode    # subset
    PYTHONPATH=src python -m repro.launch.audit --selftest       # seeded
                                                 # regressions must trip

Exit status: 0 only when every contract holds AND every metric matches
``HLO_CONTRACTS.json`` (bench-gate style — intentional structural change
is re-seeded with ``--update-baseline`` and shows up in review).

``--selftest`` proves the auditor has teeth by seeding the three
regressions the PR 7 acceptance names — a reintroduced barrier
all-gather on the ksharded Y>1 path, a forced int8 -> f32 bounce before
a dot, a non-donated KV-cache decode step — and failing unless each one
trips the matching pass.
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
# The lines above MUST run before any jax import (jax locks the device
# count at first init — the dryrun.py rule): the multidev schedule
# contracts need 8 host devices.

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
from typing import List, Optional  # noqa: E402

_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
BASELINE = os.path.join(_ROOT, "HLO_CONTRACTS.json")


def _selftest() -> int:
    """Seed the three named regressions; each MUST trip its pass."""
    import jax
    import jax.numpy as jnp

    from repro.analysis import parse_hlo, run_passes
    from repro.core.maxeva_matmul import XYZConfig, schedule_wire_ops

    failures: List[str] = []

    def expect_error(case: str, hlo: str, expect: dict, code: str):
        findings, _ = run_passes(parse_hlo(hlo), expect)
        hits = [f for f in findings
                if f.code == code and f.severity == "error"]
        if hits:
            print(f"audit --selftest: ok   {case}: tripped "
                  f"{hits[0].pass_name}/{code} ({len(hits)} finding(s))")
        else:
            failures.append(case)
            print(f"audit --selftest: FAIL {case}: expected an error "
                  f"finding with code {code}, got "
                  f"{[f.code for f in findings]}")

    # 1. reintroduced barrier all-gather on the ksharded Y>1 path: the
    # pre-overlap implementation gathered the K blocks with a blocking
    # all-gather before the GEMM — the collective-schedule pass must
    # reject it against the overlapped plan's allowed set
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.launch.mesh import make_mesh

    mesh = make_mesh(2, 4)
    xcfg = XYZConfig(y=2, schedule="reduce_scatter", x_layout="ksharded")

    def barrier_body(x, w):
        xg = jax.lax.all_gather(
            x, "model", axis_index_groups=[[0, 1], [2, 3]], axis=1,
            tiled=True)
        partial = xg @ w
        return jax.lax.psum_scatter(
            partial, "model", scatter_dimension=1,
            axis_index_groups=[[0, 1], [2, 3]], tiled=True)

    fn = jax.jit(shard_map(
        barrier_body, mesh=mesh,
        in_specs=(P("data", "model"), P("model", None)),
        out_specs=P("data", "model")))
    hlo = fn.lower(
        jax.ShapeDtypeStruct((8, 32), jnp.float32),
        jax.ShapeDtypeStruct((64, 16), jnp.float32)).compile().as_text()
    expect_error(
        "barrier all-gather on ksharded Y>1",
        hlo, {"allowed_collectives": schedule_wire_ops(xcfg, 4)},
        "barrier-all-gather")

    # 2. forced int8 -> f32 bounce before a dot: the naive dequantize-
    # then-float-GEMM implementation
    def bounced(qx, sx, w):
        x = qx.astype(jnp.float32) * sx
        return x @ w

    hlo = jax.jit(bounced).lower(
        jax.ShapeDtypeStruct((4, 64), jnp.int8),
        jax.ShapeDtypeStruct((4, 1), jnp.float32),
        jax.ShapeDtypeStruct((64, 32), jnp.float32)).compile().as_text()
    expect_error("int8 -> f32 bounce before a dot",
                 hlo, {"int8_clean": True}, "int8-bounce")

    # 3. non-donated KV-cache decode step: jit WITHOUT donate_argnums
    # against the production donation contract
    from repro.analysis.contract import _smoke_cfg
    from repro.launch.mesh import make_mesh as mk
    from repro.models.lm import Model

    cfg = _smoke_cfg()
    model = Model(cfg, mk(1, 1))
    aparams = model.abstract_params()
    acache = model.abstract_cache(2, 24)
    tok = jax.ShapeDtypeStruct((2, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    hlo = jax.jit(model.decode_step).lower(
        aparams, acache, tok, pos).compile().as_text()
    n_p = len(jax.tree_util.tree_leaves(aparams))
    n_c = len(jax.tree_util.tree_leaves(acache))
    expect_error(
        "non-donated KV-cache decode step",
        hlo, {"donated_params": tuple(range(n_p, n_p + n_c))},
        "non-donated-buffer")

    if failures:
        print(f"audit --selftest: FAIL ({len(failures)}/3 seeded "
              f"regressions not caught: {failures})")
        return 1
    print("audit --selftest: PASS (3/3 seeded regressions caught)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the traced contract metrics to "
                         "--baseline instead of gating against them")
    ap.add_argument("--only", default=None,
                    help="substring filter on contract names (spot "
                         "checks; the gate always runs everything)")
    ap.add_argument("--allow-device-skips", action="store_true",
                    help="tolerate contracts skipped for lack of "
                         "devices (local spot checks only — the gate "
                         "treats a skip as a coverage regression)")
    ap.add_argument("--selftest", action="store_true",
                    help="seed the three known regressions and verify "
                         "each trips its pass")
    args = ap.parse_args(argv)

    if args.selftest:
        return _selftest()

    from repro.analysis import (diff_baseline, production_contracts,
                                run_contract)
    from repro.analysis.contract import to_baseline

    contracts = production_contracts()
    if args.only:
        contracts = [c for c in contracts if args.only in c.name]
        if not contracts:
            print(f"audit: no contract matches --only {args.only!r}")
            return 2

    reports = []
    for c in contracts:
        r = run_contract(c)
        print(r.format())
        reports.append(r)

    if args.update_baseline:
        if args.only:
            print("audit: refusing --update-baseline with --only (a "
                  "partial baseline would fail every other contract)")
            return 2
        payload = to_baseline(reports)
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"audit: baseline reseeded at {args.baseline} "
              f"({len(payload['contracts'])} contracts)")
        # reseeding never launders an outright violation
        bad = [r for r in reports if r.errors]
        for r in bad:
            print(f"audit: VIOLATION in {r.contract} survives the "
                  f"reseed — fix the program, not the baseline")
        return 1 if bad else 0

    baseline = None
    if os.path.exists(args.baseline):
        with open(args.baseline) as f:
            baseline = json.load(f)
    else:
        print(f"audit: no baseline at {args.baseline}; run with "
              f"--update-baseline to seed it")

    failures, lines = diff_baseline(
        reports, baseline, allow_device_skips=args.allow_device_skips)
    if args.only and baseline is not None:
        # a subset run legitimately misses baseline contracts
        failures = [f for f in failures
                    if not f.startswith("MISSING contract")]
    for line in lines:
        print(f"audit: {line}")
    for f in failures:
        print(f"audit: {f}")
    if failures:
        return 1
    n = len([r for r in reports if not r.skipped])
    print(f"audit: PASS ({n} contracts match the committed baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
