"""Roofline-term extraction from compiled XLA artifacts.

``cost_analysis()`` provides HLO FLOPs and bytes accessed for the
per-device SPMD program.  Collective wire bytes are NOT in cost_analysis:
we parse the HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, applying
ring-algorithm wire factors using the parsed replica-group size.

Hardware constants (assignment-fixed, see core.device_model.TPU_V5E):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional

from repro.core.device_model import TPU_V5E, TPUDevice

# element bytes by HLO dtype prefix
_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s8": 1, "u8": 1, "pred": 1,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# `  %name = shape op-name(` or `  name = (shape, shape) op-name(`
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute"
    r"|ragged-all-to-all)"
    r"(-start)?\(([^)]*)\)(.*)$"
)

_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(.*?)\}\}")


def _shape_bytes(s: str) -> float:
    """Sum of element bytes over every `dtype[d0,d1,...]` in the string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: float
    operand_bytes: float
    group_size: int
    wire_bytes: float


def _group_size(line: str, default: int = 1) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # replica_groups=[G,S]<=[N]: G groups of S members.
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        items = [t for t in m.group(1).split(",") if t.strip() != ""]
        return max(1, len(items))
    return default


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        if "-done" in line or "-update(" in line:
            continue
        m = _OP_RE.search(line)
        if m is None:
            continue
        result_s, kind, _start, operands, rest = m.groups()
        # Operands are printed without type annotations in current XLA HLO
        # dumps, so all sizing derives from the (per-device) result shape.
        res_b = _shape_bytes(result_s)
        g = _group_size(line)
        if kind == "all-reduce":
            wire = 2.0 * (g - 1) / max(g, 1) * res_b
        elif kind == "all-gather":
            # result is the gathered (full) buffer
            wire = (g - 1) / max(g, 1) * res_b
        elif kind == "reduce-scatter":
            # result is the scattered shard; the reduced buffer is g x that
            wire = float(g - 1) * res_b
        elif kind in ("all-to-all", "ragged-all-to-all"):
            wire = (g - 1) / max(g, 1) * res_b
        else:  # collective-permute: one send of the (result-sized) buffer
            wire = res_b
        ops.append(CollectiveOp(kind, res_b, res_b, g, wire))
    return ops


def collective_wire_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire-byte totals by collective kind + grand total."""
    ops = parse_collectives(hlo_text)
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for op in ops:
        out[op.kind] += op.wire_bytes
    out["total_wire_bytes"] = sum(out[k] for k in _COLLECTIVES)
    out["n_collectives"] = float(len(ops))
    return out


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RooflineTerms:
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: Optional[float] = None
    useful_flops_ratio: Optional[float] = None

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        return d


def roofline_terms(
    cost: Dict[str, float],
    wire: Dict[str, float],
    device: TPUDevice = TPU_V5E,
    dtype: str = "bf16",
    model_flops_per_device: Optional[float] = None,
) -> RooflineTerms:
    """Terms from a compiled per-device SPMD program.

    compute  = HLO_FLOPs / peak_FLOP/s        (per chip)
    memory   = HLO_bytes / HBM_bw             (per chip)
    collective = wire_bytes / ICI link bw     (per chip; ring factors are
                 already folded into wire_bytes by the parser)
    """
    flops = float(cost.get("flops", 0.0) or 0.0)
    hbm = float(cost.get("bytes accessed", 0.0) or 0.0)
    wb = float(wire.get("total_wire_bytes", 0.0))
    compute_s = flops / device.peak_flops[dtype]
    memory_s = hbm / device.hbm_bw
    collective_s = wb / device.ici_bw_per_link
    dom = max(
        [("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)],
        key=lambda kv: kv[1],
    )[0]
    ratio = None
    if model_flops_per_device:
        ratio = model_flops_per_device / flops if flops else None
    return RooflineTerms(flops, hbm, wb, compute_s, memory_s, collective_s,
                         dom, model_flops_per_device, ratio)
