"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required for the dry-run flow, where the
placeholder device count must be set before the first jax initialization.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

from repro.core.sharding import make_mesh_compat


def _mk(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    # Auto axis types: the framework mixes GSPMD-constrained jit code with
    # explicit shard_map blocks (the XYZ matmul), which requires Auto.
    # make_mesh_compat degrades gracefully on JAX without AxisType.
    return make_mesh_compat(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16 x 16 = 256 chips, axes (data, model).
    Multi-pod: 2 x 16 x 16 = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(data: int, model: int, pod: int = 1) -> Mesh:
    """Arbitrary (pod x) data x model mesh — used by tests, examples and
    elastic restarts on whatever devices remain."""
    if pod > 1:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


def make_host_mesh() -> Mesh:
    """Whatever this host offers, as a (data, 1) mesh (CPU tests)."""
    n = jax.device_count()
    return _mk((n, 1), ("data", "model"))
