"""ShapeDtypeStruct stand-ins for every model input, per (arch x shape).

No device allocation: the dry-run lowers/compiles against these.  Modality
frontends are stubs per the assignment — whisper gets precomputed frame
embeddings, paligemma precomputed patch embeddings."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig, ShapeCell


def param_io_specs(model) -> Tuple[Any, Any]:
    """(abstract ShapeDtypeStruct tree, PartitionSpec tree) for the model
    parameters — the one source the dry-run, serving restore, and
    checkpoint migration consume, so every surface sees the packed
    ``wqkv`` shapes (and any future packed defs) consistently."""
    return model.abstract_params(), model.param_specs()


def train_batch_specs(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, Any]:
    b = cell.global_batch
    s_text = cell.seq_len - (cfg.prefix_tokens or 0)
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s_text), jnp.int32),
        "targets": jax.ShapeDtypeStruct((b, s_text), jnp.int32),
    }
    if cfg.prefix_tokens:
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.prefix_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encdec:
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    return specs


def prefill_batch_specs(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, Any]:
    specs = train_batch_specs(cfg, cell)
    del specs["targets"]
    return specs


def decode_inputs(cfg: ArchConfig, cell: ShapeCell, model) -> Tuple:
    """(cache, token, pos) abstract inputs for one decode step at a KV
    length of ``cell.seq_len``."""
    b = cell.global_batch
    cache = model.abstract_cache(b, cell.seq_len)
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, token, pos


def input_specs(cfg: ArchConfig, shape_name: str, model=None):
    cell = SHAPES[shape_name]
    if cell.kind == "train":
        return train_batch_specs(cfg, cell)
    if cell.kind == "prefill":
        return prefill_batch_specs(cfg, cell)
    assert model is not None, "decode specs need the model (cache schema)"
    return decode_inputs(cfg, cell, model)
