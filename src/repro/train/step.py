"""Training-step builder: loss + grads + AdamW, with full sharding specs
for jit (used identically by the live trainer and the dry-run)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.sharding import dp_axes
from repro.models.lm import Model
from repro.optim import AdamWConfig, adamw_update, global_norm, \
    init_opt_state, abstract_opt_state, opt_state_specs


def batch_specs(cfg, mesh: Mesh, kind: str) -> Dict[str, P]:
    dpx = dp_axes(mesh)
    specs: Dict[str, P] = {}
    if kind in ("train", "prefill"):
        specs["tokens"] = P(dpx, None)
        if kind == "train":
            specs["targets"] = P(dpx, None)
        if cfg.prefix_tokens:
            specs["patches"] = P(dpx, None, None)
        if cfg.encdec:
            specs["frames"] = P(dpx, None, None)
    else:  # decode: [B, 1] token
        specs["tokens"] = P(dpx, None)
    return specs


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    microbatches: Optional[int] = None):
    """Single optimizer step; with ``microbatches`` > 1, grads accumulate
    over a scan of microbatches (peak activation memory / n_micro)."""
    n_micro = microbatches if microbatches is not None \
        else model.cfg.microbatches

    def train_step(params, opt_state, batch):
        if n_micro <= 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                    *x.shape[1:]), batch)

            acc_dt = jnp.dtype(model.cfg.grad_accum_dtype)

            def acc_step(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(model.loss)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), g_acc, g)
                return (loss_acc + l, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (loss, grads), _ = jax.lax.scan(acc_step, (0.0, g0), micro)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)
        gnorm = global_norm(grads)
        params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def jit_train_step(model: Model, opt_cfg: AdamWConfig,
                   donate: bool = True):
    mesh = model.mesh
    pspecs = model.param_specs()
    ospecs = opt_state_specs(pspecs, opt_cfg)
    bspecs = batch_specs(model.cfg, mesh, "train")
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
    return jax.jit(
        make_train_step(model, opt_cfg),
        in_shardings=(ns(pspecs), ns(ospecs), ns(bspecs)),
        donate_argnums=(0, 1) if donate else (),
    )


def jit_prefill(model: Model):
    mesh = model.mesh
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
    b = model.cfg
    return jax.jit(
        model.prefill,
        in_shardings=(ns(model.param_specs()),
                      ns(batch_specs(b, mesh, "prefill"))),
    )


def jit_decode_step(model: Model, batch: int, max_len: int):
    mesh = model.mesh
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
    cspecs = model.cache_specs(batch, max_len)
    dpx = dp_axes(mesh)
    from repro.core.sharding import dp_size
    tok_spec = P(dpx, None) if batch % max(dp_size(mesh), 1) == 0 \
        and batch > 1 else P(None, None)
    return jax.jit(
        model.decode_step,
        in_shardings=(ns(model.param_specs()), ns(cspecs),
                      NamedSharding(mesh, tok_spec), None),
        donate_argnums=(1,),
    )
