"""Fault-tolerant training loop.

Production behaviors implemented (and unit-tested with injected faults):
  * periodic ASYNC checkpointing (atomic commits, keep-N);
  * automatic restart-from-latest-checkpoint on step failure, with
    bounded retries;
  * straggler watchdog: per-step wall-time EMA; steps slower than
    ``straggler_factor``x the EMA are logged with their step index —
    on a real cluster this feeds the scheduler's hot-spare swap;
  * elastic restart: ``Trainer.restore`` re-places the logical checkpoint
    onto WHATEVER mesh the surviving devices form (see
    checkpoint.manager); the data pipeline re-derives its stream position
    from the restored step with zero coordination;
  * failure injection for tests via ``fail_at_step`` /
    ``REPRO_FAIL_AT_STEP`` (raises inside the step, exercising the
    restore path).
"""
from __future__ import annotations

import dataclasses
import logging
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.models.lm import Model
from repro.optim import AdamWConfig, init_opt_state, opt_state_specs
from repro.train.step import jit_train_step

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_retries: int = 2
    straggler_factor: float = 3.0
    ema_alpha: float = 0.2
    log_every: int = 10
    fail_at_step: Optional[int] = None  # failure injection (tests)


class StragglerWatchdog:
    """EMA-based step-time anomaly detector."""

    def __init__(self, factor: float, alpha: float):
        self.factor = factor
        self.alpha = alpha
        self.ema: Optional[float] = None
        self.events: List[Dict[str, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        if slow:
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
            log.warning("straggler: step %d took %.3fs (ema %.3fs)",
                        step, dt, self.ema)
        self.ema = dt if self.ema is None else \
            (1 - self.alpha) * self.ema + self.alpha * dt
        return slow


class Trainer:
    def __init__(self, model: Model, opt_cfg: AdamWConfig,
                 tcfg: TrainerConfig, pipeline_factory: Callable[[int], Any]):
        """``pipeline_factory(start_step)`` -> iterator of (step, batch);
        called again after every restart so data resumes deterministically.
        """
        self.model = model
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.pipeline_factory = pipeline_factory
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.watchdog = StragglerWatchdog(tcfg.straggler_factor,
                                          tcfg.ema_alpha)
        self.step_fn = jit_train_step(model, opt_cfg)
        self.metrics: List[Dict[str, float]] = []

    # -- state ------------------------------------------------------------------

    def init_state(self, seed: int = 0):
        params = self.model.init_params(seed)
        opt = init_opt_state(params, self.opt_cfg)
        return params, opt

    def restore(self):
        """Elastic restore onto the model's (possibly new) mesh.  For
        fp32 optimizer state this also migrates pre-packing checkpoints
        (separate wq/wk/wv leaves) onto the packed schema: Adam moments
        are elementwise, so per-view moments pack exactly like the
        weights.  (int8 moment state cannot be migrated — its row scales
        are per packed array — so legacy int8 runs need packed_qkv=False
        or a fresh optimizer.)"""
        params_like = self.model.abstract_params()
        from repro.optim import abstract_opt_state
        opt_like = abstract_opt_state(params_like, self.opt_cfg)
        pspecs = self.model.param_specs()
        ospecs = opt_state_specs(pspecs, self.opt_cfg)
        defs = None
        if self.opt_cfg.state_mode == "fp32":
            pdefs = self.model.param_defs()
            defs = (pdefs, {"step": None, "m": pdefs, "v": pdefs})
        step, (params, opt) = self.ckpt.restore(
            None, (params_like, opt_like), self.model.mesh,
            (pspecs, ospecs), defs=defs)
        return step, params, opt

    # -- loop -------------------------------------------------------------------

    def run(self, seed: int = 0):
        tcfg = self.tcfg
        if self.ckpt.latest_step() is not None:
            start, params, opt = self.restore()
            log.info("resumed from checkpoint step %d", start)
        else:
            params, opt = self.init_state(seed)
            start = 0

        retries = 0
        step = start
        pipe = self.pipeline_factory(step)
        it = iter(pipe)
        fail_at = tcfg.fail_at_step
        if fail_at is None and os.environ.get("REPRO_FAIL_AT_STEP"):
            fail_at = int(os.environ["REPRO_FAIL_AT_STEP"])

        while step < tcfg.steps:
            try:
                data_step, batch = next(it)
                assert data_step == step, (data_step, step)
                t0 = time.time()
                if fail_at is not None and step == fail_at:
                    fail_at = None  # fail once
                    raise RuntimeError("injected node failure")
                params, opt, m = self.step_fn(params, opt, batch)
                loss = float(m["loss"])
                dt = time.time() - t0
                self.watchdog.observe(step, dt)
                self.metrics.append({"step": step, "loss": loss, "dt": dt})
                if step % tcfg.log_every == 0:
                    log.info("step %d loss %.4f (%.3fs)", step, loss, dt)
                step += 1
                if step % tcfg.ckpt_every == 0 or step == tcfg.steps:
                    self.ckpt.save(step, (params, opt))
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                retries += 1
                log.error("step %d failed (%s); retry %d/%d", step, e,
                          retries, tcfg.max_retries)
                if retries > tcfg.max_retries:
                    raise
                self.ckpt.wait()
                if self.ckpt.latest_step() is not None:
                    step, params, opt = self.restore()
                else:
                    params, opt = self.init_state(seed)
                    step = 0
                if hasattr(pipe, "close"):
                    pipe.close()
                pipe = self.pipeline_factory(step)
                it = iter(pipe)

        self.ckpt.wait()
        if hasattr(pipe, "close"):
            pipe.close()
        return params, opt
