"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly sequential — that is the architecture).

mLSTM recurrence (per head, state C [hd, hd], n [hd], stabilizer m):
  m_t = max(logf_t + m_{t-1}, logi_t)
  C_t = exp(logf_t + m_{t-1} - m_t) C_{t-1} + exp(logi_t - m_t) k_t v_t^T
  n_t = exp(logf_t + m_{t-1} - m_t) n_{t-1} + exp(logi_t - m_t) k_t
  h_t = (C_t^T q_t) / max(|n_t . q_t|, exp(-m_t))

Training/prefill runs the CHUNKWISE form: intra-chunk terms computed in
parallel (attention-like masked matmuls), inter-chunk state carried by a
scan over chunks — the TPU adaptation of the official fused CUDA kernels.
Correctness is property-tested against the per-step recurrence.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import TPCtx, rmsnorm
from repro.models.param import ParamDef
from repro.models.rglru import _causal_conv

_NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_defs(cfg: ArchConfig, model: int, dtype: str,
               fsdp: bool) -> Dict[str, ParamDef]:
    d = cfg.d_model
    w = 2 * d  # official mLSTM block: 2x up-projection
    col = P("data", "model") if fsdp else P(None, "model")
    row = P("model", "data") if fsdp else P("model", None)
    return {
        "up_x": ParamDef((d, w), col, dtype=dtype),
        "up_g": ParamDef((d, w), col, dtype=dtype),
        "conv": ParamDef((cfg.conv_width, w), P(None, "model"), dtype=dtype),
        "wq": ParamDef((w, w), col, dtype=dtype),
        "wk": ParamDef((w, w), col, dtype=dtype),
        "wv": ParamDef((w, w), col, dtype=dtype),
        "w_i": ParamDef((w, cfg.n_heads), P(None, None), dtype="float32"),
        "w_f": ParamDef((w, cfg.n_heads), P(None, None), dtype="float32"),
        "b_i": ParamDef((cfg.n_heads,), P(), init="zeros", dtype="float32"),
        "b_f": ParamDef((cfg.n_heads,), P(), init="custom", dtype="float32",
                        custom=lambda k: jnp.linspace(3.0, 6.0,
                                                      cfg.n_heads)),
        "norm": ParamDef((w,), P("model"), init="zeros", dtype="float32"),
        "down": ParamDef((w, d), row, dtype=dtype),
    }


def _mlstm_chunk(carry, qc, kc, vc, logf, logi):
    """One chunk. qc/kc/vc [B, L, H, hd]; logf/logi [B, L, H].
    carry = (C [B,H,hd,hd], n [B,H,hd], m [B,H])."""
    C, n, m = carry
    b, L, h, hd = qc.shape
    f32 = jnp.float32
    qc, kc, vc = qc.astype(f32), kc.astype(f32), vc.astype(f32)
    kc = kc * (hd ** -0.5)

    F = jnp.cumsum(logf, axis=1)                     # [B, L, H]
    # intra-chunk log decay matrix: D[t, s] = F_t - F_s + logi_s (s <= t)
    logD = (F[:, :, None] - F[:, None, :]
            + logi[:, None, :, :])                   # [B, t, s, H]
    tri = jnp.tril(jnp.ones((L, L), bool))
    logD = jnp.where(tri[None, :, :, None], logD, _NEG)

    # inter contribution decays the carried state: g_t = F_t + m_prev
    g = F + m[:, None]                               # [B, L, H]
    m_t = jnp.maximum(jnp.max(logD, axis=2), g)      # [B, L, H]

    intra_w = jnp.exp(logD - m_t[:, :, None])        # [B, t, s, H]
    scores = jnp.einsum("bthd,bshd->btsh", qc, kc) * intra_w
    num = jnp.einsum("btsh,bshd->bthd", scores, vc)
    # normalizer n-vector: sum_s w_{t,s} k_s
    nvec = jnp.einsum("btsh,bshd->bthd", intra_w, kc)

    inter_w = jnp.exp(g - m_t)                       # [B, L, H]
    num = num + jnp.einsum("bthd,bhde,bth->bthe", qc, C, inter_w)
    nvec = nvec + n[:, None] * inter_w[..., None]

    qn = jnp.abs(jnp.einsum("bthd,bthd->bth", qc, nvec))
    hout = num / jnp.maximum(qn, jnp.exp(-m_t))[..., None]

    # carry update to end of chunk
    m_new = jnp.maximum(F[:, -1] + m, jnp.max(
        F[:, -1:, :] - F + logi, axis=1))            # [B, H]
    wk = jnp.exp(F[:, -1:, :] - F + logi - m_new[:, None])  # [B, L, H]
    C_new = (jnp.exp(F[:, -1] + m - m_new)[..., None, None] * C
             + jnp.einsum("blh,blhd,blhe->bhde", wk, kc, vc))
    n_new = (jnp.exp(F[:, -1] + m - m_new)[..., None] * n
             + jnp.einsum("blh,blhd->bhd", wk, kc))
    return (C_new, n_new, m_new), hout


def mlstm_step(carry, q, k, v, logf, logi):
    """Single-token recurrence. q/k/v [B, H, hd]; logf/logi [B, H]."""
    C, n, m = carry
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    k = k * (k.shape[-1] ** -0.5)
    m_new = jnp.maximum(logf + m, logi)
    fw = jnp.exp(logf + m - m_new)
    iw = jnp.exp(logi - m_new)
    C = fw[..., None, None] * C + iw[..., None, None] \
        * (k[..., :, None] * v[..., None, :])
    n = fw[..., None] * n + iw[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    qn = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n))
    h = num / jnp.maximum(qn, jnp.exp(-m_new))[..., None]
    return (C, n, m_new), h


def mlstm_apply(params, x, cfg: ArchConfig, ctx: TPCtx,
                cache: Optional[Dict[str, jnp.ndarray]] = None,
                chunk: int = 64, return_state: bool = False):
    """x [B, S, D] -> ([B, S, D], new_cache)."""
    cd = ctx.compute_dtype
    b, s, d = x.shape
    nh = cfg.n_heads
    xb = jnp.einsum("bsd,dw->bsw", x, params["up_x"].astype(cd))
    gb = jnp.einsum("bsd,dw->bsw", x, params["up_g"].astype(cd))
    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xb, params["conv"].astype(cd), conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(cd)

    w = xc.shape[-1]
    hd = w // nh
    f32 = jnp.float32
    q = jnp.einsum("bsw,wv->bsv", xc, params["wq"].astype(cd)) \
        .reshape(b, s, nh, hd)
    k = jnp.einsum("bsw,wv->bsv", xc, params["wk"].astype(cd)) \
        .reshape(b, s, nh, hd)
    v = jnp.einsum("bsw,wv->bsv", xc, params["wv"].astype(cd)) \
        .reshape(b, s, nh, hd)
    # the recurrence runs at f32 regardless of param/compute dtype (the
    # scan carry is f32; f64 reference runs must not widen it)
    logi = (jnp.einsum("bsw,wh->bsh", xc.astype(f32),
                       params["w_i"].astype(f32))
            + params["b_i"].astype(f32))
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bsw,wh->bsh", xc.astype(f32),
                   params["w_f"].astype(f32))
        + params["b_f"].astype(f32))

    if cache is None:
        chunk = min(chunk, s)
        assert s % chunk == 0
        nc = s // chunk

        def step(carry, inp):
            qc, kc, vc, lf, li = inp
            carry, h = _mlstm_chunk(carry, qc, kc, vc, lf, li)
            return carry, h

        def r(t):  # [B, S, ...] -> [nc, B, chunk, ...]
            return jnp.moveaxis(
                t.reshape(b, nc, chunk, *t.shape[2:]), 1, 0)

        C0 = jnp.zeros((b, nh, hd, hd), f32)
        n0 = jnp.zeros((b, nh, hd), f32)
        m0 = jnp.full((b, nh), 0.0, f32)
        final, hs = jax.lax.scan(step, (C0, n0, m0),
                                 (r(q), r(k), r(v), r(logf), r(logi)))
        h = jnp.moveaxis(hs, 0, 1).reshape(b, s, nh, hd)
        new_cache = None
        if return_state:
            new_cache = {"C": final[0], "n": final[1], "m": final[2],
                         "conv": new_conv}
    else:
        carry = (cache["C"].astype(f32), cache["n"].astype(f32),
                 cache["m"].astype(f32))
        carry, h1 = mlstm_step(carry, q[:, 0], k[:, 0], v[:, 0],
                               logf[:, 0], logi[:, 0])
        h = h1[:, None]
        new_cache = dict(cache, C=carry[0], n=carry[1], m=carry[2],
                         conv=new_conv)
        h = h.reshape(b, 1, nh, hd)

    hflat = h.reshape(b, s if cache is None else 1, w).astype(cd)
    hflat = rmsnorm(hflat, params["norm"], 1e-6)
    out = hflat * jax.nn.silu(gb.astype(f32)).astype(cd)
    y = jnp.einsum("bsw,wd->bsd", out, params["down"].astype(cd))
    return y, new_cache


def mlstm_cache_defs(cfg: ArchConfig, batch: int, dtype: str):
    w = 2 * cfg.d_model
    nh, hd = cfg.n_heads, 2 * cfg.d_model // cfg.n_heads
    return {
        # shard the (always 16-divisible) head_dim: nh can be tiny (4)
        "C": ParamDef((batch, nh, hd, hd), P(None, None, "model", None),
                      init="zeros", dtype="float32"),
        "n": ParamDef((batch, nh, hd), P(None, None, "model"),
                      init="zeros", dtype="float32"),
        "m": ParamDef((batch, nh), P(), init="zeros", dtype="float32"),
        "conv": ParamDef((batch, cfg.conv_width - 1, w),
                         P(None, None, "model"), init="zeros", dtype=dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_defs(cfg: ArchConfig, model: int, dtype: str,
               fsdp: bool) -> Dict[str, ParamDef]:
    d = cfg.d_model
    w = d
    nh = cfg.n_heads
    col = P("data", "model") if fsdp else P(None, "model")
    return {
        # input maps for z, i, f, o
        "w_in": ParamDef((d, 4 * w), col, dtype=dtype),
        # block-diagonal recurrent maps (per head)
        "r": ParamDef((4, nh, w // nh, w // nh), P(), dtype="float32",
                      scale=0.05),
        "bias": ParamDef((4 * w,), P(), init="zeros", dtype="float32"),
        "norm": ParamDef((w,), P("model"), init="zeros", dtype="float32"),
        "out": ParamDef((w, d), P("model", None) if not fsdp
                        else P("model", "data"), dtype=dtype),
    }


def _slstm_step(params, carry, xz):
    """carry = (c, n, m, h) each [B, W]; xz [B, 4W] precomputed input map."""
    c, n, m, h = carry
    f32 = jnp.float32
    w = c.shape[-1]
    nh = params["r"].shape[1]
    hh = h.reshape(h.shape[0], nh, -1)
    rec = jnp.einsum("bhx,khxy->kbhy", hh,
                     params["r"].astype(f32)).reshape(4, h.shape[0], w)
    z = jnp.tanh(xz[:, :w] + rec[0])
    logi = xz[:, w:2 * w] + rec[1]
    logf = jax.nn.log_sigmoid(xz[:, 2 * w:3 * w] + rec[2])
    o = jax.nn.sigmoid(xz[:, 3 * w:] + rec[3])
    m_new = jnp.maximum(logf + m, logi)
    iw = jnp.exp(logi - m_new)
    fw = jnp.exp(logf + m - m_new)
    c = fw * c + iw * z
    n = fw * n + iw
    h_new = o * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h_new), h_new


def slstm_apply(params, x, cfg: ArchConfig, ctx: TPCtx,
                cache: Optional[Dict[str, jnp.ndarray]] = None,
                return_state: bool = False):
    cd = ctx.compute_dtype
    b, s, d = x.shape
    w = d
    f32 = jnp.float32
    xz = (jnp.einsum("bsd,dk->bsk", x.astype(f32),
                     params["w_in"].astype(f32))
          + params["bias"].astype(f32))

    if cache is None:
        init = tuple(jnp.zeros((b, w), f32) for _ in range(4))
        (c, n, m, h), hs = jax.lax.scan(
            lambda cr, xt: _slstm_step(params, cr, xt),
            init, jnp.moveaxis(xz, 0, 1))
        h_seq = jnp.moveaxis(hs, 0, 1)
        new_cache = None
        if return_state:
            new_cache = {"c": c, "n": n, "m": m, "h": h}
    else:
        carry = (cache["c"].astype(f32), cache["n"].astype(f32),
                 cache["m"].astype(f32), cache["h"].astype(f32))
        carry, h1 = _slstm_step(params, carry, xz[:, 0])
        h_seq = h1[:, None]
        new_cache = dict(cache, c=carry[0], n=carry[1], m=carry[2],
                         h=carry[3])

    h_seq = rmsnorm(h_seq.astype(cd), params["norm"], 1e-6)
    return jnp.einsum("bsw,wd->bsd", h_seq,
                      params["out"].astype(cd)), new_cache


def slstm_cache_defs(cfg: ArchConfig, batch: int, dtype: str):
    w = cfg.d_model
    return {k: ParamDef((batch, w), P(), init="zeros", dtype="float32")
            for k in ("c", "n", "m", "h")}
