"""Mixture-of-Experts FFN: sort-based capacity dispatch, expert-parallel.

Dispatch is O(N log N) (argsort by expert), NOT the O(N*E*C) dense GShard
dispatch — at 32k-sequence cells the dense dispatch tensor would be
terabytes.  Crucially, dispatch/combine run PER DATA SHARD (a vmap over a
data-sharded leading axis): a scatter with data-dependent indices cannot
be partitioned by GSPMD, so a global dispatch replicates the full token
buffer on every device (measured 60 GB/device on grok before this
restructure).  Per-shard capacity is also what real deployments use.

Experts are sharded over the model axis (EP) when the expert count divides
it (llama4 16e), else the FF dim is model-sharded (TP within experts,
grok 8e over 16); ``fsdp_params`` additionally shards expert weights over
the data axis (ZeRO-3 gathers at use).  Dropped tokens (capacity overflow)
pass through the residual, standard practice.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.sharding import constrain, dp_size
from repro.models.layers import TPCtx
from repro.models.param import ParamDef


def moe_defs(cfg: ArchConfig, model: int, dtype: str,
             fsdp: bool) -> Dict[str, ParamDef]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    if e % max(model, 1) == 0:
        # expert-parallel: E over the model axis (llama4: 16e)
        up_spec = P("model", "data", None) if fsdp else P("model", None,
                                                          None)
        down_spec = up_spec
    else:
        # E does not divide the axis (grok: 8e over 16): shard the FF dim
        # over model (TP within experts) + FSDP over data
        up_spec = P(None, "data", "model") if fsdp else P(None, None,
                                                          "model")
        down_spec = P(None, "model", "data") if fsdp else P(None, "model",
                                                            None)
    defs = {
        "router": ParamDef((d, e), P(), dtype="float32"),
        "w_up": ParamDef((e, d, f), up_spec, dtype=dtype),
        "w_down": ParamDef((e, f, d), down_spec, dtype=dtype),
    }
    if cfg.gated_mlp:
        defs["w_gate"] = ParamDef((e, d, f), up_spec, dtype=dtype)
    if cfg.moe_shared_expert:
        sspec = P(None, "model")
        defs["shared_up"] = ParamDef((d, f), sspec, dtype=dtype)
        defs["shared_down"] = ParamDef((f, d), P("model", None), dtype=dtype)
        if cfg.gated_mlp:
            defs["shared_gate"] = ParamDef((d, f), sspec, dtype=dtype)
    return defs


def _capacity(n_tokens: int, cfg: ArchConfig, model: int) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    mult = max(model, 8)  # mesh-divisible, MXU-friendly
    return max(mult, (c + mult - 1) // mult * mult)


def _dispatch_one_shard(xt, probs, cap: int, e: int, k: int, cd):
    """One data shard: xt [n, D], probs [n, E] ->
    (xe [E, C, D], st [n*k], dest [n*k], gates [n*k], keep [n*k])."""
    n, d = xt.shape
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # [n, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    flat_e = expert_ids.reshape(-1)
    flat_g = gate_vals.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(n), k)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    starts = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(n * k) - starts[se]
    keep = pos_in_e < cap
    dest = jnp.where(keep, se * cap + pos_in_e, e * cap)     # overflow slot

    xe = jnp.zeros((e * cap + 1, d), cd).at[dest].set(
        xt[st].astype(cd) * keep[:, None].astype(cd))[:-1]
    return xe.reshape(e, cap, d), st, dest, sg, keep


def _combine_one_shard(ye, st, dest, sg, keep, n: int, e: int, cap: int):
    d = ye.shape[-1]
    ye_flat = jnp.concatenate(
        [ye.reshape(e * cap, d), jnp.zeros((1, d), ye.dtype)], axis=0)
    contrib = ye_flat[jnp.where(keep, dest, e * cap)] \
        * (sg * keep).astype(ye.dtype)[:, None]
    return jnp.zeros((n, d), jnp.float32).at[st].add(
        contrib.astype(jnp.float32))


def moe_apply(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
              cfg: ArchConfig, ctx: TPCtx) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] (replicated over model) -> (out [B, S, D], aux_loss)."""
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    cd = ctx.compute_dtype

    # data-shard the token stream for dispatch locality
    ds = dp_size(ctx.mesh)
    if n % max(ds, 1) != 0 or n < ds * e:
        ds = 1
    n_loc = n // ds
    xt = x.reshape(ds, n_loc, d)
    xt = constrain(xt, ctx.mesh, P(ctx.dp, None, None))

    logits = jnp.einsum("xnd,de->xne", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)

    # load-balancing aux loss (Switch/GShard form), global over all shards
    top1 = jnp.argmax(probs, axis=-1)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    cap = _capacity(n_loc, cfg, ctx.model)
    xe, st, dest, sg, keep = jax.vmap(
        functools.partial(_dispatch_one_shard, cap=cap, e=e, k=k, cd=cd)
    )(xt, probs)
    # xe [ds, E, C, D]: tokens stay on their data shard.  EP archs shard
    # the expert dim over model; non-divisible expert counts (grok 8e/16)
    # shard the FF dim over model instead (TP within experts) — the expert
    # weights are consumed in their stored sharding, so no multi-GB weight
    # gathers appear in the layer body.
    ep = e % max(ctx.model, 1) == 0
    espec = P(ctx.dp, "model", None, None) if ep \
        else P(ctx.dp, None, None, None)
    hspec = P(ctx.dp, "model", None, None) if ep \
        else P(ctx.dp, None, None, "model")
    xe = constrain(xe, ctx.mesh, espec)

    h = jnp.einsum("xecd,edf->xecf", xe, params["w_up"].astype(cd))
    if cfg.gated_mlp:
        g = jnp.einsum("xecd,edf->xecf", xe, params["w_gate"].astype(cd))
        # einsum expert path: the gate multiply cannot ride a GEMM
        # epilogue here; tag it so the fusion audit sees a deliberate
        # unfused site rather than a regression
        with jax.named_scope("gate_mul_unfused"):
            h = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(cd)
    h = constrain(h, ctx.mesh, hspec)
    ye = jnp.einsum("xecf,efd->xecd", h, params["w_down"].astype(cd))
    ye = constrain(ye, ctx.mesh, espec)

    out = jax.vmap(
        functools.partial(_combine_one_shard, n=n_loc, e=e, cap=cap)
    )(ye, st, dest, sg, keep)
    out = constrain(out, ctx.mesh, P(ctx.dp, None, None))

    # shared expert (llama4): plain dense MLP on the sharded stream
    if "shared_up" in params:
        hs = jnp.einsum("xnd,df->xnf", xt, params["shared_up"].astype(cd))
        if cfg.gated_mlp:
            gs = jnp.einsum("xnd,df->xnf", xt,
                            params["shared_gate"].astype(cd))
            with jax.named_scope("gate_mul_unfused"):
                hs = jax.nn.silu(gs.astype(jnp.float32)).astype(cd) * hs
        else:
            hs = jax.nn.gelu(hs.astype(jnp.float32)).astype(cd)
        out = out + jnp.einsum("xnf,fd->xnd", hs,
                               params["shared_down"].astype(cd)) \
            .astype(jnp.float32)

    return out.astype(cd).reshape(b, s, d), aux
