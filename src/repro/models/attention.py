"""Attention: chunked-flash training/prefill path, cached decode path.

Variants required by the assigned archs:
  * global causal (all), GQA grouping (q heads grouped over kv heads)
  * sliding-window 'local' (gemma2/3, recurrentgemma)
  * 'chunked' iRoPE-style block-local (llama4)
  * prefix-LM bidirectional prefix (paligemma)
  * bidirectional 'full' + cross-attention (whisper)
  * attention-logit softcap (gemma2)

The flash path is a jnp scan over (q-chunk x kv-chunk) blocks with running
(max, denom, acc) — the working set stays O(chunk^2), which is what makes
the 32k prefill cells compilable.  Local/chunked kinds slice a static-size
kv window per q chunk instead of scanning all kv (O(S * W) not O(S^2)).

Sharding: projections are GSPMD-sharded einsums (weights column/row
sharded over the model axis — the X*Z / Y*Z mapping, compiler-scheduled);
the attention core constrains the kv-head dim over 'model' when divisible,
else the head_dim (always divisible: 16 | hd for every assigned arch).
"""
from __future__ import annotations

import functools
import math
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.sharding import constrain
from repro.models.layers import TPCtx, rope
from repro.models.param import ParamDef, split_packed_columns

_NEG = -1e30

# Flash-kernel dispatch switch (PR 9).  Default ON: decode and paged
# decode take the tiled flash paths in ``kernels/flash_attention.py``
# (per-tile dots at storage dtype, rank-order split combine — no
# full-cache fp32 upcast in the traced HLO).  Off (env REPRO_FLASH_ATTN
# in {0, off, false} or ``set_flash_attention(False)``) restores the
# einsum paths — kept as the benchmark control and for the ring-buffer
# decode layouts the flash kernels don't cover.
_FLASH_ATTN = os.environ.get(
    "REPRO_FLASH_ATTN", "on").lower() not in ("0", "off", "false")


def use_flash_attention() -> bool:
    return _FLASH_ATTN


def set_flash_attention(on: bool) -> None:
    """Process-global, like ``kernels.ops.set_kernel_mode``.  Callers
    re-tracing jitted serving steps (the benchmarks do) must build a
    fresh engine afterwards — the branch is baked in at trace time."""
    global _FLASH_ATTN
    _FLASH_ATTN = bool(on)


def use_xyz_attn_out(cfg: ArchConfig, model: int) -> bool:
    """o-proj through the MaxEVA xyz row-parallel path (adder tree +
    sequence scatter) — needs whole heads per model shard."""
    return (model > 1 and cfg.n_heads % model == 0
            and cfg.q_dim % model == 0)


def qkv_packing(cfg: ArchConfig) -> int:
    """MESH-INDEPENDENT shard-interleave factor of the packed wqkv column
    axis: gcd(q_dim, kv_dim).  The packed columns are laid out in G
    groups, each [wq_g | wk_g | wv_g].  Any model-parallel degree m the
    fused path can use divides both view sizes, hence divides G, so every
    m-shard's local columns are whole groups in order and split locally
    with cheap slices (``split_packed_columns`` with interleave G/m).
    Because the layout never depends on the mesh, packed checkpoints
    restore elastically across different model sizes."""
    return math.gcd(cfg.q_dim, cfg.kv_dim)


def qkv_sizes(cfg: ArchConfig) -> Tuple[int, int, int]:
    return (cfg.q_dim, cfg.kv_dim, cfg.kv_dim)


def attn_defs(cfg: ArchConfig, model: int, dtype: str,
              fsdp: bool, packed: bool = True) -> Dict[str, ParamDef]:
    d = cfg.d_model
    col = P("data", "model") if fsdp else P(None, "model")
    row = P("model", "data") if fsdp else P("model", None)
    if packed:
        # ONE column-sharded (d, q_dim + 2*kv_dim) array; apply time pays
        # a single GEMM dispatch and zero weight copies.  Checkpoints and
        # reference math see the logical wq/wk/wv through the split views.
        defs = {
            "wqkv": ParamDef(
                (d, cfg.q_dim + 2 * cfg.kv_dim), col, dtype=dtype,
                views=(("wq", cfg.q_dim), ("wk", cfg.kv_dim),
                       ("wv", cfg.kv_dim)),
                packing=qkv_packing(cfg)),
        }
    else:
        # unpacked: cross-attention applies wq and wk/wv to DIFFERENT
        # inputs, so packing would force a per-step weight slice there
        defs = {
            "wq": ParamDef((d, cfg.q_dim), col, dtype=dtype),
            "wk": ParamDef((d, cfg.kv_dim), col, dtype=dtype),
            "wv": ParamDef((d, cfg.kv_dim), col, dtype=dtype),
        }
    if use_xyz_attn_out(cfg, model):
        from repro.core.maxeva_matmul import xyz_weight_shape
        defs["wo"] = ParamDef(
            xyz_weight_shape(cfg.q_dim, d, model, model),
            P("model", "data", None) if fsdp else P("model", None, None),
            dtype=dtype)
    else:
        defs["wo"] = ParamDef((cfg.q_dim, d), row, dtype=dtype)
    return defs


def _head_spec(n_heads: int, ctx: TPCtx) -> P:
    """[B, S, H, hd]: shard heads over model.  GSPMD pads uneven head
    counts (whisper 12, llama4 40); past 2x padding fall back to
    replicated heads (none of the assigned archs hit that)."""
    if ctx.model == 1:
        return P()
    if n_heads * 2 >= ctx.model:
        return P(ctx.dp, None, "model", None)
    return P(ctx.dp, None, None, None)


def _constrain_qkv(q, k, v, cfg: ArchConfig, ctx: TPCtx):
    """All three in head-expanded layout [B, S, H, hd]; heads are the
    paper's Z axis — fully parallel, zero collectives inside the flash
    loops."""
    if ctx.model == 1:
        return q, k, v
    spec = _head_spec(cfg.n_heads, ctx)
    return (constrain(q, ctx.mesh, spec), constrain(k, ctx.mesh, spec),
            constrain(v, ctx.mesh, spec))


def fused_qkv_sp(params, x_sharded, cfg: ArchConfig, ctx: TPCtx):
    """QKV projection in ONE shard_map over seq-sharded input: the
    sequence all-gather (broadcast) happens inside, so its backward is the
    AG transpose (reduce-scatter) instead of one all-reduce of [B,S,D] per
    projection (§Perf iteration 3).  q comes out head-sharded; k/v are
    re-gathered to full (they are g-times smaller).

    With a packed ``wqkv`` (packing == model) every model shard's local
    columns are [wq_i | wk_i | wv_i], so the body issues ONE planned
    blocked GEMM per step with zero weight copies and splits the
    activation output by cheap contiguous slices."""
    from repro.core.maxeva_matmul import _shard_map
    from repro.models.layers import _row_spec
    mesh = ctx.mesh
    rs = _row_spec(x_sharded, ctx)
    cd = ctx.compute_dtype
    packed = "wqkv" in params
    qloc = cfg.q_dim // ctx.model
    kvloc = cfg.kv_dim // ctx.model
    # local interleave: each model shard holds G/m whole [q|k|v] groups
    g_local = qkv_packing(cfg) // ctx.model if packed else 1

    def body_packed(xl, wl):
        x2 = jax.lax.all_gather(xl, "model", axis=1, tiled=True)
        b, s, _ = x2.shape
        xf = x2.reshape(b * s, -1).astype(cd)
        from repro.kernels import ops as kops
        # single-dispatch QKV: one planned blocked GEMM with the
        # compute-dtype cast fused into the store phase (fp32
        # accumulation, no accumulator round trip)
        y = kops.matmul(xf, wl, out_dtype=cd).reshape(b, s, -1)
        q, k, v = split_packed_columns(y, (qloc, kvloc, kvloc), g_local)
        k = jax.lax.all_gather(k, "model", axis=2, tiled=True)
        v = jax.lax.all_gather(v, "model", axis=2, tiled=True)
        return q, k, v

    def body_legacy(xl, wq, wk, wv):
        x2 = jax.lax.all_gather(xl, "model", axis=1, tiled=True)
        b, s, _ = x2.shape
        xf = x2.reshape(b * s, -1).astype(cd)
        from repro.kernels import ops as kops
        q = kops.matmul(xf, wq, out_dtype=cd).reshape(b, s, -1)
        k = kops.matmul(xf, wk, out_dtype=cd).reshape(b, s, -1)
        v = kops.matmul(xf, wv, out_dtype=cd).reshape(b, s, -1)
        k = jax.lax.all_gather(k, "model", axis=2, tiled=True)
        v = jax.lax.all_gather(v, "model", axis=2, tiled=True)
        return q, k, v

    out_specs = (P(rs, None, "model"), P(rs, None, None), P(rs, None, None))
    if packed:
        q, k, v = _shard_map(
            body_packed, mesh, (P(rs, "model", None), P(None, "model")),
            out_specs)(x_sharded, params["wqkv"])
    else:
        q, k, v = _shard_map(
            body_legacy, mesh,
            (P(rs, "model", None), P(None, "model"), P(None, "model"),
             P(None, "model")),
            out_specs)(x_sharded, params["wq"], params["wk"], params["wv"])
    b, s = q.shape[0], q.shape[1]
    return (q.reshape(b, s, cfg.n_heads, cfg.hd),
            k.reshape(b, s, cfg.n_kv_heads, cfg.hd),
            v.reshape(b, s, cfg.n_kv_heads, cfg.hd))


def project_qkv(params, x, cfg: ArchConfig, ctx: TPCtx):
    """Replicated-input QKV projection (train/prefill fallback and decode):
    one GEMM dispatch against the packed ``wqkv`` — the SAME computation in
    every mode, which is what makes prefill and decode round identically —
    with the split paid on the activation output, never the weights.
    Legacy unpacked params fall back to three GEMMs.

    Returns head-expanded (q [B,S,H,hd], k [B,S,KV,hd], v [B,S,KV,hd]),
    un-roped."""
    b, s, _ = x.shape
    cd = ctx.compute_dtype
    if "wqkv" not in params:
        q = jnp.einsum("bsd,dn->bsn", x, params["wq"].astype(cd))
        k = jnp.einsum("bsd,dn->bsn", x, params["wk"].astype(cd))
        v = jnp.einsum("bsd,dn->bsn", x, params["wv"].astype(cd))
    else:
        from repro.kernels.quantize import QuantizedWeight
        w = params["wqkv"]
        if isinstance(w, QuantizedWeight):
            # int8 serving path (single-shard): the normed stream is
            # rowwise-quantized, ONE int8 x int8 -> int32 GEMM covers all
            # of Q/K/V (packed invariant preserved), and both scales come
            # back inside the fused epilogue — the packed weight is never
            # dequantized to fp
            from repro.kernels import ops as kops
            y = kops.matmul(x.reshape(b * s, -1), w,
                            out_dtype=cd).reshape(b, s, -1)
        elif ctx.model == 1:
            # planned blocked GEMM, cast fused into the store phase
            from repro.kernels import ops as kops
            y = kops.matmul(x.reshape(b * s, -1), w.astype(cd),
                            out_dtype=cd).reshape(b, s, -1)
        else:
            y = jnp.einsum("bsd,dn->bsn", x, w.astype(cd))
        q, k, v = split_packed_columns(y, qkv_sizes(cfg),
                                       qkv_packing(cfg))
    return (q.reshape(b, s, cfg.n_heads, cfg.hd),
            k.reshape(b, s, cfg.n_kv_heads, cfg.hd),
            v.reshape(b, s, cfg.n_kv_heads, cfg.hd))


# ---------------------------------------------------------------------------
# flash attention (train / prefill)
# ---------------------------------------------------------------------------

def _block_attend(qc, kc, vc, qpos, kpos, *, kind, window, prefix_len,
                  softcap, carry=None):
    """One (q-chunk, kv-chunk) block with running-softmax carry.

    Head-expanded layout: qc [B, Cq, H, hd]; kc/vc [B, Ck, H, hd] (GQA kv
    heads repeated to H before sharding — heads are the fully-parallel Z
    axis).  Positions are global.
    carry = (m [B,H,Cq], l [B,H,Cq], acc [B,H,Cq,hd]).
    """
    s = jnp.einsum("bqhd,bKhd->bhqK", qc.astype(jnp.float32),
                   kc.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if kind in ("global", "local", "chunked", "prefix"):
        mask &= qpos[:, None] >= kpos[None, :]
    if kind == "local":
        mask &= (qpos[:, None] - kpos[None, :]) < window
    if kind == "chunked":
        mask &= (qpos[:, None] // window) == (kpos[None, :] // window)
    if kind == "prefix":
        mask |= kpos[None, :] < prefix_len
        mask &= kpos[None, :] >= 0
    mask &= kpos[None, :] >= 0  # left/right padding of kv slices
    s = jnp.where(mask[None, None], s, _NEG)

    if carry is None:
        b, ck, h, hd = kc.shape
        cq = qc.shape[1]
        m = jnp.full((b, h, cq), _NEG, jnp.float32)
        l = jnp.zeros((b, h, cq), jnp.float32)
        acc = jnp.zeros((b, h, cq, hd), jnp.float32)
    else:
        m, l, acc = carry

    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    # guard fully-masked rows: exp(_NEG - _NEG) would be 1
    alpha = jnp.exp(jnp.minimum(m - m_new, 0.0))
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(mask[None, None], p, 0.0)
    l = l * alpha + jnp.sum(p, axis=-1)
    acc = acc * alpha[..., None] + jnp.einsum("bhqK,bKhd->bhqd", p,
                                              vc.astype(jnp.float32))
    return m_new, l, acc


def flash_attention(q, k, v, *, kind="global", window=0, prefix_len=0,
                    softcap=None, q_chunk=512, kv_chunk=512,
                    q_offset=0) -> jnp.ndarray:
    """Head-expanded: q/k/v [B, S, H, hd] -> [B, Sq, H, hd].

    ``q_offset``: global position of q[0] (prefill continuation).
    """
    b, sq, n_h, hd = q.shape
    skv = k.shape[1]
    q = q * (hd ** -0.5)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    sq_orig = sq
    if sq % q_chunk != 0:  # e.g. whisper's 1500 encoder frames
        q = jnp.pad(q, ((0, 0), (0, q_chunk - sq % q_chunk), (0, 0),
                        (0, 0)))
        sq = q.shape[1]
    nq = sq // q_chunk

    windowed = kind in ("local", "chunked") and window > 0 and skv > window

    if windowed:
        assert q_offset == 0, "windowed flash supports q_offset=0 only"
        # pad kv on the left so every q chunk slices a static-size window:
        # q chunk qi needs global kpos in [qi*Cq - W, qi*Cq + Cq) for both
        # 'local' (sliding) and 'chunked' (block-aligned; mask trims).
        # The right pad covers the q-padding tail (sq rounded up to a
        # q_chunk multiple): without it the last chunk's slice start gets
        # CLAMPED by dynamic_slice and real rows attend through
        # mislabeled positions.
        pad = window
        kp = jnp.pad(k, ((0, 0), (pad, max(0, sq - skv)), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad, max(0, sq - skv)), (0, 0), (0, 0)))

        def per_q(qi):
            qc = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, 1)
            qpos = qi * q_chunk + jnp.arange(q_chunk)
            # padded index of global position p is p + W
            kc = jax.lax.dynamic_slice_in_dim(kp, qi * q_chunk,
                                              window + q_chunk, 1)
            vc = jax.lax.dynamic_slice_in_dim(vp, qi * q_chunk,
                                              window + q_chunk, 1)
            kpos = qi * q_chunk - window + jnp.arange(window + q_chunk)
            kpos = jnp.where(kpos < skv, kpos, -1)  # right-pad mask
            m, l, acc = _block_attend(qc, kc, vc, qpos, kpos, kind=kind,
                                      window=window, prefix_len=prefix_len,
                                      softcap=softcap)
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            return jnp.einsum("bhqd->bqhd", out)

        outs = jax.lax.map(per_q, jnp.arange(nq))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, n_h, hd)
        return out[:, :sq_orig].astype(q.dtype)

    # global / full / prefix: scan kv chunks with running softmax
    kv_len = skv
    if skv % kv_chunk != 0:  # e.g. whisper's 1500 encoder frames
        pad = kv_chunk - skv % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        skv = k.shape[1]
    nk = skv // kv_chunk
    kr = jnp.moveaxis(k.reshape(b, nk, kv_chunk, n_h, hd), 1, 0)
    vr = jnp.moveaxis(v.reshape(b, nk, kv_chunk, n_h, hd), 1, 0)

    def per_q(qi):
        qc = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, 1)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            kj, kc, vc = inp
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            kpos = jnp.where(kpos < kv_len, kpos, -1)  # right-pad mask
            carry = _block_attend(qc, kc, vc, qpos, kpos, kind=kind,
                                  window=window, prefix_len=prefix_len,
                                  softcap=softcap, carry=carry)
            return carry, None

        m0 = jnp.full((b, n_h, q_chunk), _NEG, jnp.float32)
        l0 = jnp.zeros((b, n_h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, n_h, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.einsum("bhqd->bqhd", out)

    outs = jax.lax.map(per_q, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, n_h, hd)
    return out[:, :sq_orig].astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention (single new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, pos, *, kind="global", window=0,
                     softcap=None) -> jnp.ndarray:
    """q [B, 1, kv, g, hd]; caches [B, S, kv, hd] (global) or ring buffers
    [B, W, kv, hd] (local/chunked).  ``pos`` is the current position.

    Dispatch: 'global'/'full' take the tiled flash-decode path (per-tile
    dots at the cache's storage dtype, deterministic rank-order split
    combine); the ring-buffer kinds keep the einsum path — their slot ->
    position remap breaks the tiles-anchored-at-0 contract, and the ring
    buffer is already window-sized, so there is no full-cache upcast to
    avoid there."""
    if use_flash_attention() and kind in ("global", "full"):
        from repro.kernels import ops as kops
        return kops.flash_decode(q, k_cache, v_cache, pos, kind=kind,
                                 softcap=softcap)
    return decode_attention_einsum(q, k_cache, v_cache, pos, kind=kind,
                                   window=window, softcap=softcap)


def decode_attention_einsum(q, k_cache, v_cache, pos, *, kind="global",
                            window=0, softcap=None) -> jnp.ndarray:
    """The pre-flash einsum decode.  Scores run as a single dot at the
    cache's storage dtype with fp32 accumulation
    (``preferred_element_type``) and the probabilities are cast DOWN to
    the storage dtype for the value dot — the old path upcast the whole
    K and V caches to fp32 every step, a full-pool HBM round-trip per
    token (the PR 9 satellite bug)."""
    hd = q.shape[-1]
    s = jnp.einsum("bqkgd,bKkd->bkgqK", q.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32)
    s = s * jnp.float32(hd) ** -0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    slots = jnp.arange(k_cache.shape[1])
    if kind == "full":          # cross-attention: every slot is valid
        valid = slots >= 0
    elif kind == "global":
        valid = slots <= pos
    else:
        w = k_cache.shape[1]
        # ring buffer: slot j holds global position pos - ((pos - j) mod W)
        kpos = pos - jnp.mod(pos - slots, w)
        valid = (kpos >= 0) & (kpos <= pos)
        if kind == "local":
            valid &= (pos - kpos) < window
        else:  # chunked
            valid &= (kpos // window) == (pos // window)
    s = jnp.where(valid[None, None, None, None, :], s, _NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(valid[None, None, None, None, :], p, 0.0)
    out = jnp.einsum("bkgqK,bKkd->bkgqd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(jnp.sum(p, axis=-1)[..., None], 1e-30)
    return jnp.einsum("bkgqd->bqkgd", out).astype(q.dtype)


def update_cache(k_cache, v_cache, k_new, v_new, pos, *, ring: bool):
    """Insert [B, 1, kv, hd] at ``pos`` (mod W for ring buffers)."""
    slot = jnp.mod(pos, k_cache.shape[1]) if ring else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# paged KV cache (serving): pooled fixed-size pages + lane -> page-table
# indirection, so serving lanes admit/retire requests without re-jitting
# ---------------------------------------------------------------------------

def paged_update(k_pool, v_pool, k_new, v_new, positions, page_table):
    """Scatter freshly projected K/V rows through the page table.

    ``k_pool``/``v_pool`` [NP, PS, kv, hd] — the shared page pools; row
    NP-1 is the trash page (never read).
    ``k_new``/``v_new``   [B, S, kv, hd] post-rope projections.
    ``positions``         [B, S] global positions; -1 marks an inactive
    slot (an idle lane during decode, the padded tail of the last prefill
    chunk).
    ``page_table``        [B, P] physical page id per logical page, -1 =
    unmapped.

    Logical position p of lane b lives at physical page
    ``page_table[b, p // PS]``, slot ``p % PS`` (pages are allocated in
    order, so logical index == global position).  Writes from inactive
    slots or through unmapped table entries are routed to the trash page:
    the scatter shape never depends on how many lanes are live, which is
    what keeps one decode jit serving arbitrary request churn.
    """
    n_pool, ps = k_pool.shape[0], k_pool.shape[1]
    b, s = positions.shape
    valid = positions >= 0
    lpage = jnp.clip(positions // ps, 0, page_table.shape[1] - 1)
    slot = jnp.where(valid, positions % ps, 0)
    phys = jnp.take_along_axis(page_table, lpage, axis=1)
    phys = jnp.where(valid & (phys >= 0), phys, n_pool - 1)
    pf, sf = phys.reshape(-1), slot.reshape(-1)
    kf = k_new.reshape(b * s, *k_new.shape[2:]).astype(k_pool.dtype)
    vf = v_new.reshape(b * s, *v_new.shape[2:]).astype(v_pool.dtype)
    return k_pool.at[pf, sf].set(kf), v_pool.at[pf, sf].set(vf)


def paged_attention(q, k_pool, v_pool, page_table, positions, *,
                    kind="global", window=0, softcap=None) -> jnp.ndarray:
    """q [B, S, kv, g, hd] against the paged pools -> [B, S, kv, g, hd].

    Dispatch: the flash path tiles the lane's logical view with the SAME
    ``kv_tile`` anchoring as dense flash decode (bitwise-consistent with
    the fixed-loop shim) and masks unmapped/trash pages to exact zeros,
    preserving the lane-isolation invariant; the einsum path below is
    the benchmark control.  Both serve the L-lane decode step (S == 1)
    and chunked-prefill chunks (S > 1) with the same math.
    """
    if use_flash_attention():
        from repro.kernels import ops as kops
        return kops.paged_flash_decode(q, k_pool, v_pool, page_table,
                                       positions, kind=kind, window=window,
                                       softcap=softcap)
    return paged_attention_einsum(q, k_pool, v_pool, page_table, positions,
                                  kind=kind, window=window, softcap=softcap)


def paged_attention_einsum(q, k_pool, v_pool, page_table, positions, *,
                           kind="global", window=0, softcap=None
                           ) -> jnp.ndarray:
    """The pre-flash einsum paged path.

    Gathers each lane's mapped pages into a logical [B, P*PS, kv, hd]
    view (logical index == global position) and runs the decode mask /
    softmax generalized to S >= 1: a decode step is just a chunk of size
    one, so chunked prefill and decode round identically.  Unmapped
    pages gather the trash page but are masked out of both the max and
    the probability sum, so their (finite) garbage contributes exact
    zeros — a lane's output is bitwise independent of its neighbors.
    Window kinds mask by position (paged lanes keep full history; there
    is no ring buffer, so the summation order never depends on wrap).
    The gather moves pages at their storage dtype and the dots accumulate
    at fp32 via ``preferred_element_type`` — the old full-view
    ``astype(jnp.float32)`` upcasts were the PR 9 satellite bug.
    """
    n_pool, ps = k_pool.shape[0], k_pool.shape[1]
    b, p_max = page_table.shape
    hd = q.shape[-1]
    mapped = page_table >= 0
    ptc = jnp.where(mapped, page_table, n_pool - 1)
    kl = k_pool[ptc].reshape(b, p_max * ps, *k_pool.shape[2:])
    vl = v_pool[ptc].reshape(b, p_max * ps, *v_pool.shape[2:])
    s_mat = jnp.einsum("bqkgd,bKkd->bkgqK", q.astype(kl.dtype), kl,
                       preferred_element_type=jnp.float32)
    s_mat = s_mat * jnp.float32(hd) ** -0.5
    if softcap:
        s_mat = softcap * jnp.tanh(s_mat / softcap)

    kvpos = jnp.arange(p_max * ps)
    kvalid = jnp.repeat(mapped, ps, axis=1)                  # [B, L]
    qpos = positions                                         # [B, S]
    mask = (kvalid[:, None, :]
            & (kvpos[None, None, :] <= qpos[:, :, None])
            & (qpos[:, :, None] >= 0))
    if kind == "local":
        mask &= (qpos[:, :, None] - kvpos[None, None, :]) < window
    elif kind == "chunked":
        mask &= ((qpos[:, :, None] // window)
                 == (kvpos[None, None, :] // window))
    m4 = mask[:, None, None]                                 # [B,1,1,S,L]
    s_mat = jnp.where(m4, s_mat, _NEG)
    m = jnp.max(s_mat, axis=-1, keepdims=True)
    p = jnp.exp(s_mat - m)
    p = jnp.where(m4, p, 0.0)
    out = jnp.einsum("bkgqK,bKkd->bkgqd", p.astype(vl.dtype), vl,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(jnp.sum(p, axis=-1)[..., None], 1e-30)
    return jnp.einsum("bkgqd->bqkgd", out).astype(q.dtype)


# ---------------------------------------------------------------------------
# the full attention sub-block (projections + core + output)
# ---------------------------------------------------------------------------

def attention_apply(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                  # [B, S, D] replicated-over-model
    cfg: ArchConfig,
    ctx: TPCtx,
    *,
    kind: str,
    theta: float,
    positions: jnp.ndarray,          # [S] global positions
    prefix_len: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    pos: Optional[jnp.ndarray] = None,
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    use_rope: bool = True,
    x_seq_sharded: bool = False,
    return_kv: bool = False,
    page_table: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]], bool]:
    """Returns (attn_out, new_cache, out_is_seq_sharded).

    Modes: cache None -> train/prefill over the full sequence;
    cache present -> single-token decode (S == 1) at position ``pos``;
    cache present + ``page_table`` -> paged serving (cache is the
    ``{"kp", "vp"}`` page pools, ``positions`` is [B, S] per-token global
    positions with -1 marking inactive slots — covers both the
    multi-lane decode step (S == 1) and a chunked-prefill chunk (B == 1)
    with the same write-then-attend math).
    ``kv_override`` supplies external K/V activations (cross-attention).
    ``x_seq_sharded``: x is the SP-sharded residual; the QKV fused path
    performs the gather internally.
    ``return_kv`` (cache None only): return the projected post-rope K/V as
    ``{"k": .., "v": ..}`` in the cache slot so prefill can build the
    decode cache without re-projecting.
    """
    b, s, _ = x.shape
    n_kv, g, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd
    cd = ctx.compute_dtype

    if kv_override is not None:
        # cross-attention: wq applies to x, K/V come from the encoder —
        # always the unpacked schema (see attn_defs)
        q = jnp.einsum("bsd,dn->bsn", x, params["wq"].astype(cd))
        q = q.reshape(b, s, cfg.n_heads, hd)
        k, v = kv_override
        if use_rope:
            q = rope(q, positions, theta)
    else:
        if x_seq_sharded:
            q, k, v = fused_qkv_sp(params, x, cfg, ctx)
        else:
            q, k, v = project_qkv(params, x, cfg, ctx)
        if use_rope:
            q = rope(q, positions, theta)
            k = rope(k, positions, theta)

    new_cache = None
    if cache is None:
        if return_kv:
            assert kv_override is None
            new_cache = {"k": k, "v": v}
        from repro.kernels import ops as kops
        if (use_flash_attention() and kops.kernel_mode() != "xla"
                and ctx.model == 1):
            # fused prefill kernel: GQA-aware index maps consume the
            # grouped K/V views straight off the packed wqkv projection —
            # no jnp.repeat head expansion materialized
            out = kops.flash_attention(q, k, v, kind=kind,
                                       window=cfg.window,
                                       prefix_len=prefix_len,
                                       softcap=cfg.attn_softcap)
        else:
            # head-expand GQA K/V once, OUTSIDE the flash loops, so the
            # blocks are fully head-parallel (paper Z-sharding, zero
            # inner collectives)
            ke = jnp.repeat(k, g, axis=2) if g > 1 else k
            ve = jnp.repeat(v, g, axis=2) if g > 1 else v
            q, ke, ve = _constrain_qkv(q, ke, ve, cfg, ctx)
            out = flash_attention(q, ke, ve, kind=kind, window=cfg.window,
                                  prefix_len=prefix_len,
                                  softcap=cfg.attn_softcap,
                                  q_chunk=q_chunk, kv_chunk=kv_chunk)
    elif page_table is not None:
        # paged serving: scatter the new K/V through the page table, then
        # attend over the lane's gathered logical history.  The SAME path
        # serves the L-lane decode step and each prefill chunk, so the
        # two phases round identically by construction.
        qg = q.reshape(b, s, n_kv, g, hd)
        kc, vc = paged_update(cache["kp"], cache["vp"], k, v, positions,
                              page_table)
        new_cache = dict(cache, kp=kc, vp=vc)
        out = paged_attention(qg, kc, vc, page_table, positions, kind=kind,
                              window=cfg.window, softcap=cfg.attn_softcap)
    else:
        qg = q.reshape(b, s, n_kv, g, hd)
        if kv_override is None:
            ring = kind in ("local", "chunked")
            kc, vc = update_cache(cache["k"], cache["v"], k, v, pos,
                                  ring=ring)
            new_cache = dict(cache, k=kc, v=vc)
            out = decode_attention(qg, kc, vc, pos, kind=kind,
                                   window=cfg.window,
                                   softcap=cfg.attn_softcap)
        else:  # cross-attention: static external KV
            new_cache = cache
            out = decode_attention(qg, k, v, jnp.asarray(k.shape[1] - 1),
                                   kind="full", softcap=cfg.attn_softcap)

    out = out.reshape(b, s, cfg.q_dim).astype(cd)
    if use_xyz_attn_out(cfg, ctx.model):
        from repro.core.maxeva_matmul import XYZConfig, \
            xyz_matmul_replicated_out
        from repro.models.layers import _sp_active, xyz_matmul_seq_scatter
        if cache is None and _sp_active(out, ctx):
            # adder tree + sequence scatter fused (RS instead of AR); the
            # attention core's head sharding IS the natural ksharded layout
            o = xyz_matmul_seq_scatter(out, params["wo"], ctx=ctx,
                                       x_layout="ksharded")
            return o, new_cache, True  # seq-sharded output
        o = xyz_matmul_replicated_out(
            out, params["wo"], mesh=ctx.mesh,
            cfg=XYZConfig(y=ctx.model, x_layout="replicated" if cache
                          is not None else "ksharded",
                          out_dtype=cd))
        return o, new_cache, False
    if ctx.model == 1:
        # fused out-projection: planned blocked GEMM, cast in-kernel
        from repro.kernels import ops as kops
        o = kops.matmul(out.reshape(b * s, -1), params["wo"],
                        out_dtype=cd).reshape(b, s, -1)
        return o, new_cache, False
    o = jnp.einsum("bsn,nd->bsd", out, params["wo"].astype(cd))
    return o, new_cache, False
