"""Declarative parameter schema.

Each model declares a tree of ``ParamDef``s; from one declaration we derive
  * ``abstract(tree)``  -> ShapeDtypeStruct tree (dry-run: zero allocation)
  * ``specs(tree)``     -> PartitionSpec tree (in_shardings / checkpoints)
  * ``initialize(tree)``-> materialized arrays (deterministic per path)

Packed parameters: a ``ParamDef`` may declare named ``views`` splitting its
LAST axis (e.g. wqkv = [wq | wk | wv]) so several logical weights live in
one physical array and apply-time code issues ONE GEMM with zero copies.
``packing`` is the shard-interleave factor of the packed axis: with
``packing == g`` the columns are laid out shard-major — column block i of
the packed array holds [wq_i | wk_i | wv_i] (each view's i-th of g column
shards) — so a ``P(..., 'model')``-sharded packed array gives every model
shard contiguous per-view columns with no resharding.  ``split_views`` /
``pack_views`` convert between the packed layout and the logical per-view
arrays (checkpoints, reference math); they are exact mutual inverses.
Initialization draws each view with the seed stream of ``<path>/<view>``,
bitwise identical to declaring the views as separate ParamDefs.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    spec: P = P()
    init: str = "normal"       # normal | zeros | ones | lru_log | custom
    scale: Optional[float] = None  # default: 1/sqrt(fan_in)
    dtype: str = "float32"
    custom: Optional[Callable[[jax.Array], jax.Array]] = None
    # packed param: named views splitting the last axis, e.g.
    # (("wq", q_dim), ("wk", kv_dim), ("wv", kv_dim)); sizes must sum to
    # shape[-1] and each must divide by ``packing`` (see module docstring)
    views: Optional[Tuple[Tuple[str, int], ...]] = None
    packing: int = 1

    def __post_init__(self):
        if self.views is not None:
            sizes = [s for _, s in self.views]
            assert sum(sizes) == self.shape[-1], (self.shape, self.views)
            assert all(s % self.packing == 0 for s in sizes), (
                self.views, self.packing)


def view_defs(d: ParamDef) -> Dict[str, ParamDef]:
    """Logical per-view ParamDefs of a packed def (same spec/init/dtype)."""
    assert d.views is not None
    return {name: ParamDef(d.shape[:-1] + (size,), d.spec, d.init, d.scale,
                           d.dtype, d.custom)
            for name, size in d.views}


def split_packed_columns(arr, sizes: Tuple[int, ...],
                         packing: int = 1) -> Tuple[Any, ...]:
    """Split the last axis of ``arr`` into per-view arrays.  Works on the
    packed weight AND on the output of a GEMM against it (activations
    inherit the packed column layout).  Plain basic indexing: traced jax
    arrays and host numpy arrays both stay what they are (checkpoint
    migration splits on host, no device round trip)."""
    lead = arr.shape[:-1]
    if packing == 1:
        off, out = 0, []
        for s in sizes:
            out.append(arr[..., off:off + s])
            off += s
        return tuple(out)
    a = arr.reshape(*lead, packing, sum(sizes) // packing)
    off, out = 0, []
    for s in sizes:
        sl = a[..., off:off + s // packing]
        out.append(sl.reshape(*lead, s))
        off += s // packing
    return tuple(out)


def split_views(d: ParamDef, arr: jax.Array) -> Dict[str, jax.Array]:
    """Packed array -> {view name: logical array}."""
    assert d.views is not None
    parts = split_packed_columns(arr, tuple(s for _, s in d.views),
                                 d.packing)
    return {name: p for (name, _), p in zip(d.views, parts)}


def pack_views(d: ParamDef, views: Dict[str, jax.Array]) -> jax.Array:
    """{view name: logical array} -> packed array (inverse of split_views).
    All-numpy inputs pack on host (checkpoint migration never bounces the
    unsharded array through a device)."""
    assert d.views is not None
    g = d.packing
    xp = np if all(isinstance(views[n], np.ndarray)
                   for n, _ in d.views) else jnp
    parts, lead = [], None
    for name, size in d.views:
        v = views[name]
        lead = v.shape[:-1]
        parts.append(v.reshape(*lead, g, size // g))
    packed = xp.concatenate(parts, axis=-1)
    return packed.reshape(*lead, packed.shape[-2] * packed.shape[-1])


def _path_seed(path: str, base: int) -> int:
    h = hashlib.md5(f"{base}:{path}".encode()).digest()
    return int.from_bytes(h[:4], "little")


def _init_one(d: ParamDef, path: str, base_seed: int) -> jax.Array:
    if d.views is not None:
        # per-view streams at <parent>/<view> (the packed def's own name is
        # replaced by the view name): bitwise identical to declaring the
        # views as separate ParamDefs, so legacy checkpoints line up
        parent = path.rsplit("/", 1)[0]
        vs = {name: _init_one(vd, f"{parent}/{name}", base_seed)
              for name, vd in view_defs(d).items()}
        return pack_views(d, vs)
    key = jax.random.PRNGKey(_path_seed(path, base_seed))
    dt = jnp.dtype(d.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dt)
    if d.init == "ones":
        return jnp.ones(d.shape, dt)
    if d.init == "lru_log":
        # RG-LRU Lambda init: uniform such that a = exp(-c*softplus(L)) has
        # moduli in [0.9, 0.999]
        u = jax.random.uniform(key, d.shape, jnp.float32,
                               minval=0.9 ** 2, maxval=0.999 ** 2)
        lam = jnp.log(jnp.expm1(-0.5 * jnp.log(u) / 8.0))
        return lam.astype(dt)
    if d.init == "custom":
        # broadcast handles group-stacked defs (leading group axis added
        # after the custom fn was declared)
        return jnp.broadcast_to(d.custom(key), d.shape).astype(dt)
    # normal with fan-in scaling: fan_in = second-to-last dim by convention
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    scale = d.scale if d.scale is not None else 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dt)


def _walk(tree: Any, fn: Callable[[ParamDef, str], Any], path: str = "") -> Any:
    if isinstance(tree, ParamDef):
        return fn(tree, path)
    if isinstance(tree, dict):
        return {k: _walk(v, fn, f"{path}/{k}") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_walk(v, fn, f"{path}/{i}")
                          for i, v in enumerate(tree))
    raise TypeError(type(tree))


def abstract(tree: Any) -> Any:
    return _walk(tree, lambda d, p: jax.ShapeDtypeStruct(d.shape,
                                                         jnp.dtype(d.dtype)))


def specs(tree: Any) -> Any:
    return _walk(tree, lambda d, p: d.spec)


def initialize(tree: Any, seed: int = 0,
               mesh: Optional[Mesh] = None) -> Any:
    def mk(d: ParamDef, path: str):
        arr = _init_one(d, path, seed)
        if mesh is not None and mesh.devices.size > 1:
            arr = jax.device_put(arr, NamedSharding(mesh, d.spec))
        return arr
    return _walk(tree, mk)


class _PassThrough:
    """Leaf marker in a legacy-``like`` tree for defs entries that are not
    ParamDefs (e.g. the optimizer step counter): restored as-is, shape
    unchecked."""


PASS_THROUGH = _PassThrough()


def unpack_defs(tree: Any) -> Any:
    """The legacy (unpacked) schema of the same model: every packed
    ParamDef is replaced by its per-view defs spliced into the PARENT dict
    as siblings (e.g. attn {"wqkv", "wo"} -> {"wq", "wk", "wv", "wo"}).
    Sibling splicing — not nesting under the packed name — is what makes
    the dict flatten order match checkpoints written before packing
    existed (jax flattens dicts in sorted-key order).  Non-ParamDef
    leaves pass through unchanged (mixed defs trees: optimizer state)."""
    if isinstance(tree, ParamDef):
        return view_defs(tree) if tree.views is not None else tree
    if isinstance(tree, dict):
        out: Dict[str, Any] = {}
        for k, v in tree.items():
            if isinstance(v, ParamDef) and v.views is not None:
                vd = view_defs(v)
                clash = (set(vd) & set(tree)) | (set(vd) & set(out))
                assert not clash, (k, clash)
                out.update(vd)
            else:
                out[k] = unpack_defs(v)
        return out
    if isinstance(tree, (list, tuple)):
        return type(tree)(unpack_defs(v) for v in tree)
    return tree


def unpack_like(defs: Any) -> Any:
    """Legacy-schema ``like`` tree of a (possibly mixed) defs tree:
    ParamDefs become ShapeDtypeStructs (packed ones splice their view
    structs into the parent as siblings), any other leaf becomes a
    PASS_THROUGH marker whose shape is not checked at restore."""
    def to_like(t: Any) -> Any:
        if isinstance(t, ParamDef):
            return jax.ShapeDtypeStruct(t.shape, jnp.dtype(t.dtype))
        if isinstance(t, dict):
            return {k: to_like(v) for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            return type(t)(to_like(v) for v in t)
        return PASS_THROUGH
    return to_like(unpack_defs(defs))


def split_tree(defs: Any, values: Any) -> Any:
    """Packed value tree -> legacy value tree (checkpoint export), with
    views spliced into the parent dict exactly as ``unpack_defs`` lays
    the schema out."""
    if isinstance(defs, ParamDef):
        return (split_views(defs, values) if defs.views is not None
                else values)
    if isinstance(defs, dict):
        out: Dict[str, Any] = {}
        for k, v in defs.items():
            if isinstance(v, ParamDef) and v.views is not None:
                out.update(split_views(v, values[k]))
            else:
                out[k] = split_tree(v, values[k])
        return out
    if isinstance(defs, (list, tuple)):
        return type(defs)(split_tree(v, values[i])
                          for i, v in enumerate(defs))
    return values  # non-ParamDef leaf: pass through


def pack_tree(defs: Any, legacy_values: Any) -> Any:
    """Legacy value tree (per-view leaves as siblings, the pre-packing
    layout) -> packed value tree (checkpoint migration).  Non-ParamDef
    defs leaves pass their value through unchanged."""
    if isinstance(defs, ParamDef):
        return (pack_views(defs, legacy_values)
                if defs.views is not None else legacy_values)
    if isinstance(defs, dict):
        out: Dict[str, Any] = {}
        for k, v in defs.items():
            if isinstance(v, ParamDef) and v.views is not None:
                out[k] = pack_views(
                    v, {n: legacy_values[n] for n, _ in v.views})
            else:
                out[k] = pack_tree(v, legacy_values[k])
        return out
    if isinstance(defs, (list, tuple)):
        return type(defs)(pack_tree(v, legacy_values[i])
                          for i, v in enumerate(defs))
    return legacy_values  # non-ParamDef leaf: pass through


def n_params(tree: Any) -> int:
    total = [0]

    def count(d: ParamDef, path: str):
        n = 1
        for s in d.shape:
            n *= s
        total[0] += n
        return None

    _walk(tree, count)
    return total[0]
