"""Declarative parameter schema.

Each model declares a tree of ``ParamDef``s; from one declaration we derive
  * ``abstract(tree)``  -> ShapeDtypeStruct tree (dry-run: zero allocation)
  * ``specs(tree)``     -> PartitionSpec tree (in_shardings / checkpoints)
  * ``initialize(tree)``-> materialized arrays (deterministic per path)
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    spec: P = P()
    init: str = "normal"       # normal | zeros | ones | lru_log | custom
    scale: Optional[float] = None  # default: 1/sqrt(fan_in)
    dtype: str = "float32"
    custom: Optional[Callable[[jax.Array], jax.Array]] = None


def _path_seed(path: str, base: int) -> int:
    h = hashlib.md5(f"{base}:{path}".encode()).digest()
    return int.from_bytes(h[:4], "little")


def _init_one(d: ParamDef, path: str, base_seed: int) -> jax.Array:
    key = jax.random.PRNGKey(_path_seed(path, base_seed))
    dt = jnp.dtype(d.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dt)
    if d.init == "ones":
        return jnp.ones(d.shape, dt)
    if d.init == "lru_log":
        # RG-LRU Lambda init: uniform such that a = exp(-c*softplus(L)) has
        # moduli in [0.9, 0.999]
        u = jax.random.uniform(key, d.shape, jnp.float32,
                               minval=0.9 ** 2, maxval=0.999 ** 2)
        lam = jnp.log(jnp.expm1(-0.5 * jnp.log(u) / 8.0))
        return lam.astype(dt)
    if d.init == "custom":
        # broadcast handles group-stacked defs (leading group axis added
        # after the custom fn was declared)
        return jnp.broadcast_to(d.custom(key), d.shape).astype(dt)
    # normal with fan-in scaling: fan_in = second-to-last dim by convention
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    scale = d.scale if d.scale is not None else 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dt)


def _walk(tree: Any, fn: Callable[[ParamDef, str], Any], path: str = "") -> Any:
    if isinstance(tree, ParamDef):
        return fn(tree, path)
    if isinstance(tree, dict):
        return {k: _walk(v, fn, f"{path}/{k}") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_walk(v, fn, f"{path}/{i}")
                          for i, v in enumerate(tree))
    raise TypeError(type(tree))


def abstract(tree: Any) -> Any:
    return _walk(tree, lambda d, p: jax.ShapeDtypeStruct(d.shape,
                                                         jnp.dtype(d.dtype)))


def specs(tree: Any) -> Any:
    return _walk(tree, lambda d, p: d.spec)


def initialize(tree: Any, seed: int = 0,
               mesh: Optional[Mesh] = None) -> Any:
    def mk(d: ParamDef, path: str):
        arr = _init_one(d, path, seed)
        if mesh is not None and mesh.devices.size > 1:
            arr = jax.device_put(arr, NamedSharding(mesh, d.spec))
        return arr
    return _walk(tree, mk)


def n_params(tree: Any) -> int:
    total = [0]

    def count(d: ParamDef, path: str):
        n = 1
        for s in d.shape:
            n *= s
        total[0] += n
        return None

    _walk(tree, count)
    return total[0]
