"""Shared layers: norms, RoPE, vocab-parallel embedding, MaxEVA-planned MLP.

All heavy GEMMs route through the MaxEVA XYZ matmul (core.maxeva_matmul):
column-parallel up/gate projections (Z = model, the input broadcast),
row-parallel down projections (Y = model, the adder-tree reduction), with
the reduction schedule chosen per the placement-pattern economics.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.maxeva_matmul import (
    XYZConfig,
    _shard_map,
    xyz_matmul,
    xyz_matmul_replicated_out,
    xyz_weight_shape,
)
from repro.core.sharding import dp_axes, model_size
from repro.kernels.epilogue import Epilogue
from repro.models.param import ParamDef


@dataclasses.dataclass(frozen=True)
class TPCtx:
    """Tensor/sequence-parallel context threaded through every layer."""

    mesh: Mesh
    sp: bool                       # residual stream seq-sharded over model
    compute_dtype: Any = jnp.bfloat16
    down_schedule: str = "reduce_scatter"   # P2 analogue by default
    up_y: int = 1                  # Y for up/gate projections (Z = model/Y)
    down_y: Optional[int] = None   # Y for down projections (default: model)

    @property
    def model(self) -> int:
        return model_size(self.mesh)

    @property
    def dp(self):
        return dp_axes(self.mesh)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    # named_scope marks this as a STANDALONE norm in the traced HLO's
    # op_name metadata; the fusion audit counts these against the
    # rmsnorm-fused GEMM outputs (which carry the fused_epilogue scope)
    with jax.named_scope("rmsnorm"):
        xf = x.astype(jnp.float32)
        # sum / n, NOT jnp.mean: must be the exact expression the fused
        # epilogue's norm stage emits (kernels.epilogue.apply_epilogue),
        # so a folded (value, normed) GEMM output is bitwise identical
        # to storing value and re-reading it through this function
        var = jnp.sum(xf * xf, axis=-1, keepdims=True) / xf.shape[-1]
        out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
        return out.astype(x.dtype)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., S, n, hd] (n = heads or groups), positions [S] or [B, S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # [..., S, half]
    # broadcast over the head dim: [..., S, 1, half]
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# sequence-parallel gather / scatter (Megatron-SP; the broadcast + adder
# tree applied to the residual stream)
# ---------------------------------------------------------------------------

def _row_spec(x: jnp.ndarray, ctx: TPCtx):
    from repro.core.sharding import row_axes
    return row_axes(ctx.mesh, x.shape[0])


def _sp_active(x: jnp.ndarray, ctx: TPCtx) -> bool:
    """SP applies only when the (global) sequence dim is shardable: decode
    steps (S=1) and whisper's 1500-frame encoder fall through to no-ops."""
    return (ctx.sp and ctx.model > 1 and x.shape[1] % ctx.model == 0
            and x.shape[1] >= ctx.model)


def gather_seq(x: jnp.ndarray, ctx: TPCtx) -> jnp.ndarray:
    """[B, S, D] seq-sharded over model -> replicated (all-gather)."""
    if not _sp_active(x, ctx):
        return x

    rs = _row_spec(x, ctx)

    def body(xl):
        return jax.lax.all_gather(xl, "model", axis=1, tiled=True)

    return _shard_map(body, ctx.mesh, (P(rs, "model", None),),
                      P(rs, None, None))(x)


def scatter_seq(x: jnp.ndarray, ctx: TPCtx) -> jnp.ndarray:
    """[B, S, D] (replicated over model) -> seq-sharded (keep own shard)."""
    if not _sp_active(x, ctx):
        return x

    rs = _row_spec(x, ctx)

    def body(xl):
        md = jax.lax.axis_index("model")
        shard = xl.shape[1] // ctx.model
        return jax.lax.dynamic_slice_in_dim(xl, md * shard, shard, axis=1)

    return _shard_map(body, ctx.mesh, (P(rs, None, None),),
                      P(rs, "model", None))(x)


def xyz_matmul_seq_scatter(x: jnp.ndarray, w_xyz: jnp.ndarray, *,
                           ctx: TPCtx, x_layout: str = "ksharded",
                           residual: Optional[jnp.ndarray] = None,
                           norm_scale: Optional[jnp.ndarray] = None,
                           norm_eps: float = 1e-6):
    """Row-parallel (Y = model) GEMM whose reduction scatters over the
    SEQUENCE dim: out [B, S, N] -> [B, S/model, N].  The Megatron-SP
    down-projection; adder tree + scatter in one collective.

    With ``residual`` (the seq-sharded stream [B, S/model, N]) and
    ``norm_scale`` the fused epilogue runs after the psum_scatter on the
    seq shard each device owns — every residual row is full-N, so the
    rmsnorm fold is always legal here — and the return is
    ``(h_new, rmsnorm(h_new, norm_scale))``, both seq-sharded."""
    from repro.kernels.epilogue import apply_epilogue
    mesh, model = ctx.mesh, ctx.model
    cd = ctx.compute_dtype
    fold = norm_scale is not None
    ep = Epilogue(residual=True, norm="rmsnorm", norm_eps=norm_eps,
                  out_dtype=cd) if fold else None
    if model == 1:
        return xyz_matmul(x, w_xyz, mesh=mesh,
                          cfg=XYZConfig(y=1, epilogue=ep, out_dtype=cd),
                          residual=residual, norm_scale=norm_scale)
    rs = _row_spec(x, ctx)
    x_spec = P(rs, None, "model" if x_layout == "ksharded" else None)

    def body(xl, wl, *rest):
        wl = wl[0]
        md = jax.lax.axis_index("model")
        b, s, _ = xl.shape
        x2 = xl.reshape(b * s, -1)
        if x_layout == "replicated":
            from repro.core.maxeva_matmul import _slice_k_block
            x2 = _slice_k_block(x2, md, model, model)
        from repro.kernels import ops as kops
        # 16-bit wire + AD buffers; the cast is fused into the kernel's
        # store phase (no fp32 round trip through HBM)
        partial = kops.matmul(x2, wl, out_dtype=cd)
        partial = partial.reshape(b, s, -1)
        out = jax.lax.psum_scatter(partial, "model", scatter_dimension=1,
                                   tiled=True)
        if not fold:
            return out
        res_l, ns_l = rest
        b2, s2, n2 = out.shape
        val, xn = apply_epilogue(out.reshape(b2 * s2, n2), ep,
                                 residual=res_l.reshape(b2 * s2, n2),
                                 norm_scale=ns_l)
        return val.reshape(b2, s2, n2), xn.reshape(b2, s2, n2)

    in_specs = [x_spec, P("model", None, None)]
    args = [x, w_xyz]
    out_spec = P(rs, "model", None)
    if fold:
        in_specs += [P(rs, "model", None), P(None)]
        args += [residual, norm_scale]
        out_spec = (out_spec, out_spec)
    return _shard_map(body, mesh, tuple(in_specs), out_spec)(*args)


def mlp_apply_fused_sp(params: Dict[str, jnp.ndarray], h_sharded: jnp.ndarray,
                       ctx: TPCtx, gated: bool,
                       residual: Optional[jnp.ndarray] = None,
                       norm_scale: Optional[jnp.ndarray] = None,
                       norm_eps: float = 1e-6):
    """Whole Megatron-SP MLP in ONE shard_map: AG(x) -> up/gate (broadcast
    consumers) -> down partial -> psum_scatter over seq.

    Collective economics vs the unfused path: the x broadcast's backward is
    the AG's transpose (a reduce-scatter) instead of one all-reduce per
    consumer — measured -25% wire on gemma3 train (EXPERIMENTS §Perf).
    Requires up_y == 1 and down_y == model (the planner's choice for every
    assigned arch's MLP).

    The gated MLP runs the ``silu(g) * u`` multiply as the up GEMM's
    two-operand gate epilogue (the gate GEMM emits RAW g; the activation
    happens once, in fp32, on the up accumulator).  With ``residual``
    (seq-sharded stream) + ``norm_scale`` the residual add AND the next
    block's rmsnorm fold into one elementwise chain after the
    psum_scatter; returns ``(h_new, rmsnorm(h_new))``, both seq-sharded.
    """
    from repro.kernels.epilogue import apply_epilogue
    mesh, model = ctx.mesh, ctx.model
    rs = _row_spec(h_sharded, ctx)
    cd = ctx.compute_dtype
    fold = norm_scale is not None
    fold_ep = Epilogue(residual=True, norm="rmsnorm", norm_eps=norm_eps,
                       out_dtype=cd) if fold else None

    def body(xl, wu, wg, wd, *rest):
        x2 = jax.lax.all_gather(xl, "model", axis=1, tiled=True)
        b, s, _ = x2.shape
        xf = x2.reshape(b * s, -1)
        from repro.kernels import ops as kops
        # up/gate GEMMs carry their activation + cast in the fused
        # epilogue: the fp32 accumulator never round-trips through HBM
        if wg is not None:
            g = kops.matmul(xf, wg[0], epilogue=Epilogue(out_dtype=cd))
            hcol = kops.matmul(xf, wu[0],
                               epilogue=Epilogue(gate="silu", out_dtype=cd),
                               operand2=g)
        else:
            hcol = kops.matmul(xf, wu[0], epilogue=Epilogue(
                activation="gelu", out_dtype=cd))
        part = kops.matmul(hcol, wd[0], out_dtype=cd)
        part = part.reshape(b, s, -1)
        out = jax.lax.psum_scatter(part, "model", scatter_dimension=1,
                                   tiled=True)
        if not fold:
            return out
        res_l, ns_l = rest
        b2, s2, n2 = out.shape
        val, xn = apply_epilogue(out.reshape(b2 * s2, n2), fold_ep,
                                 residual=res_l.reshape(b2 * s2, n2),
                                 norm_scale=ns_l)
        return val.reshape(b2, s2, n2), xn.reshape(b2, s2, n2)

    wspec = P("model", None, None)
    sspec = P(rs, "model", None)
    in_specs = [sspec, wspec, wspec, wspec] if gated \
        else [sspec, wspec, wspec]
    args = [h_sharded, params["up"], params["gate"], params["down"]] \
        if gated else [h_sharded, params["up"], params["down"]]
    out_spec = (sspec, sspec) if fold else sspec
    if fold:
        in_specs += [sspec, P(None)]
        args += [residual, norm_scale]
    fn = body if gated else (
        lambda xl, wu, wd, *rest: body(xl, wu, None, wd, *rest))
    return _shard_map(fn, mesh, tuple(in_specs), out_spec)(*args)


# ---------------------------------------------------------------------------
# vocab-parallel embedding
# ---------------------------------------------------------------------------

def embed_def(vocab_padded: int, d_model: int, dtype: str,
              fsdp: bool = False) -> ParamDef:
    # std 1/sqrt(d): with the sqrt(d) embedding multiplier the stream enters
    # at unit scale, and the tied head produces ~unit-scale logits.
    spec = P("model", "data") if fsdp else P("model", None)
    return ParamDef((vocab_padded, d_model), spec, "normal",
                    scale=1.0 / math.sqrt(d_model), dtype=dtype)


def vocab_parallel_embed(table: jnp.ndarray, ids: jnp.ndarray,
                         ctx: TPCtx) -> jnp.ndarray:
    """ids [B, S] -> [B, S, D].  Table is row(vocab)-sharded over model;
    each shard gathers its range and the psum (adder tree) combines."""
    mesh, model = ctx.mesh, ctx.model
    if model == 1:
        return table[ids].astype(ctx.compute_dtype)

    def body(tbl, ids_l):
        md = jax.lax.axis_index("model")
        vloc = tbl.shape[0]
        loc = ids_l - md * vloc
        ok = (loc >= 0) & (loc < vloc)
        loc = jnp.clip(loc, 0, vloc - 1)
        out = tbl[loc] * ok[..., None].astype(tbl.dtype)
        return jax.lax.psum(out.astype(ctx.compute_dtype), "model")

    rs = _row_spec(ids, ctx)
    return _shard_map(body, mesh, (P("model", None), P(rs, None)),
                      P(rs, None, None))(table, ids)


# ---------------------------------------------------------------------------
# MaxEVA-planned MLP
# ---------------------------------------------------------------------------

def mlp_defs(d_model: int, d_ff: int, model: int, gated: bool, dtype: str,
             fsdp: bool, up_y: int = 1,
             down_y: Optional[int] = None) -> Dict[str, ParamDef]:
    down_y = down_y or model
    up_shape = xyz_weight_shape(d_model, d_ff, model, up_y)
    down_shape = xyz_weight_shape(d_ff, d_model, model, down_y)
    spec = P("model", "data", None) if fsdp else P("model", None, None)
    defs = {
        "up": ParamDef(up_shape, spec, dtype=dtype),
        "down": ParamDef(down_shape, spec, dtype=dtype),
    }
    if gated:
        defs["gate"] = ParamDef(up_shape, spec, dtype=dtype)
    return defs


def _mlp_apply_int8(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
                    ctx: TPCtx, gated: bool,
                    residual: Optional[jnp.ndarray] = None,
                    norm_scale: Optional[jnp.ndarray] = None,
                    norm_eps: float = 1e-6):
    """Single-shard int8 MLP (the serving path, weights quantized
    column-wise by ``Model.quantize_params_for_serving``).

    ONE rowwise quantize of the normed stream feeds both the up and gate
    int8 GEMMs (the broadcast input is quantized once, never per
    consumer).  BOTH MLP shapes hand the down GEMM a fused ``(q, scale)``
    pair straight out of the up GEMM's store phase: plain-GELU via the
    ``activation='gelu'`` quantize epilogue, gated via the two-operand
    ``gate='silu'`` epilogue (``silu(g) * u`` on the fp32 accumulator —
    the gate GEMM emits RAW g, the multiply and requantize never leave
    the fused elementwise chain).  The int32 -> fp32 boundary lives
    entirely inside the kernels' store phases: zero standalone rowwise
    quantizes after the input one, zero fp dequant -> requant bounces
    (both contract-audited in the traced decode/prefill HLO).

    With ``residual`` + ``norm_scale`` the down GEMM additionally folds
    the residual add and the NEXT block's rmsnorm, returning
    ``(h_new, rmsnorm(h_new, norm_scale))``.
    """
    assert ctx.model == 1, "int8 serving path is single-shard"
    from repro.kernels import ops as kops
    cd = ctx.compute_dtype
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    qx, sx = kops.quantize_rowwise(x2)
    if gated:
        g = kops.int8_matmul(qx, sx, *params["gate"].as_matrix(),
                             out_dtype=cd)
        qh, sh = kops.int8_matmul(qx, sx, *params["up"].as_matrix(),
                                  epilogue=Epilogue(gate="silu",
                                                    quantize=True),
                                  operand2=g)
    else:
        qh, sh = kops.int8_matmul(qx, sx, *params["up"].as_matrix(),
                                  epilogue=Epilogue(activation="gelu",
                                                    quantize=True))
    if norm_scale is not None:
        ep = Epilogue(residual=True, norm="rmsnorm", norm_eps=norm_eps,
                      out_dtype=cd)
        val, xn = kops.int8_matmul(
            qh, sh, *params["down"].as_matrix(), epilogue=ep,
            residual=residual.reshape(-1, residual.shape[-1]),
            norm_scale=norm_scale)
        return (val.reshape(*lead, -1), xn.reshape(*lead, -1))
    out = kops.int8_matmul(qh, sh, *params["down"].as_matrix(),
                           out_dtype=cd)
    return out.reshape(*lead, -1)


def mlp_apply(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
              ctx: TPCtx, gated: bool,
              residual: Optional[jnp.ndarray] = None,
              norm_scale: Optional[jnp.ndarray] = None,
              norm_eps: float = 1e-6):
    """x: replicated-over-model activations [B, S, D] (already gathered if
    SP).  Returns activations matching the residual-stream sharding:
    seq-sharded under active SP, replicated otherwise.

    With ``residual`` (the residual stream, in stream sharding) and
    ``norm_scale`` (the NEXT norm's scale param) the down projection
    folds the residual add and the next rmsnorm into its epilogue and
    returns ``(h_new, rmsnorm(h_new, norm_scale))`` — eliminating one
    full residual-stream read + write per block.  The fold runs fused on
    every full-N down path (seq-scatter SP, replicated-out, model == 1,
    int8 serving); the general Y < model path (N-sharded output) cannot
    hold a full row and composes the same math standalone."""
    from repro.kernels.quantize import QuantizedWeight
    fold = norm_scale is not None
    if isinstance(params["up"], QuantizedWeight):
        return _mlp_apply_int8(params, x, ctx, gated, residual=residual,
                               norm_scale=norm_scale, norm_eps=norm_eps)
    model = ctx.model
    cd = ctx.compute_dtype
    up_cfg = XYZConfig(y=ctx.up_y, schedule=ctx.down_schedule, out_dtype=cd)
    if gated:
        # two-operand gate epilogue: the gate GEMM emits RAW g and the up
        # GEMM's store phase computes silu(g) * u on the fp32 accumulator
        # (with up_y == 1 on the VMEM tile inside the kernel; with
        # up_y > 1 post-reduction inside the shard_map — elementwise, so
        # bitwise schedule-invariant)
        g = xyz_matmul(x, params["gate"], mesh=ctx.mesh, cfg=up_cfg)
        gate_cfg = dataclasses.replace(up_cfg, epilogue=Epilogue(
            gate="silu", out_dtype=cd))
        h = xyz_matmul(x, params["up"], mesh=ctx.mesh, cfg=gate_cfg,
                       operand2=g)
    else:
        up_fused = dataclasses.replace(up_cfg, epilogue=Epilogue(
            activation="gelu", out_dtype=cd))
        h = xyz_matmul(x, params["up"], mesh=ctx.mesh, cfg=up_fused)

    down_y = ctx.down_y or model
    if _sp_active(x, ctx) and down_y == model:
        # adder tree + sequence scatter fused in one psum_scatter
        return xyz_matmul_seq_scatter(
            h, params["down"], ctx=ctx, x_layout="ksharded",
            residual=residual if fold else None,
            norm_scale=norm_scale, norm_eps=norm_eps)
    fold_ep = Epilogue(residual=True, norm="rmsnorm", norm_eps=norm_eps,
                       out_dtype=cd) if fold else None
    cfg = XYZConfig(y=down_y, schedule=ctx.down_schedule,
                    x_layout="ksharded", out_dtype=cd)
    if down_y == model:
        if fold:
            return xyz_matmul_replicated_out(
                h, params["down"], mesh=ctx.mesh,
                cfg=dataclasses.replace(cfg, epilogue=fold_ep),
                residual=residual, norm_scale=norm_scale)
        out = xyz_matmul_replicated_out(h, params["down"], mesh=ctx.mesh,
                                        cfg=cfg)
    else:
        # general Y < model: output lands N-sharded; gather to replicated
        out = xyz_matmul(h, params["down"], mesh=ctx.mesh, cfg=cfg)
        out = gather_last_dim(out, ctx)
        if fold:
            # no full-N shard exists pre-gather: compose the identical
            # math standalone (same fp32 add, same rmsnorm)
            out = scatter_seq(out, ctx)
            hf = residual.astype(jnp.float32) + out.astype(jnp.float32)
            h_new = hf.astype(cd)
            return h_new, rmsnorm(h_new, norm_scale, norm_eps)
    return scatter_seq(out, ctx)


def gather_last_dim(x: jnp.ndarray, ctx: TPCtx) -> jnp.ndarray:
    """[.., N/model sharded] -> replicated [.., N]."""
    if ctx.model == 1:
        return x
    mid = [None] * (x.ndim - 2)
    rs = _row_spec(x, ctx)

    def body(xl):
        return jax.lax.all_gather(xl, "model", axis=xl.ndim - 1, tiled=True)

    return _shard_map(body, ctx.mesh, (P(rs, *mid, "model"),),
                      P(rs, *mid, None))(x)
