"""Vocab-parallel chunked cross-entropy.

The vocab head is the largest single GEMM in most assigned archs (e.g.
gemma3: 3840 x 262144).  Logits are never materialized for the full
sequence: a remat'd scan walks sequence chunks; within a chunk, logits are
computed against the LOCAL vocab shard and the log-sum-exp / target-logit
terms are combined with psum over the model axis — the paper's adder-tree
reduction applied to the softmax.  Targets < 0 are ignored (prefix/padding
positions).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.maxeva_matmul import _shard_map
from repro.models.layers import TPCtx


def vocab_parallel_xent(
    h: jnp.ndarray,            # [B, S, D] replicated over model
    head: jnp.ndarray,         # [Vp, D] vocab(row)-sharded over model
    targets: jnp.ndarray,      # [B, S] int32; < 0 -> ignored
    ctx: TPCtx,
    *,
    chunk: int = 512,
    final_softcap: Optional[float] = None,
) -> jnp.ndarray:
    """Mean NLL over non-ignored tokens (scalar, replicated)."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nchunks = s // chunk

    def per_chunk(hl, headl, tgt, md):
        vloc = headl.shape[0]
        logits = jnp.einsum("bcd,vd->bcv", hl.astype(jnp.float32),
                            headl.astype(jnp.float32))
        if final_softcap:
            logits = final_softcap * jnp.tanh(logits / final_softcap)
        mx = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
        if ctx.model > 1:
            mx = jax.lax.pmax(mx, "model")
        se = jnp.sum(jnp.exp(logits - mx[..., None]), axis=-1)
        if ctx.model > 1:
            se = jax.lax.psum(se, "model")
        lse = mx + jnp.log(se)

        loc = tgt - md * vloc
        ok = (loc >= 0) & (loc < vloc)
        locc = jnp.clip(loc, 0, vloc - 1)
        tl = jnp.take_along_axis(logits, locc[..., None], axis=-1)[..., 0]
        tl = tl * ok.astype(jnp.float32)
        if ctx.model > 1:
            tl = jax.lax.psum(tl, "model")

        w = (tgt >= 0).astype(jnp.float32)
        return jnp.sum((lse - tl) * w), jnp.sum(w)

    def body(hl, headl, tgt):
        md = jax.lax.axis_index("model") if ctx.model > 1 else 0

        def step(acc, i):
            hs = jax.lax.dynamic_slice_in_dim(hl, i * chunk, chunk, 1)
            ts = jax.lax.dynamic_slice_in_dim(tgt, i * chunk, chunk, 1)
            nll, w = jax.checkpoint(per_chunk)(hs, headl, ts, md)
            return (acc[0] + nll, acc[1] + w), None

        # carries are (1,) arrays, not scalars: older shard_map fails to
        # transpose a scan with scalar carries under grad (_SpecError)
        zero = jnp.zeros((1,), jnp.float32)
        (nll, w), _ = jax.lax.scan(step, (zero, zero), jnp.arange(nchunks))
        if rs is not None:
            nll = jax.lax.psum(nll, rs)
            w = jax.lax.psum(w, rs)
        return (nll / jnp.maximum(w, 1.0))[0]

    from repro.core.sharding import row_axes
    rs = row_axes(ctx.mesh, h.shape[0]) if ctx.mesh.devices.size > 1 \
        else None
    if ctx.mesh.devices.size == 1:
        return body(h, head, targets)
    return _shard_map(
        body, ctx.mesh,
        (P(rs, None, None), P("model", None), P(rs, None)),
        P(),
    )(h, head, targets)


def vocab_parallel_logits(h: jnp.ndarray, head: jnp.ndarray, ctx: TPCtx,
                          final_softcap: Optional[float] = None
                          ) -> jnp.ndarray:
    """[B, S, D] -> [B, S, Vp] (vocab-sharded over model). Serving path."""
    if ctx.mesh.devices.size == 1:
        # promote (not hard-cast): an f64 reference run keeps f64 here
        lt = jnp.promote_types(h.dtype, jnp.float32)
        logits = jnp.einsum("bsd,vd->bsv", h.astype(lt), head)
        if final_softcap:
            logits = final_softcap * jnp.tanh(logits / final_softcap)
        return logits

    def body(hl, headl):
        logits = jnp.einsum("bsd,vd->bsv", hl.astype(jnp.float32),
                            headl.astype(jnp.float32))
        if final_softcap:
            logits = final_softcap * jnp.tanh(logits / final_softcap)
        return logits

    from repro.core.sharding import row_axes
    rs = row_axes(ctx.mesh, h.shape[0])
    return _shard_map(body, ctx.mesh,
                      (P(rs, None, None), P("model", None)),
                      P(rs, None, "model"))(h, head)
