"""LM assembly: params schema, forward (train / prefill / decode), caches.

One generic decoder frame covers all ten assigned archs:
  * layers follow ``cfg.block_pattern`` cycled; the repeating group is
    scanned (params stacked on a leading group axis) so compile time is
    per-group, not per-layer; pattern remainders (recurrentgemma's 38 = 3k+2)
    run unrolled as a tail.
  * block = mixer (attention kind / rglru / mlstm / slstm) + FFN
    (dense MaxEVA-planned MLP or routed MoE); xLSTM blocks carry their own
    projections (d_ff = 0 -> no FFN sub-block).
  * whisper adds an encoder stack + per-layer cross-attention;
    paligemma prepends (stubbed) patch embeddings with a prefix-LM mask.

Residual stream is sequence-sharded over the model axis (Megatron-SP) when
``cfg.seq_shard_activations``; every block gathers (broadcast) on entry and
scatters (adder-tree reduction) on exit, exactly the paper's I/O economics.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.sharding import dp_axes, dp_size, model_size
from repro.models import param as pm
from repro.models.attention import attn_defs, attention_apply, update_cache
from repro.models.layers import (
    TPCtx,
    _sp_active,
    embed_def,
    gather_seq,
    mlp_apply,
    mlp_defs,
    rmsnorm,
    scatter_seq,
    vocab_parallel_embed,
)
from repro.models.loss import vocab_parallel_logits, vocab_parallel_xent
from repro.models.moe import moe_apply, moe_defs
from repro.models.param import ParamDef
from repro.models.rglru import rglru_apply, rglru_cache_defs, rglru_defs
from repro.models.xlstm import (
    mlstm_apply,
    mlstm_cache_defs,
    mlstm_defs,
    slstm_apply,
    slstm_cache_defs,
    slstm_defs,
)

_ATTN_KINDS = ("global", "local", "chunked")


def _stack_defs(defs: Any, n: int) -> Any:
    """Prepend a group axis to every ParamDef in a tree (packed views ride
    along: the view axis stays last)."""
    def add(d: ParamDef, _path: str):
        spec = P(*([None] + list(d.spec)))
        return dataclasses.replace(d, shape=(n, *d.shape), spec=spec)
    return pm._walk(defs, add)


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    mesh: Mesh

    def __post_init__(self):
        cfg = self.cfg
        self.ctx = TPCtx(
            mesh=self.mesh,
            sp=cfg.seq_shard_activations and model_size(self.mesh) > 1,
            compute_dtype=jnp.dtype(cfg.compute_dtype),
        )

    # -- parameter schema ----------------------------------------------------

    def _block_defs(self, btype: str) -> Dict[str, Any]:
        cfg, model = self.cfg, model_size(self.mesh)
        dt, fsdp = cfg.param_dtype, cfg.fsdp_params
        d = {"ln1": ParamDef((cfg.d_model,), P(), init="zeros",
                             dtype="float32")}
        if btype in _ATTN_KINDS:
            d["attn"] = attn_defs(cfg, model, dt, fsdp,
                                  packed=cfg.packed_qkv)
        elif btype == "rglru":
            d["mix"] = rglru_defs(cfg, model, dt, fsdp)
        elif btype == "mlstm":
            d["mix"] = mlstm_defs(cfg, model, dt, fsdp)
        elif btype == "slstm":
            d["mix"] = slstm_defs(cfg, model, dt, fsdp)
        else:
            raise ValueError(btype)
        if self.cfg.encdec:
            d["lnx"] = ParamDef((cfg.d_model,), P(), init="zeros",
                                dtype="float32")
            # cross-attention stays unpacked: wq consumes the decoder
            # stream, wk/wv the encoder output — packing would force a
            # per-step weight slice (the copy this schema exists to kill)
            d["xattn"] = attn_defs(cfg, model, dt, fsdp, packed=False)
        if cfg.d_ff > 0:
            d["ln2"] = ParamDef((cfg.d_model,), P(), init="zeros",
                                dtype="float32")
            if cfg.moe:
                d["ffn"] = moe_defs(cfg, model, dt, fsdp)
            else:
                d["ffn"] = mlp_defs(cfg.d_model, cfg.d_ff, model,
                                    cfg.gated_mlp, dt, fsdp,
                                    up_y=self.ctx.up_y,
                                    down_y=self.ctx.down_y)
        return d

    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        vp = cfg.padded_vocab()
        defs: Dict[str, Any] = {
            "embed": embed_def(vp, cfg.d_model, cfg.param_dtype,
                               cfg.fsdp_params),
            "final_norm": ParamDef((cfg.d_model,), P(), init="zeros",
                                   dtype="float32"),
        }
        if not cfg.tie_embeddings:
            defs["head"] = embed_def(vp, cfg.d_model, cfg.param_dtype,
                                     cfg.fsdp_params)
        group = {f"b{i}": self._block_defs(bt)
                 for i, bt in enumerate(cfg.block_pattern)}
        if cfg.n_groups > 0:
            defs["groups"] = _stack_defs(group, cfg.n_groups)
        defs["tail"] = {f"t{i}": self._block_defs(bt)
                        for i, bt in enumerate(cfg.tail_blocks)}
        if cfg.encdec:
            enc_block = {
                "ln1": ParamDef((cfg.d_model,), P(), init="zeros",
                                dtype="float32"),
                "attn": attn_defs(cfg, model_size(self.mesh),
                                  cfg.param_dtype, cfg.fsdp_params,
                                  packed=cfg.packed_qkv),
                "ln2": ParamDef((cfg.d_model,), P(), init="zeros",
                                dtype="float32"),
                "ffn": mlp_defs(cfg.d_model, cfg.d_ff,
                                model_size(self.mesh), cfg.gated_mlp,
                                cfg.param_dtype, cfg.fsdp_params),
            }
            defs["encoder"] = {
                "blocks": _stack_defs(enc_block, cfg.n_enc_layers),
                "final_norm": ParamDef((cfg.d_model,), P(), init="zeros",
                                       dtype="float32"),
            }
        return defs

    def abstract_params(self):
        return pm.abstract(self.param_defs())

    def param_specs(self):
        return pm.specs(self.param_defs())

    def init_params(self, seed: int = 0):
        return pm.initialize(self.param_defs(), seed, self.mesh)

    def n_params(self) -> int:
        return pm.n_params(self.param_defs())

    def quantize_params_for_serving(self, params: Dict[str, Any]
                                    ) -> Dict[str, Any]:
        """One-shot int8 weight quantization for serving (paper §IV-C1's
        int8 pipeline applied to decode): every projection GEMM weight —
        the packed ``wqkv``, the o-projection ``wo``, and the MLP
        ``up``/``gate``/``down`` — becomes a ``QuantizedWeight`` (int8
        values + per-output-column f32 scales, the ROADMAP column-wise
        quantize).  Decode then runs int8 x int8 -> int32 GEMMs whose
        epilogues re-apply the scales at the int32 -> fp32 boundary, so
        consecutive GEMMs never bounce through a dequantized fp32 tensor
        (guarded by ``launch.hlo_analysis.int8_bounce_count``).

        Deliberately left at full precision: norms and embeddings (tiny,
        gather-dominated), the vocab head (logit fidelity), recurrent
        mixers (rglru/mlstm/slstm state math), MoE experts (routed einsum
        path), cross-attention and the encoder stack (prefill-side,
        different-input GEMMs), and legacy unpacked wq/wk/wv schemas.

        Single-shard only (the multi-device decode path runs GSPMD
        einsums); idempotent — already-quantized leaves pass through."""
        from repro.kernels.quantize import (QuantizedWeight,
                                            quantize_weight_colwise)
        assert model_size(self.mesh) == 1, (
            "int8 serving is single-shard: the model-parallel decode path "
            "keeps full-precision GSPMD einsums")
        moe = self.cfg.moe

        def walk(tree: Any, path: str) -> Any:
            if isinstance(tree, dict):
                return {k: walk(v, f"{path}/{k}") for k, v in tree.items()}
            if isinstance(tree, QuantizedWeight):
                return tree
            name = path.rsplit("/", 1)[-1]
            if "/encoder/" in path or "/xattn/" in path:
                return tree
            if "/attn/" in path and name in ("wqkv", "wo"):
                return quantize_weight_colwise(tree)
            if "/ffn/" in path and not moe and name in ("up", "gate",
                                                        "down"):
                return quantize_weight_colwise(tree)
            return tree

        return walk(params, "")

    # -- blocks ---------------------------------------------------------------

    def _theta(self, btype: str) -> float:
        cfg = self.cfg
        if btype == "global" and cfg.rope_theta_global:
            return cfg.rope_theta_global
        return cfg.rope_theta

    def _block(self, btype: str, bp, h, xn, next_scale, *, positions,
               mode, cache, pos, enc_out, prefix_len, q_chunk=512,
               page_table=None):
        """h: residual stream (seq-sharded under SP); xn: this block's
        input norm ``rmsnorm(h, bp["ln1"])``, PRE-COMPUTED — by the
        previous block's rmsnorm-fused down projection, or by the entry
        norm for the first block; next_scale: the NEXT norm's scale
        (the following block's ln1, or final_norm) whose rmsnorm folds
        into this block's MLP down epilogue together with the residual
        add.  Returns (h, xn_next, new_cache, aux)."""
        cfg, ctx = self.cfg, self.ctx
        aux = jnp.zeros((), jnp.float32)
        # fused-QKV path consumes the SP-sharded stream directly (the
        # gather happens inside one shard_map; backward is RS, not AR)
        fuse_qkv = (btype in _ATTN_KINDS and mode not in ("decode", "paged")
                    and _sp_active(xn, ctx)
                    and cfg.q_dim % ctx.model == 0
                    and cfg.kv_dim % ctx.model == 0)
        x = xn if fuse_qkv else gather_seq(xn, ctx)

        new_cache: Dict[str, Any] = {}
        c_attn = cache.get("attn") if cache else None
        if btype in _ATTN_KINDS:
            if mode == "prefill":
                out, built, pre_scattered = self._prefill_attention(
                    bp["attn"], x, btype, positions, prefix_len, c_attn,
                    q_chunk, x_seq_sharded=fuse_qkv)
                new_cache["attn"] = built
            else:
                out, nc, pre_scattered = attention_apply(
                    bp["attn"], x, cfg, ctx, kind=btype,
                    theta=self._theta(btype), positions=positions,
                    prefix_len=prefix_len, q_chunk=q_chunk,
                    cache=c_attn, pos=pos,
                    use_rope=not cfg.encdec, x_seq_sharded=fuse_qkv,
                    page_table=page_table)
                if nc is not None:
                    new_cache["attn"] = nc
        elif btype in ("rglru", "mlstm", "slstm"):
            fn = {"rglru": rglru_apply, "mlstm": mlstm_apply,
                  "slstm": slstm_apply}[btype]
            out, nc = fn(bp["mix"], x, cfg, ctx,
                         cache.get("mix") if mode == "decode" else None,
                         return_state=(mode == "prefill"))
            if nc is not None:
                new_cache["mix"] = nc
            pre_scattered = False
        else:
            raise ValueError(btype)
        h = h + (out if pre_scattered else scatter_seq(out, ctx))

        # cross-attention (whisper decoder)
        if cfg.encdec and enc_out is not None:
            xx = gather_seq(rmsnorm(h, bp["lnx"], cfg.norm_eps), ctx)
            cd = ctx.compute_dtype
            ek = jnp.einsum("bfd,dn->bfn", enc_out,
                            bp["xattn"]["wk"].astype(cd)).reshape(
                enc_out.shape[0], -1, cfg.n_kv_heads, cfg.hd)
            ev = jnp.einsum("bfd,dn->bfn", enc_out,
                            bp["xattn"]["wv"].astype(cd)).reshape(
                enc_out.shape[0], -1, cfg.n_kv_heads, cfg.hd)
            xout, _, xps = attention_apply(
                bp["xattn"], xx, cfg, ctx, kind="full",
                theta=cfg.rope_theta, positions=positions,
                kv_override=(ek, ev), use_rope=False,
                cache={} if mode == "decode" else None, pos=pos)
            h = h + (xout if xps else scatter_seq(xout, ctx))

        if cfg.d_ff > 0:
            xn2 = rmsnorm(h, bp["ln2"], cfg.norm_eps)
            if cfg.moe:
                # routed einsum path: no GEMM epilogue to fold into —
                # residual add + next norm compose standalone
                y, aux = moe_apply(bp["ffn"], gather_seq(xn2, ctx), cfg,
                                   ctx)
                h = h + scatter_seq(y, ctx)
                xn_next = rmsnorm(h, next_scale, cfg.norm_eps)
            elif _sp_active(xn2, ctx) and ctx.up_y == 1 \
                    and (ctx.down_y or ctx.model) == ctx.model:
                from repro.models.layers import mlp_apply_fused_sp
                h, xn_next = mlp_apply_fused_sp(
                    bp["ffn"], xn2, ctx, cfg.gated_mlp, residual=h,
                    norm_scale=next_scale, norm_eps=cfg.norm_eps)
            else:
                h, xn_next = mlp_apply(
                    bp["ffn"], gather_seq(xn2, ctx), ctx, cfg.gated_mlp,
                    residual=h, norm_scale=next_scale,
                    norm_eps=cfg.norm_eps)
        else:
            # xLSTM-style block without an FFN sub-block: no down GEMM,
            # the next input norm runs standalone
            xn_next = rmsnorm(h, next_scale, cfg.norm_eps)
        return h, xn_next, new_cache, aux

    def _prefill_attention(self, ap, x, btype, positions, prefix_len,
                           empty_cache, q_chunk, x_seq_sharded=False):
        """Full-sequence flash attention + build the decode cache from the
        SAME projected K/V the flash path consumed (return_kv: the packed
        QKV GEMM runs once, and the cache rounds exactly like decode)."""
        cfg, ctx = self.cfg, self.ctx
        out, kv, pre_scattered = attention_apply(
            ap, x, cfg, ctx, kind=btype, theta=self._theta(btype),
            positions=positions, prefix_len=prefix_len, q_chunk=q_chunk,
            use_rope=not cfg.encdec, x_seq_sharded=x_seq_sharded,
            return_kv=True)
        k, v = kv["k"], kv["v"]  # [B, S, KV, hd], post-rope
        b, s = k.shape[0], k.shape[1]
        kc, vc = empty_cache["k"], empty_cache["v"]
        w = kc.shape[1]
        if btype == "global":
            kc = jax.lax.dynamic_update_slice_in_dim(
                kc, k.astype(kc.dtype), 0, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                vc, v.astype(vc.dtype), 0, axis=1)
        else:
            # ring buffer: last min(S, W) positions at slots p % W
            n = min(s, w)
            ppos = jnp.arange(n) + (s - n)
            slots = jnp.mod(ppos, w)
            kc = kc.at[:, slots].set(k[:, s - n:].astype(kc.dtype))
            vc = vc.at[:, slots].set(v[:, s - n:].astype(vc.dtype))
        return out, dict(empty_cache, k=kc, v=vc), pre_scattered

    # -- forward ---------------------------------------------------------------

    def _embed_inputs(self, params, batch, mode, pos=None):
        cfg, ctx = self.cfg, self.ctx
        cd = ctx.compute_dtype
        h = vocab_parallel_embed(params["embed"], batch["tokens"], ctx)
        h = h * jnp.asarray(math.sqrt(cfg.d_model), cd)
        prefix_len = 0
        if cfg.prefix_tokens and mode != "decode":
            patches = batch["patches"].astype(cd)  # [B, P, D] (stub)
            h = jnp.concatenate([patches, h], axis=1)
            prefix_len = cfg.prefix_tokens
        if cfg.encdec:
            # sinusoidal positions for the decoder (whisper has no RoPE)
            s = h.shape[1]
            start = pos if (mode == "decode" and pos is not None) else 0
            h = h + _sinusoid(start, s, cfg.d_model, cd)
        return h, prefix_len

    def _encode(self, params, frames):
        """Whisper encoder over (stubbed) frame embeddings [B, F, D]."""
        cfg, ctx = self.cfg, self.ctx
        cd = ctx.compute_dtype
        h = frames.astype(cd) + _sinusoid(0, frames.shape[1], cfg.d_model,
                                          cd)
        positions = jnp.arange(frames.shape[1])

        def body(hh, bp):
            x = rmsnorm(hh, bp["ln1"], cfg.norm_eps)
            out, _, _ = attention_apply(bp["attn"], x, cfg, ctx,
                                        kind="full", theta=cfg.rope_theta,
                                        positions=positions,
                                        use_rope=False)
            hh = hh + out
            x2 = rmsnorm(hh, bp["ln2"], cfg.norm_eps)
            y = mlp_apply(bp["ffn"], x2, dataclasses.replace(ctx, sp=False),
                          cfg.gated_mlp)
            return hh + y, None

        h, _ = jax.lax.scan(jax.checkpoint(body), h,
                            params["encoder"]["blocks"])
        return rmsnorm(h, params["encoder"]["final_norm"], cfg.norm_eps)

    def forward(self, params, batch, *, mode="train", cache=None,
                pos=None, page_table=None):
        """Returns (h_final, new_cache, aux).  h_final is seq-sharded under
        SP (train/prefill) or [B, 1, D] (decode).

        ``mode="paged"``: paged serving — ``cache`` holds the page pools
        (``paged_cache_defs``), ``pos`` is a [B, S] array of per-token
        global positions (-1 = inactive slot), and ``page_table`` [B, P]
        maps each lane's logical pages to pool rows.  Covers both the
        multi-lane decode step (S == 1) and a chunked-prefill chunk."""
        cfg, ctx = self.cfg, self.ctx
        h, prefix_len = self._embed_inputs(params, batch, mode, pos)

        enc_out = None
        if cfg.encdec:
            if mode == "decode":
                enc_out = cache["enc_out"].astype(ctx.compute_dtype)
            else:
                enc_out = self._encode(params, batch["frames"])

        if mode == "decode":
            positions = pos + jnp.zeros((1,), jnp.int32)
        elif mode == "paged":
            positions = pos                     # [B, S] per-token, -1 idle
        else:
            positions = jnp.arange(h.shape[1])
            h = scatter_seq(h, ctx)

        pattern = cfg.block_pattern
        remat = mode == "train" and cfg.remat != "none"

        def one_block(bt, hh, xn, bp, nscale, gc):
            return self._block(bt, bp, hh, xn, nscale,
                               positions=positions, mode=mode,
                               cache=gc, pos=pos, enc_out=enc_out,
                               prefix_len=prefix_len,
                               page_table=page_table)

        if remat:
            # PER-BLOCK remat: during a group's backward only ONE layer's
            # residuals are live (per-group remat keeps all p layers live —
            # measured 45 GB/device on gemma3; see EXPERIMENTS §Perf).
            one_block = jax.checkpoint(one_block, static_argnums=(0,))

        # rmsnorm-fused down projections: each block's MLP epilogue emits
        # the NEXT block's input norm, so the residual stream never takes
        # the extra read + write between blocks.  The entry norm (first
        # block) is the single standalone input norm left; inside the
        # group scan the "next" ln1 is the SHIFTED ln1 stack (next
        # iteration's b0), closed by the first tail block's ln1 or
        # final_norm.
        if cfg.n_groups > 0:
            first_scale = params["groups"]["b0"]["ln1"][0]
        elif cfg.tail_blocks:
            first_scale = params["tail"]["t0"]["ln1"]
        else:
            first_scale = None
        xn = (rmsnorm(h, first_scale, cfg.norm_eps)
              if first_scale is not None else None)

        def group_body(carry, xs):
            hh, xnc = carry
            if cache is not None:
                gp, ln1_nxt, gcache = xs
            else:
                (gp, ln1_nxt), gcache = xs, None
            new_gc = {}
            aux_t = jnp.zeros((), jnp.float32)
            for i, bt in enumerate(pattern):
                nscale = (gp[f"b{i + 1}"]["ln1"]
                          if i + 1 < len(pattern) else ln1_nxt)
                hh, xnc, nc, aux = one_block(
                    bt, hh, xnc, gp[f"b{i}"], nscale,
                    gcache[f"b{i}"] if gcache is not None else None)
                new_gc[f"b{i}"] = nc
                aux_t = aux_t + aux
            return (hh, xnc), (new_gc, aux_t)

        aux_total = jnp.zeros((), jnp.float32)
        new_cache: Dict[str, Any] = {}
        if cfg.n_groups > 0:
            after_groups = (params["tail"]["t0"]["ln1"] if cfg.tail_blocks
                            else params["final_norm"])
            ln1_stack = params["groups"]["b0"]["ln1"]       # [G, D] f32
            ln1_next = jnp.concatenate(
                [ln1_stack[1:], after_groups.astype(ln1_stack.dtype)[None]],
                axis=0)
            xs = (params["groups"], ln1_next, cache["groups"]) \
                if cache is not None else (params["groups"], ln1_next)
            (h, xn), (gcaches, auxs) = jax.lax.scan(group_body, (h, xn),
                                                    xs)
            aux_total = aux_total + jnp.sum(auxs)
            if cache is not None or mode == "prefill":
                new_cache["groups"] = gcaches

        tail_caches = {}
        for i, bt in enumerate(cfg.tail_blocks):
            nscale = (params["tail"][f"t{i + 1}"]["ln1"]
                      if i + 1 < len(cfg.tail_blocks)
                      else params["final_norm"])
            h, xn, nc, aux = one_block(
                bt, h, xn, params["tail"][f"t{i}"], nscale,
                cache["tail"][f"t{i}"] if cache is not None else None)
            tail_caches[f"t{i}"] = nc
            aux_total = aux_total + aux
        if cache is not None or mode == "prefill":
            new_cache["tail"] = tail_caches
            if cfg.encdec:
                new_cache["enc_out"] = (cache["enc_out"] if mode == "decode"
                                        else enc_out)

        # the last block's fold already produced rmsnorm(h, final_norm)
        h = (xn if first_scale is not None
             else rmsnorm(h, params["final_norm"], cfg.norm_eps))
        return h, new_cache, aux_total

    # -- entry points -----------------------------------------------------------

    def head_weights(self, params):
        return params["embed"] if self.cfg.tie_embeddings else params["head"]

    def loss(self, params, batch) -> jnp.ndarray:
        cfg, ctx = self.cfg, self.ctx
        h, _, aux = self.forward(params, batch, mode="train")
        h = gather_seq(h, ctx)
        targets = batch["targets"]
        if cfg.prefix_tokens:
            ignore = -jnp.ones(
                (targets.shape[0], cfg.prefix_tokens), targets.dtype)
            targets = jnp.concatenate([ignore, targets], axis=1)
        nll = vocab_parallel_xent(h, self.head_weights(params), targets,
                                  ctx, final_softcap=cfg.final_softcap)
        if cfg.moe:
            nll = nll + 0.01 * aux / cfg.n_layers
        return nll

    def prefill(self, params, batch, max_len: Optional[int] = None):
        """Returns (last-token logits [B, Vp] vocab-sharded, cache).
        ``max_len`` reserves decode headroom beyond the prompt."""
        cfg, ctx = self.cfg, self.ctx
        b = batch["tokens"].shape[0]
        seq = batch["tokens"].shape[1] + (cfg.prefix_tokens or 0)
        defs = self.cache_defs(b, max(max_len or seq, seq, 1))
        cache = pm.initialize(defs, 0)  # traced zeros (inside jit)
        if self.mesh.devices.size > 1:
            from repro.core.sharding import constrain as _c
            cache = jax.tree.map(
                lambda x, s: _c(x, self.mesh, s), cache, pm.specs(defs))
        h, new_cache, _ = self.forward(params, batch, mode="prefill",
                                       cache=cache)
        h = gather_seq(h, ctx)
        logits = vocab_parallel_logits(h[:, -1:], self.head_weights(params),
                                       ctx, cfg.final_softcap)
        return logits[:, 0], new_cache

    def decode_step(self, params, cache, token, pos):
        """token [B, 1], pos scalar -> (logits [B, Vp] sharded, cache)."""
        cfg, ctx = self.cfg, self.ctx
        h, new_cache, _ = self.forward(params, {"tokens": token},
                                       mode="decode", cache=cache, pos=pos)
        logits = vocab_parallel_logits(h, self.head_weights(params), ctx,
                                       cfg.final_softcap)
        return logits[:, 0], new_cache

    # -- paged serving entry points (continuous batching) -----------------------

    @property
    def supports_paged_serving(self) -> bool:
        """The paged scheduler covers single-device attention-only decoder
        stacks: recurrent mixers (rglru/mlstm/slstm) carry dense state
        caches with no page indirection, enc-dec and prefix-LM archs
        prefill through extra inputs the chunk loop does not model, and
        multi-device meshes shard the dense cache layout.  Engines fall
        back to the fixed-batch loop for those."""
        cfg = self.cfg
        return (self.mesh.devices.size == 1
                and not cfg.encdec and not cfg.prefix_tokens
                and all(bt in _ATTN_KINDS
                        for bt in (*cfg.block_pattern, *cfg.tail_blocks)))

    def decode_step_paged(self, params, cache, token, positions,
                          page_table):
        """One decode step for every serving lane through the page pools.

        token [L, 1] each lane's previous pick; positions [L] the global
        position being written (-1 = idle lane: its write lands on the
        trash page and its logits row is garbage the host ignores);
        page_table [L, P].  Returns (logits [L, Vp] vocab-sharded, cache).
        The jit shape depends only on (L, pools, P) — never on which
        requests occupy the lanes, so one compiled program serves
        arbitrary admit/retire churn.

        Attention inside this trace is the paged flash-decode kernel
        (``kernels/flash_attention.py``): the page gather stays at the
        pools' storage dtype and logical tiles are anchored at position
        0 with the dense path's ``kv_tile``, so a lane's output is
        bitwise identical to the same history decoded through a dense
        cache — and unmapped pages / idle lanes contribute exact +0.0,
        which is what makes a lane's math independent of its neighbors'
        page assignments (the PR 8 isolation invariant, preserved by
        the kernel's tile masking)."""
        cfg, ctx = self.cfg, self.ctx
        h, new_cache, _ = self.forward(
            params, {"tokens": token}, mode="paged", cache=cache,
            pos=positions[:, None], page_table=page_table)
        logits = vocab_parallel_logits(h, self.head_weights(params), ctx,
                                       cfg.final_softcap)
        return logits[:, 0], new_cache

    def prefill_chunk(self, params, cache, tokens, positions, page_table,
                      last_idx):
        """One fixed-size prompt chunk for EVERY serving lane at once
        (write-then-attend, the same math as the decode step, so prefill
        and decode round identically).

        tokens [L, C]; positions [L, C] global positions (-1 marks idle
        lanes and the padded tail of a final partial chunk — those writes
        go to the trash page and are overwritten by decode before any
        mask admits them); page_table [L, P]; last_idx [L] index of each
        lane's final real token in THIS chunk (-1 = idle lane, clamped to
        0: its gathered row is garbage the host ignores).  Returns
        (logits [L, Vp] at each lane's last real token, cache) — only the
        rows of lanes finishing their prompt this chunk seed a pick."""
        cfg, ctx = self.cfg, self.ctx
        h, new_cache, _ = self.forward(
            params, {"tokens": tokens}, mode="paged", cache=cache,
            pos=positions, page_table=page_table)
        idx = jnp.clip(last_idx, 0)
        hl = h[jnp.arange(h.shape[0]), idx][:, None]        # [L, 1, D]
        logits = vocab_parallel_logits(hl, self.head_weights(params), ctx,
                                       cfg.final_softcap)
        return logits[:, 0], new_cache

    # -- caches -----------------------------------------------------------------

    def _cache_bs_spec(self, batch: int):
        dpx = dp_axes(self.mesh)
        if self.mesh.devices.size == 1:
            return None, None
        if batch % max(dp_size(self.mesh), 1) == 0 and batch > 1:
            return dpx, "model"
        return None, tuple([*dpx, "model"])

    def _block_cache_defs(self, btype: str, batch: int, max_len: int
                          ) -> Dict[str, Any]:
        cfg = self.cfg
        bspec, sspec = self._cache_bs_spec(batch)
        out: Dict[str, Any] = {}
        if btype in _ATTN_KINDS:
            clen = max_len if btype == "global" else min(cfg.window, max_len)
            if sspec is not None and isinstance(sspec, tuple):
                # keep tiny ring buffers shardable
                total = 1
                for a in sspec:
                    total *= dict(zip(self.mesh.axis_names,
                                      self.mesh.devices.shape))[a]
                if clen % total != 0:
                    sspec = "model"
            spec = P(bspec, sspec, None, None)
            out["attn"] = {
                "k": ParamDef((batch, clen, cfg.n_kv_heads, cfg.hd), spec,
                              init="zeros", dtype="bfloat16"),
                "v": ParamDef((batch, clen, cfg.n_kv_heads, cfg.hd), spec,
                              init="zeros", dtype="bfloat16"),
            }
        elif btype == "rglru":
            out["mix"] = rglru_cache_defs(cfg, batch, "bfloat16")
        elif btype == "mlstm":
            out["mix"] = mlstm_cache_defs(cfg, batch, "bfloat16")
        elif btype == "slstm":
            out["mix"] = slstm_cache_defs(cfg, batch, "bfloat16")
        return out

    def cache_defs(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        group = {f"b{i}": self._block_cache_defs(bt, batch, max_len)
                 for i, bt in enumerate(cfg.block_pattern)}
        defs: Dict[str, Any] = {}
        if cfg.n_groups > 0:
            defs["groups"] = _stack_defs(group, cfg.n_groups)
        defs["tail"] = {f"t{i}": self._block_cache_defs(bt, batch, max_len)
                        for i, bt in enumerate(cfg.tail_blocks)}
        if cfg.encdec:
            defs["enc_out"] = ParamDef(
                (batch, cfg.enc_frames, cfg.d_model), P(),
                init="zeros", dtype="bfloat16")
        return defs

    def abstract_cache(self, batch: int, max_len: int):
        return pm.abstract(self.cache_defs(batch, max_len))

    def cache_specs(self, batch: int, max_len: int):
        return pm.specs(self.cache_defs(batch, max_len))

    def paged_cache_defs(self, n_pages: int, page_size: int
                         ) -> Dict[str, Any]:
        """Paged serving cache: per attention layer, K/V page POOLS of
        shape [n_pages + 1, page_size, kv, hd] shared by every lane
        through the page table (row n_pages is the trash page — written
        by idle lanes and padded chunk tails, never read).  Replaces the
        per-(batch, max_len) dense layout, which is what decouples the
        decode jit shape from request shapes."""
        if not self.supports_paged_serving:
            raise ValueError(
                "paged serving needs a single-device attention-only "
                "decoder (no recurrent mixers / enc-dec / prefix-LM); "
                f"got pattern {self.cfg.block_pattern} on "
                f"{self.mesh.devices.size} device(s)")
        cfg = self.cfg
        shape = (n_pages + 1, page_size, cfg.n_kv_heads, cfg.hd)

        def block() -> Dict[str, Any]:
            return {"attn": {
                "kp": ParamDef(shape, P(), init="zeros", dtype="bfloat16"),
                "vp": ParamDef(shape, P(), init="zeros", dtype="bfloat16"),
            }}

        group = {f"b{i}": block()
                 for i, _ in enumerate(cfg.block_pattern)}
        defs: Dict[str, Any] = {}
        if cfg.n_groups > 0:
            defs["groups"] = _stack_defs(group, cfg.n_groups)
        defs["tail"] = {f"t{i}": block()
                        for i, _ in enumerate(cfg.tail_blocks)}
        return defs

    def abstract_paged_cache(self, n_pages: int, page_size: int):
        return pm.abstract(self.paged_cache_defs(n_pages, page_size))


def _sinusoid(start, length, d_model, dtype):
    pos = start + jnp.arange(length)[:, None].astype(jnp.float32)
    half = d_model // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                   * (math.log(10000.0) / max(half - 1, 1)))
    ang = pos * freq[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                           axis=-1)[None].astype(dtype)
