"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
  a_t = exp(-c * softplus(Lambda) * sigma(r_t)),  c = 8
  r_t, i_t: per-channel gates from linear maps of the input.

Training/prefill uses ``jax.lax.associative_scan`` (parallel prefix over
the affine maps h -> a*h + b) — log-depth, the TPU-friendly adaptation of
what Griffin implements as a fused GPU scan kernel.  Decode is the O(1)
per-step update.  The temporal conv is width-``conv_width`` causal.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import TPCtx
from repro.models.param import ParamDef

_C = 8.0


def rglru_defs(cfg: ArchConfig, model: int, dtype: str,
               fsdp: bool) -> Dict[str, ParamDef]:
    d = cfg.d_model
    w = cfg.lru_width or d
    col = P("data", "model") if fsdp else P(None, "model")
    row = P("model", "data") if fsdp else P("model", None)
    return {
        "in_x": ParamDef((d, w), col, dtype=dtype),       # recurrence branch
        "in_g": ParamDef((d, w), col, dtype=dtype),       # gate branch
        "conv": ParamDef((cfg.conv_width, w), P(None, "model"), dtype=dtype),
        "w_a": ParamDef((w, w), col, dtype=dtype),        # recurrence gate
        "w_i": ParamDef((w, w), col, dtype=dtype),        # input gate
        "lam": ParamDef((w,), P("model"), init="lru_log", dtype="float32"),
        "out": ParamDef((w, d), row, dtype=dtype),
    }


def _causal_conv(x: jnp.ndarray, kernel: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv over time. x [B, S, W], kernel [cw, W].
    ``state`` [B, cw-1, W] carries the left context for decode."""
    cw = kernel.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * kernel[i][None, None]
              for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else state
    return out.astype(x.dtype), new_state


def _gates(params, xc):
    """a (log-space decay) and gated input from the conv'd branch."""
    f32 = jnp.float32
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc.astype(f32),
                                  params["w_a"].astype(f32)))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc.astype(f32),
                                  params["w_i"].astype(f32)))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(f32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xc.astype(f32))
    return a, b


def rglru_apply(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
                cfg: ArchConfig, ctx: TPCtx,
                cache: Optional[Dict[str, jnp.ndarray]] = None,
                return_state: bool = False
                ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """x [B, S, D] replicated-over-model -> ([B, S, D], new_cache).

    cache = {'h': [B, W], 'conv': [B, cw-1, W]} for decode;
    ``return_state`` (prefill) emits the post-sequence cache for free
    (the scan's final element)."""
    cd = ctx.compute_dtype
    xb = jnp.einsum("bsd,dw->bsw", x, params["in_x"].astype(cd))
    gb = jnp.einsum("bsd,dw->bsw", x, params["in_g"].astype(cd))

    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xb, params["conv"].astype(cd), conv_state)
    a, b = _gates(params, xc)

    if cache is None:
        # parallel prefix over affine maps: (a2,b2)o(a1,b1) = (a1a2, a2b1+b2)
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        new_cache = None
        if return_state:
            new_cache = {"h": h[:, -1], "conv": new_conv}
    else:
        h = a[:, 0] * cache["h"].astype(jnp.float32) + b[:, 0]
        new_cache = dict(cache, h=h.astype(cache["h"].dtype),
                         conv=new_conv)
        h = h[:, None]

    out = h.astype(cd) * jax.nn.gelu(gb.astype(jnp.float32)).astype(cd)
    y = jnp.einsum("bsw,wd->bsd", out, params["out"].astype(cd))
    return y, new_cache


def rglru_cache_defs(cfg: ArchConfig, batch: int, dtype: str
                     ) -> Dict[str, ParamDef]:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": ParamDef((batch, w), P(None, "model"), init="zeros",
                      dtype="float32"),
        "conv": ParamDef((batch, cfg.conv_width - 1, w),
                         P(None, None, "model"), init="zeros", dtype=dtype),
    }
