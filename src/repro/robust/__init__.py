"""Fault-tolerance layer for the serving stack: deterministic fault
injection (``faults``), numerical-health guards + structured per-request
statuses (``guards``), and the retry/backoff supervisor (``retry``).
See ``docs/robustness.md`` for the fault model and guard invariants."""
from repro.robust.faults import (
    FaultPlan,
    LogitFault,
    StallFault,
    TransientServeError,
    bitflip_leaf,
    truncate_leaf,
    truncate_manifest,
)
from repro.robust.guards import (
    STATUS_DEGRADED,
    STATUS_NONFINITE,
    STATUS_OK,
    STATUS_SHED,
    STATUS_TIMEOUT,
    GenerateResult,
    NumericalHealthError,
)
from repro.robust.retry import generate_with_retry

__all__ = [
    "FaultPlan", "LogitFault", "StallFault", "TransientServeError",
    "bitflip_leaf", "truncate_leaf", "truncate_manifest",
    "GenerateResult", "NumericalHealthError", "generate_with_retry",
    "STATUS_OK", "STATUS_NONFINITE", "STATUS_DEGRADED", "STATUS_TIMEOUT",
    "STATUS_SHED",
]
