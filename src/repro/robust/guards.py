"""Numerical-health guards and the structured per-request result type.

The serving engine's failure-isolation contract: one poisoned lane (a
non-finite logit, a saturated int8 activation range) must never take down
the batch.  The guards here are the *measurement* half of that contract —
cheap per-lane probes computed in the SAME jitted dispatch as the token
pick (see ``ServeEngine._pick_guarded``), so a guarded step costs one
fused call exactly like an unguarded one — and ``GenerateResult`` is the
*reporting* half: per-lane structured statuses instead of an exception or
silently corrupt tokens.

Status vocabulary (``GenerateResult.status`` per lane):

  * ``ok``                     — decoded normally (EOS or token budget).
  * ``quarantined_nonfinite``  — a NaN/Inf logit appeared; the lane was
    frozen at that step (padded from then on) while its batch peers kept
    decoding bitwise-unchanged.
  * ``degraded_fp32``          — the int8 saturation probe tripped; the
    lane finished decoding but its tokens came from the fp32 fallback
    path from the following step on (only with
    ``ServeConfig.fp32_fallback``; without it the status still records
    the saturation so the caller can re-issue at fp32).
  * ``timeout``                — the request's wall-clock budget expired
    while the lane was still decoding (partial tokens are returned).
  * ``shed``                   — admission control rejected the lane
    (batch rows beyond ``ServeConfig.max_lanes``); no compute was spent.
"""
from __future__ import annotations

import dataclasses

import numpy as np

STATUS_OK = "ok"
STATUS_NONFINITE = "quarantined_nonfinite"
STATUS_DEGRADED = "degraded_fp32"
STATUS_TIMEOUT = "timeout"
STATUS_SHED = "shed"

STATUSES = (STATUS_OK, STATUS_NONFINITE, STATUS_DEGRADED, STATUS_TIMEOUT,
            STATUS_SHED)


class NumericalHealthError(RuntimeError):
    """Raised (only under ``ServeConfig(on_nonfinite='raise')``) when a
    non-finite logit appears — for callers that prefer fail-stop over
    per-lane quarantine."""


@dataclasses.dataclass
class GenerateResult:
    """Structured outcome of one ``ServeEngine.generate_with_status``.

    ``tokens``     [B, n] generated ids (pad_id beyond a lane's fault /
                   completion point; shed lanes are all-pad).
    ``status``     length-B list of the statuses above.
    ``fault_step`` [B] step index at which the lane left ``ok`` (-1 if it
                   never did — including shed lanes, which are rejected
                   at admission before any step runs).
    ``n_steps``    decode steps actually executed.
    ``timed_out``  True when the wall-clock budget ended the loop.
    ``admitted``   lanes actually decoded (B - admitted were shed).
    """

    tokens: np.ndarray
    status: list
    fault_step: np.ndarray
    n_steps: int
    timed_out: bool = False
    admitted: int = 0

    @property
    def ok(self) -> bool:
        return all(s == STATUS_OK for s in self.status)

    def lanes_with(self, status: str) -> np.ndarray:
        return np.flatnonzero(np.asarray(self.status, object) == status)
