"""Deterministic fault-injection harness for the serving stack.

A ``FaultPlan`` is a *seeded, declarative* description of the faults a
test (or a chaos drill) wants injected into one ``ServeEngine.generate``
call: NaN/Inf/overscaled logits at a chosen decode step and lane, a host
stall at a chosen step, and transient whole-call failures for exercising
the retry wrapper.  Checkpoint corruption (truncated leaf, flipped bit,
truncated manifest) operates on a committed checkpoint directory on disk,
reading the manifest so the corrupted *parameter* is known by name.

Design rules:

  * ZERO overhead when disabled: the engine's decode loop holds a single
    ``plan is not None`` check per hook; no plan, no extra work, and the
    traced decode HLO is byte-identical (``tests/test_robustness.py``
    proves it against the ``gemm_dispatches`` / ``int8_bounce_count``
    guards).
  * DETERMINISTIC: every random choice (bit-flip position) comes from a
    ``numpy`` Generator seeded by the plan/argument seed, so a failing
    fault run reproduces exactly.
  * EXPLICIT hooks: faults are applied where the production code already
    has a boundary (logits on the host loop, files on disk), never by
    monkeypatching internals — what the harness proves is therefore what
    production would do.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Tuple

import jax.numpy as jnp
import numpy as np

_LOGIT_KINDS = ("nan", "inf", "ninf", "scale")


class TransientServeError(RuntimeError):
    """A retryable whole-request failure (the class of error the
    retry/backoff wrapper in ``repro.robust.retry`` absorbs)."""


@dataclasses.dataclass(frozen=True)
class LogitFault:
    """Corrupt the logits a decode step's token is picked from.

    ``step`` indexes the generated token (0 = the token picked from the
    prefill logits); ``lanes`` are batch rows.  ``kind``:

      * ``'nan'`` / ``'inf'`` / ``'ninf'``: poison the whole lane row —
        the non-finite fault the finite-lane guard must quarantine;
      * ``'scale'``: multiply the lane by ``scale`` — drives the
        fixed-scale int8 saturation probe past its threshold without
        leaving the finite domain (the graceful-degradation fault).
    """

    step: int
    lanes: Tuple[int, ...]
    kind: str = "nan"
    scale: float = 64.0

    def __post_init__(self):
        if self.kind not in _LOGIT_KINDS:
            raise ValueError(f"unknown logit-fault kind {self.kind!r}; "
                             f"valid kinds are {_LOGIT_KINDS}")


@dataclasses.dataclass(frozen=True)
class StallFault:
    """Stall the host loop for ``seconds`` before decode step ``step`` —
    the hung-host fault the per-request wall-clock budget must convert
    into structured TIMEOUT statuses instead of an unbounded hang."""

    step: int
    seconds: float


@dataclasses.dataclass
class FaultPlan:
    seed: int = 0
    logit_faults: Tuple[LogitFault, ...] = ()
    stalls: Tuple[StallFault, ...] = ()
    # raise TransientServeError for the first N generate() admissions
    # (attempt counting survives across retries: the wrapper's backoff
    # loop is what eventually gets through)
    fail_first_generates: int = 0
    enabled: bool = True
    _attempts: int = dataclasses.field(default=0, repr=False)

    # -- engine hooks ---------------------------------------------------------

    def on_generate_start(self) -> None:
        if self.enabled and self._attempts < self.fail_first_generates:
            self._attempts += 1
            raise TransientServeError(
                f"injected transient failure (attempt {self._attempts} of "
                f"{self.fail_first_generates} planned)")
        self._attempts += 1

    def maybe_stall(self, step: int, sleep=time.sleep) -> None:
        if not self.enabled:
            return
        for f in self.stalls:
            if f.step == step:
                sleep(f.seconds)

    def perturb_logits(self, step: int, logits: jnp.ndarray) -> jnp.ndarray:
        """Apply every logit fault registered for ``step`` (host-side
        copy-on-write: untouched steps return ``logits`` unchanged)."""
        if not self.enabled:
            return logits
        hits = [(f, lane) for f in self.logit_faults if f.step == step
                for lane in f.lanes]
        return _poison_rows(logits, hits)

    # -- scheduler hooks ------------------------------------------------------
    #
    # The continuous-batching loop has no global step: each lane carries
    # its own request at its own step.  These variants take the per-lane
    # step vector (-1 = lane idle/stale this iteration) and interpret
    # ``LogitFault.lanes`` / ``StallFault.step`` against the step of the
    # REQUEST currently in that lane — on the lockstep fixed-batch shim
    # they reduce exactly to the legacy hooks above.

    def maybe_stall_lanes(self, lane_steps, fired: set,
                          sleep=time.sleep) -> None:
        """Per-lane stall: fires each StallFault once per drain (tracked
        in the caller-owned ``fired`` set) when any live lane reaches its
        step — under churn several iterations can match, and a stall that
        re-fired every one would model N faults, not one."""
        if not self.enabled:
            return
        for i, f in enumerate(self.stalls):
            if i in fired:
                continue
            if any(int(t) == f.step for t in lane_steps if t >= 0):
                fired.add(i)
                sleep(f.seconds)

    def perturb_logits_lanes(self, lane_steps, logits) -> jnp.ndarray:
        """Per-lane perturb: fault (step, lane) hits when the request in
        ``lane`` is at ``step`` this iteration (copy-on-write like
        ``perturb_logits``)."""
        if not self.enabled:
            return logits
        hits = [(f, lane) for f in self.logit_faults for lane in f.lanes
                if 0 <= lane < len(lane_steps)
                and int(lane_steps[lane]) == f.step]
        return _poison_rows(logits, hits)


def _poison_rows(logits: jnp.ndarray, hits) -> jnp.ndarray:
    """Apply (fault, lane) pairs to logit rows; no hits returns the SAME
    object (the copy-on-write contract both hook flavors share)."""
    if not hits:
        return logits
    arr = np.array(logits, copy=True)
    for f, lane in hits:
        if f.kind == "nan":
            arr[lane, :] = np.nan
        elif f.kind == "inf":
            arr[lane, :] = np.inf
        elif f.kind == "ninf":
            arr[lane, :] = -np.inf
        else:  # 'scale'
            arr[lane, :] *= f.scale
    return jnp.asarray(arr)


# -- on-disk checkpoint corruption -------------------------------------------
#
# These operate on a COMMITTED step directory (the post-rename layout the
# CheckpointManager wrote) and return the name of the parameter they
# corrupted, so tests can assert the structured restore error names it.


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def _leaf_meta(ckpt_dir: str, step: int, leaf: int):
    d = _step_dir(ckpt_dir, step)
    with open(os.path.join(d, "manifest.json")) as f:
        meta = json.load(f)["leaves"][leaf]
    return d, meta, meta.get("param", meta["file"])


def truncate_leaf(ckpt_dir: str, step: int, leaf: int = 0,
                  keep_bytes: int = 16) -> str:
    """Truncate a leaf file to ``keep_bytes`` (a half-written / torn leaf
    after a crash that beat the fsync).  Returns the parameter name."""
    d, meta, name = _leaf_meta(ckpt_dir, step, leaf)
    path = os.path.join(d, meta["file"])
    with open(path, "rb") as f:
        data = f.read(keep_bytes)
    with open(path, "wb") as f:
        f.write(data)
    return name


def bitflip_leaf(ckpt_dir: str, step: int, leaf: int = 0,
                 seed: int = 0) -> str:
    """Flip one seeded-random bit in a leaf file's data section (silent
    media corruption the crc32 must catch).  Returns the parameter name."""
    d, meta, name = _leaf_meta(ckpt_dir, step, leaf)
    path = os.path.join(d, meta["file"])
    with open(path, "rb") as f:
        data = bytearray(f.read())
    rng = np.random.default_rng(seed)
    # stay clear of the .npy header so the flip corrupts VALUES, which
    # only the checksum (not the parser) can see
    off = int(rng.integers(len(data) // 2, len(data)))
    data[off] ^= 1 << int(rng.integers(0, 8))
    with open(path, "wb") as f:
        f.write(data)
    return name


def truncate_manifest(ckpt_dir: str, step: int, keep_bytes: int = 32) -> str:
    """Truncate a step's manifest.json (torn metadata write): the step
    still *lists* as present but must restore as structured corruption."""
    path = os.path.join(_step_dir(ckpt_dir, step), "manifest.json")
    with open(path, "rb") as f:
        data = f.read(keep_bytes)
    with open(path, "wb") as f:
        f.write(data)
    return "manifest.json"
