"""Retry/backoff supervisor around ``ServeEngine.generate_with_status``.

Production traffic sees transient failures (a preempted host step, a
flaky interconnect op) that succeed on re-issue; the supervisor absorbs
``TransientServeError`` with seedless deterministic exponential backoff
and re-raises once the attempt budget is spent.  Hard failures
(``NumericalHealthError`` under fail-stop config, programming errors)
propagate immediately — retrying a deterministic fault only burns the
wall-clock budget the request has left.

The per-request wall-clock budget itself lives in
``ServeConfig.request_timeout_s`` (enforced inside the decode loop, so a
stalled host step surfaces as structured per-lane TIMEOUT statuses) and
load shedding in ``ServeConfig.max_lanes``; this wrapper only adds the
retry dimension on top.
"""
from __future__ import annotations

import time

from repro.robust.faults import FaultPlan, TransientServeError
from repro.robust.guards import GenerateResult


def generate_with_retry(engine, batch, seed: int = 0, *,
                        retries: int = 2, backoff_s: float = 0.05,
                        fault_plan: FaultPlan = None,
                        sleep=time.sleep) -> GenerateResult:
    """Run ``engine.generate_with_status`` with up to ``retries`` retries
    on ``TransientServeError``, doubling ``backoff_s`` between attempts.

    ``sleep`` is injectable so tests assert the backoff schedule without
    waiting it out.  Returns the first successful ``GenerateResult``.
    """
    if retries < 0:
        raise ValueError(f"retries must be >= 0, got {retries}")
    if backoff_s < 0:
        raise ValueError(f"backoff_s must be >= 0, got {backoff_s}")
    delay = backoff_s
    for attempt in range(retries + 1):
        try:
            return engine.generate_with_status(batch, seed,
                                               fault_plan=fault_plan)
        except TransientServeError:
            if attempt == retries:
                raise
            sleep(delay)
            delay *= 2
