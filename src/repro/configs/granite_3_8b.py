"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 — GQA. [hf:ibm-granite/granite-3.0-2b-base]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab=49155,
    block_pattern=("global",),
    gated_mlp=True,
    param_dtype="bfloat16",
    fsdp_params=True,
    # pure full attention: long_500k would be a 524288-token quadratic KV —
    # skipped per the assignment's sub-quadratic rule (DESIGN.md).
    skip_shapes=("long_500k",),
    microbatches=4,
)

SMOKE = ArchConfig(
    name="granite-3-8b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    block_pattern=("global",),
    gated_mlp=True,
    seq_shard_activations=False,
)
