"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention, 2 recurrent : 1 attention.
[arXiv:2402.19427]

38 layers with a period-3 pattern leaves a 2-block tail (rglru, rglru),
handled unrolled outside the scanned groups."""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    lru_width=4096,
    conv_width=4,
    gated_mlp=True,
    param_dtype="bfloat16",
    fsdp_params=True,
    # RG-LRU state + windowed attention -> long_500k runs natively.
    microbatches=4,
)

SMOKE = ArchConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    n_layers=5,   # 1 full group + (rglru, rglru) tail, like the real 38
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=256,
    block_pattern=("rglru", "rglru", "local"),
    window=16,
    lru_width=64,
    conv_width=4,
    gated_mlp=True,
    seq_shard_activations=False,
)
