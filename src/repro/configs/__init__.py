"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import SHAPES, ArchConfig, ShapeCell
from repro.configs import (
    granite_3_8b,
    gemma3_12b,
    gemma2_27b,
    internlm2_1_8b,
    paligemma_3b,
    whisper_small,
    xlstm_350m,
    recurrentgemma_9b,
    grok_1_314b,
    llama4_scout_17b_a16e,
)

_MODULES = {
    "granite-3-8b": granite_3_8b,
    "gemma3-12b": gemma3_12b,
    "gemma2-27b": gemma2_27b,
    "internlm2-1.8b": internlm2_1_8b,
    "paligemma-3b": paligemma_3b,
    "whisper-small": whisper_small,
    "xlstm-350m": xlstm_350m,
    "recurrentgemma-9b": recurrentgemma_9b,
    "grok-1-314b": grok_1_314b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str, smoke: bool = False) -> ArchConfig:
    mod = _MODULES[arch_id]
    return mod.SMOKE if smoke else mod.FULL


def runnable_cells(arch_id: str) -> List[str]:
    cfg = get_config(arch_id)
    return [s for s in SHAPES if s not in cfg.skip_shapes]


__all__ = ["ArchConfig", "ShapeCell", "SHAPES", "ARCH_IDS", "get_config",
           "runnable_cells"]
