"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 — sLSTM +
mLSTM blocks (7:1 mLSTM:sLSTM). [arXiv:2405.04517]

d_ff=0: xLSTM blocks carry their own up/down projections (mLSTM expands
2x internally); there is no separate FFN."""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    gated_mlp=False,
    # recurrent state -> long_500k runs (O(1) state per step).
    microbatches=2,
)

SMOKE = ArchConfig(
    name="xlstm-350m-smoke",
    family="ssm",
    n_layers=8,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    head_dim=32,
    d_ff=0,
    vocab=256,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    gated_mlp=False,
    seq_shard_activations=False,
)
