"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216 — SigLIP + gemma backbone. [arXiv:2407.07726]

The SigLIP tower is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings [B, 256, d_model]; the transformer backbone
treats them as a bidirectional prefix (prefix-LM masking)."""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    block_pattern=("global",),
    prefix_tokens=256,
    gated_mlp=True,
    # pure full attention -> long_500k skipped (DESIGN.md).
    skip_shapes=("long_500k",),
    microbatches=2,
)

SMOKE = ArchConfig(
    name="paligemma-3b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=256,
    block_pattern=("global",),
    prefix_tokens=8,
    gated_mlp=True,
    seq_shard_activations=False,
)
