"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192,
MoE 16 experts top-1 + shared expert, early fusion, iRoPE-style 3:1
chunked:global attention. [hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    block_pattern=("chunked", "chunked", "chunked", "global"),
    window=8192,
    moe=True,
    n_experts=16,
    top_k=1,
    moe_shared_expert=True,
    gated_mlp=True,
    param_dtype="bfloat16",
    fsdp_params=True,
    # 3:1 chunked-local -> long_500k runs (global layers keep sharded KV).
    microbatches=8,
)

SMOKE = ArchConfig(
    name="llama4-scout-smoke",
    family="moe",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    block_pattern=("chunked", "chunked", "chunked", "global"),
    window=16,
    moe=True,
    n_experts=4,
    top_k=1,
    capacity_factor=8.0,
    moe_shared_expert=True,
    gated_mlp=True,
    seq_shard_activations=False,
)
