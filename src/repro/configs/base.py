"""Architecture / shape-cell configuration schema."""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # Block pattern, cycled over layers.  Entries: 'global', 'local',
    # 'chunked', 'rglru', 'mlstm', 'slstm'.  MoE archs set moe=True and the
    # FFN of every block becomes a routed MoE.
    block_pattern: Tuple[str, ...] = ("global",)
    window: int = 1024           # local/chunked attention window
    attn_softcap: Optional[float] = None   # gemma2 attention logit softcap
    final_softcap: Optional[float] = None  # gemma2 final logit softcap
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None  # gemma3 dual-theta

    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    moe_shared_expert: bool = False
    capacity_factor: float = 1.25

    # gated MLP (SwiGLU/GeGLU) vs plain
    gated_mlp: bool = True

    # encoder-decoder (whisper)
    encdec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500

    # VLM prefix (paligemma): number of (stubbed) patch-embedding tokens
    prefix_tokens: int = 0

    # recurrent widths
    lru_width: Optional[int] = None     # RG-LRU state width
    conv_width: int = 4

    norm_eps: float = 1e-6
    tie_embeddings: bool = True

    # self-attention QKV as one packed column-sharded `wqkv` parameter
    # (single GEMM dispatch per apply, zero apply-time weight copies);
    # False falls back to the legacy separate wq/wk/wv schema
    packed_qkv: bool = True

    # dtype / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_state_mode: str = "fp32"        # 'fp32' | 'int8'
    fsdp_params: bool = False           # additionally shard params over data
    seq_shard_activations: bool = True  # Megatron-SP residual stream
    remat: str = "full"                 # 'none' | 'full'

    # gradient-accumulation microbatches for train_4k (peak activation
    # memory divides by this; grads accumulate in param-sharded buffers)
    microbatches: int = 1
    grad_accum_dtype: str = "float32"   # 'bfloat16' halves the buffers

    # shape cells this arch skips (with the reason recorded in DESIGN.md)
    skip_shapes: Tuple[str, ...] = ()

    # --- derived -----------------------------------------------------------

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    def padded_vocab(self, multiple: int = 128) -> int:
        return multiple * math.ceil(self.vocab / multiple)

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.pattern_period

    @property
    def tail_blocks(self) -> Tuple[str, ...]:
        """Remainder layers when n_layers % pattern_period != 0 (e.g.
        recurrentgemma's 38 layers with a period-3 pattern)."""
        r = self.n_layers % self.pattern_period
        return self.block_pattern[:r]

    def param_count(self) -> int:
        """Total parameters (exact for our param schema)."""
        d, ff, v = self.d_model, self.d_ff, self.padded_vocab()
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += d * v
        total += d  # final norm
        for i in range(self.n_layers):
            total += self._block_params(self.block_pattern[i % self.pattern_period])
        if self.encdec:
            for _ in range(self.n_enc_layers):
                total += self._enc_block_params()
        return total

    def active_param_count(self) -> int:
        """Active-per-token parameters (MoE: top_k + shared experts)."""
        if not self.moe:
            return self.param_count()
        total = self.param_count()
        n_mats = 3 if self.gated_mlp else 2
        expert = n_mats * self.d_model * self.d_ff
        dead = (self.n_experts - self.top_k) * expert * self.n_layers
        if self.moe_shared_expert:
            pass  # shared expert always active
        return total - dead

    def _attn_params(self) -> int:
        d = self.d_model
        return (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d + d)

    def _mlp_params(self) -> int:
        n_mats = 3 if self.gated_mlp else 2
        if self.moe:
            e = self.n_experts * n_mats * self.d_model * self.d_ff
            e += self.d_model * self.n_experts  # router
            if self.moe_shared_expert:
                e += n_mats * self.d_model * self.d_ff
            return e
        return n_mats * self.d_model * self.d_ff

    def _block_params(self, btype: str) -> int:
        d = self.d_model
        if btype in ("global", "local", "chunked"):
            return self._attn_params() + self._mlp_params() + d
        if btype == "rglru":
            w = self.lru_width or d
            # in/out proj (x2 branches), conv, gates, + mlp
            return (2 * d * w + w * d + self.conv_width * w + 3 * w
                    + self._mlp_params() + 2 * d)
        if btype == "mlstm":
            # up 2x, q/k/v (width), o gate, down, conv, norms
            w = 2 * d
            return (d * 2 * w + 3 * w * w // 4 + w * d + self.conv_width * w
                    + 4 * w + d)
        if btype == "slstm":
            w = d
            return (4 * d * w + 4 * w + (4 * w * w) // max(1, self.n_heads)
                    + self._mlp_params() + 2 * d)
        raise ValueError(btype)

    def _enc_block_params(self) -> int:
        d = self.d_model
        return self._attn_params() + 2 * d * self.d_ff + 2 * d


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}
