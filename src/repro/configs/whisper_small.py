"""whisper-small [audio]: 12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865
— encoder-decoder; conv frontend STUBBED. [arXiv:2212.04356]

Per the assignment the conv frontend is a stub: ``input_specs()`` provides
precomputed frame embeddings [B, 1500, d_model].  The encoder is 12
bidirectional layers over those frames; the decoder is 12 causal layers
with cross-attention.  ``long_500k`` is skipped: the decoder context is
architecturally bounded by the 1500-frame encoder (DESIGN.md)."""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51865,
    block_pattern=("global",),
    encdec=True,
    n_enc_layers=12,
    enc_frames=1500,
    gated_mlp=False,       # whisper uses plain GELU MLPs
    tie_embeddings=True,
    seq_shard_activations=False,  # 1500 frames not divisible by the mesh
    skip_shapes=("long_500k",),
    microbatches=2,
)

SMOKE = ArchConfig(
    name="whisper-small-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    block_pattern=("global",),
    encdec=True,
    n_enc_layers=2,
    enc_frames=24,
    gated_mlp=False,
    seq_shard_activations=False,
)
