"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2. [hf:xai-org/grok-1]

Memory policy: bf16 params, int8 block-quantized Adam states, FSDP
(params additionally sharded over the data axis) — the 314B-parameter
memory-pressure case."""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab=131072,
    block_pattern=("global",),
    moe=True,
    n_experts=8,
    top_k=2,
    gated_mlp=True,
    param_dtype="bfloat16",
    opt_state_mode="int8",
    fsdp_params=True,
    # pure full attention -> long_500k skipped (DESIGN.md).
    skip_shapes=("long_500k",),
    microbatches=8,
    grad_accum_dtype="bfloat16",
)

SMOKE = ArchConfig(
    name="grok-1-314b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    block_pattern=("global",),
    moe=True,
    n_experts=4,
    top_k=2,
    capacity_factor=8.0,
    gated_mlp=True,
    seq_shard_activations=False,
)
