"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 — local+global alternating, logit softcap. [arXiv:2408.00118]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    block_pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    gated_mlp=True,
    param_dtype="bfloat16",
    fsdp_params=True,
    # 1:1 local:global -> long_500k runs with the global-layer KV sharded.
    microbatches=8,
)

SMOKE = ArchConfig(
    name="gemma2-27b-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab=256,
    block_pattern=("local", "global"),
    window=16,
    attn_softcap=50.0,
    final_softcap=30.0,
    gated_mlp=True,
    seq_shard_activations=False,
)
