"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544 — GQA. [arXiv:2403.17297]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92544,
    block_pattern=("global",),
    gated_mlp=True,
    # pure full attention -> long_500k skipped (DESIGN.md).
    skip_shapes=("long_500k",),
    microbatches=2,
)

SMOKE = ArchConfig(
    name="internlm2-1.8b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    block_pattern=("global",),
    gated_mlp=True,
    seq_shard_activations=False,
)
