"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global, 128k context. [hf:google/gemma-3-1b-pt]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    block_pattern=("local",) * 5 + ("global",),
    window=1024,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    gated_mlp=True,
    param_dtype="bfloat16",
    fsdp_params=True,
    # mostly-local (5:1) -> long_500k runs: local layers cost O(window),
    # the 1-in-6 global layers keep a full (sharded) 500k KV.
    microbatches=4,
)

SMOKE = ArchConfig(
    name="gemma3-12b-smoke",
    family="dense",
    n_layers=6,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    block_pattern=("local",) * 5 + ("global",),
    window=16,
    rope_theta_global=1_000_000.0,
    gated_mlp=True,
    seq_shard_activations=False,
)
