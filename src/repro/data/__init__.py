from repro.data.pipeline import (
    DataConfig,
    MemmapTokenSource,
    SyntheticTokenSource,
    TokenPipeline,
)

__all__ = ["DataConfig", "TokenPipeline", "SyntheticTokenSource",
           "MemmapTokenSource"]
