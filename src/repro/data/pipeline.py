"""Deterministic, resumable token data pipeline.

Sources:
  * ``SyntheticTokenSource`` — counter-based PRNG (philox-style mixing of
    (seed, step, position)); step N is reproducible from scratch, which is
    what makes checkpoint-resume exact and what a 1000-node job needs to
    re-derive a shard's data after a restart WITHOUT coordination.
  * ``MemmapTokenSource``  — flat binary token file (np.memmap), strided by
    (step, host_shard); the production path for tokenized corpora.

``TokenPipeline`` assembles global batches for a mesh: each host builds its
slice, a background thread prefetches ``prefetch`` steps ahead, and arrays
are placed with the batch sharding so jit consumes them without resharding.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.sharding import dp_axes


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    prefetch: int = 2


def _mix(a: np.ndarray, b: int) -> np.ndarray:
    # 64-bit splitmix-style mixing, vectorized
    x = (a ^ np.uint64(b)) * np.uint64(0x9E3779B97F4A7C15)
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    return x


class SyntheticTokenSource:
    """tokens[step, row, pos] = f(seed, step, row, pos) mod vocab."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.seed = seed

    def batch(self, step: int, rows: slice, cfg: DataConfig) -> np.ndarray:
        r0, r1 = rows.start, rows.stop
        rr = np.arange(r0, r1, dtype=np.uint64)[:, None]
        pp = np.arange(cfg.seq_len + 1, dtype=np.uint64)[None, :]
        base = _mix(rr * np.uint64(1_000_003) + pp,
                    (self.seed << 20) ^ step)
        return (base % np.uint64(max(2, self.vocab - 2))).astype(np.int32)


class MemmapTokenSource:
    """Flat int32 token file; document order strided deterministically."""

    def __init__(self, path: str, vocab: int):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.vocab = vocab

    def batch(self, step: int, rows: slice, cfg: DataConfig) -> np.ndarray:
        n = len(self.tokens)
        width = cfg.seq_len + 1
        out = np.empty((rows.stop - rows.start, width), np.int32)
        for i, r in enumerate(range(rows.start, rows.stop)):
            start = ((step * cfg.global_batch + r) * width) % max(
                1, n - width)
            out[i] = self.tokens[start:start + width]
        return out


class TokenPipeline:
    def __init__(self, source, cfg: DataConfig, mesh: Mesh,
                 arch: Optional[ArchConfig] = None,
                 start_step: int = 0):
        self.source = source
        self.cfg = cfg
        self.mesh = mesh
        self.arch = arch
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- host-side batch construction ---------------------------------------

    def _host_rows(self) -> slice:
        # single-process container: the full batch; multi-host would slice
        # by process_index / process_count here.
        n = jax.process_count()
        per = self.cfg.global_batch // n
        i = jax.process_index()
        return slice(i * per, (i + 1) * per)

    def _build(self, step: int) -> Dict[str, np.ndarray]:
        toks = self.source.batch(step, self._host_rows(), self.cfg)
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if self.arch is not None and self.arch.prefix_tokens:
            rng = np.random.default_rng(self.cfg.seed * 7919 + step)
            batch["patches"] = rng.standard_normal(
                (toks.shape[0], self.arch.prefix_tokens,
                 self.arch.d_model), np.float32)
            batch["tokens"] = batch["tokens"][
                :, :self.cfg.seq_len - self.arch.prefix_tokens]
            batch["targets"] = batch["targets"][
                :, :self.cfg.seq_len - self.arch.prefix_tokens]
        if self.arch is not None and self.arch.encdec:
            rng = np.random.default_rng(self.cfg.seed * 104729 + step)
            batch["frames"] = rng.standard_normal(
                (toks.shape[0], self.arch.enc_frames, self.arch.d_model),
                np.float32)
        return batch

    def _place(self, batch: Dict[str, np.ndarray]) -> Dict[str, Any]:
        if self.mesh.devices.size == 1:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        dpx = dp_axes(self.mesh)
        out = {}
        for k, v in batch.items():
            spec = P(dpx, *([None] * (v.ndim - 1)))
            out[k] = jax.device_put(v, NamedSharding(self.mesh, spec))
        return out

    # -- prefetch thread ------------------------------------------------------

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                batch = self._place(self._build(step))
            except Exception as e:  # surface in the consumer
                self._q.put(e)
                return
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        self.step = item[0] + 1
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
