"""Generate the EXPERIMENTS.md dry-run / roofline tables from the dry-run
JSONs.  Usage: PYTHONPATH=src python scripts/gen_experiments_tables.py"""
import glob
import json
import os

DIR = "experiments/dryrun"


def load(mesh):
    out = {}
    for f in sorted(glob.glob(os.path.join(DIR, f"*_{mesh}.json"))):
        d = json.load(open(f))
        out[(d["arch"], d["shape"])] = d
    return out


def human(x):
    for unit, div in (("P", 1e15), ("T", 1e12), ("G", 1e9), ("M", 1e6)):
        if abs(x) >= div:
            return f"{x / div:.2f}{unit}"
    return f"{x:.0f}"


def main():
    single = load("single")
    multi = load("multi")

    print("### Dry-run matrix (per-device numbers)\n")
    print("| arch | shape | mesh | HLO FLOPs | HBM bytes | wire bytes |"
          " mem/dev | compile |")
    print("|---|---|---|---|---|---|---|---|")
    for (a, s), d in {**{(k, 'single'): v for k, v in single.items()},
                      }.items():
        pass
    for mesh, table in (("single", single), ("multi", multi)):
        for (a, s), d in table.items():
            if d.get("skipped"):
                print(f"| {a} | {s} | {mesh} | — | — | — | — | SKIP |")
                continue
            c = d["cost_analysis"]
            mem = d["memory"].get("total_per_device_bytes", 0) / 2 ** 30
            print(f"| {a} | {s} | {mesh} | {human(c['flops'])} |"
                  f" {human(c['bytes accessed'])} |"
                  f" {human(d['collectives']['total_wire_bytes'])} |"
                  f" {mem:.1f}G | {d['compile_s']}s |")

    print("\n### Roofline terms (single-pod, per device, seconds)\n")
    print("| arch | shape | compute | memory | collective | dominant |"
          " MODEL_FLOPS/HLO | roofline frac | mem/dev |")
    print("|---|---|---|---|---|---|---|---|---|"[:-2])
    for (a, s), d in single.items():
        if d.get("skipped"):
            continue
        r = d["roofline"]
        mem = d["memory"].get("total_per_device_bytes", 0) / 2 ** 30
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = (r["model_flops"] / 197e12) / bound if bound else 0
        print(f"| {a} | {s} | {r['compute_s']:.3f} | {r['memory_s']:.3f} |"
              f" {r['collective_s']:.3f} | {r['dominant']} |"
              f" {r['useful_flops_ratio']:.2f} | {frac:.4f} | {mem:.1f}G |")


if __name__ == "__main__":
    main()
