#!/usr/bin/env python
"""Benchmark-regression gate for CI.

Runs the smoke benchmarks (``fused_epilogue``, ``tpu_matmul``,
``int8_decode``), writes the measured medians to ``BENCH_ci.json`` (CI
uploads it as a workflow artifact), and compares them against the
committed ``BENCH_baseline.json``.

Noise policy (host timing on shared runners is noisy — see the timing
docstrings in benchmarks/):

* every per-benchmark number is a median-of-N with each timed region
  closed by ``block_until_ready``;
* regression ratios are HOST-NORMALIZED: the gate computes
  ``ratio = current_us / baseline_us`` per benchmark and divides by the
  median ratio across ALL benchmarks before applying the tolerance.  A
  runner that is uniformly 3x slower than the machine that seeded the
  baseline shifts every ratio by 3x and the median normalization cancels
  it; a single benchmark regressing relative to its peers sticks out.
* the gate fails only when a benchmark exceeds ``1 + tol`` (default
  tol = 0.25, i.e. >25% regression) BOTH raw and host-normalized: the
  normalized test cancels uniform host speed, the raw test stops one
  noisy peer row from dragging the others over the line.  (Tradeoff,
  chosen deliberately: a regression on a runner that is itself >25%
  faster than the baseline host can hide under the raw test — for a CI
  gate, false alarms are the failure mode that kills trust.)

Correctness invariants carried in the benchmark derived columns
(``bounces=0`` for int8 decode, ``fused_le_unfused`` for the epilogue
rows) fail the gate regardless of timing.

Usage:
    python scripts/bench_gate.py                   # gate vs baseline
    python scripts/bench_gate.py --update-baseline # reseed the baseline
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
from typing import Dict, List, Tuple

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(_ROOT, "BENCH_baseline.json")
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_ci.json")
DEFAULT_TOL = 0.25


def collect() -> Tuple[Dict[str, float], List[str]]:
    """Run the smoke benchmark rows.  Returns ({name: median_us},
    [invariant violations])."""
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    sys.path.insert(0, _ROOT)
    from benchmarks import (flash_attention, fused_epilogue, int8_decode,
                            serve_guard_overhead, serve_throughput,
                            tpu_matmul)

    rows: List[Tuple[str, float, str]] = []
    # one pass of the interleaved fused-vs-unfused sweep (the gate's own
    # cross-run noise control is the normalized-ratio comparison)
    rows += fused_epilogue.fused_vs_unfused_rows(passes=1)
    # the v2 algebra's fusions (two-operand gate, rmsnorm-folded output)
    # carry the same fused_le_unfused timing invariant (WARN below)
    rows += fused_epilogue.v2_epilogue_rows(passes=1)
    # ring_overlap_rows asserts the cross-schedule BITWISE determinism
    # guarantee inside its subprocess (RING_OK) for 'ring', 'bidir_ring'
    # AND the ksharded overlapped-gather path — a hard correctness check
    # the gate must keep running, timing aside
    rows += fused_epilogue.ring_overlap_rows()
    rows += tpu_matmul.rows()
    rows += int8_decode.rows()
    # serve_guard_overhead asserts the hardened decode loop's two claims:
    # identical decode HLO with guards on/off (structural, hard fail) and
    # <2% health-guard overhead per decode step (timing, WARN — same
    # noise policy as fused_le_unfused)
    rows += serve_guard_overhead.rows()
    # serve_throughput drives one mixed workload through the continuous-
    # batching scheduler and the fixed-batch loop; sched_beats_fixed is
    # timing-derived (WARN here, hard fail in the standalone entry point
    # — same noise policy as fused_le_unfused)
    rows += serve_throughput.rows()
    # flash_attention compares the tiled flash-decode against the fixed
    # einsum fallback at serving-scale KV lengths; flash_beats_einsum is
    # timing-derived (WARN here, hard fail in the standalone entry point
    # — same noise policy as sched_beats_fixed)
    rows += flash_attention.rows()

    out: Dict[str, float] = {}
    violations: List[str] = []
    for name, us, derived in rows:
        # prefer the median estimate when the row reports one beside a
        # gating min (fused_epilogue does)
        med = us
        for tok in derived.split(";"):
            if tok.startswith("median_us="):
                med = float(tok.split("=", 1)[1])
        out[name] = med
        if "bounces=" in derived and "bounces=0" not in derived:
            # structural invariant (HLO property, noise-free): hard fail
            violations.append(f"{name}: int8 decode has an fp32 bounce "
                              f"({derived})")
        if "fused_le_unfused=False" in derived:
            # timing-derived: the gate's single pass is noisier than the
            # 3-pass standalone benchmark, so report without failing
            print(f"bench_gate: WARN {name} fused epilogue measured "
                  f"slower than unfused this pass ({derived})")
        if "decode_hlo_unchanged=False" in derived:
            # structural invariant (HLO string equality, noise-free):
            # health guards must never alter the traced decode step
            violations.append(f"{name}: guards changed the decode-step "
                              f"HLO ({derived})")
        if "sched_beats_fixed=False" in derived:
            # timing-derived (same policy as fused_le_unfused): the
            # standalone serve_throughput entry point fails hard on this,
            # the gate's single pass only warns
            print(f"bench_gate: WARN {name} scheduler measured slower "
                  f"than the fixed loop this pass ({derived})")
        if "flash_beats_einsum=False" in derived:
            # timing-derived (same policy as sched_beats_fixed): the
            # standalone flash_attention entry point fails hard on this,
            # the gate's single pass only warns
            print(f"bench_gate: WARN {name} flash decode measured "
                  f"slower than the einsum fallback this pass "
                  f"({derived})")
        if "guard_overhead_lt_2pct=False" in derived:
            # timing-derived (same policy as fused_le_unfused): the
            # standalone benchmark entry point fails hard on this, the
            # gate's single pass only warns
            print(f"bench_gate: WARN {name} health-guard overhead "
                  f"exceeded 2% this pass ({derived})")
    return out, violations


def compare(current: Dict[str, float], baseline: Dict[str, float],
            tol: float = DEFAULT_TOL
            ) -> Tuple[List[str], List[str]]:
    """Pure comparison (unit-tested): returns (failures, report lines).

    A benchmark fails when it exceeds ``1 + tol`` both RAW and
    HOST-NORMALIZED (ratio / median ratio over the common rows): the
    normalized test cancels a uniformly faster/slower host, the raw test
    keeps one contention-hit peer row from inflating everyone else's
    normalized ratio.  New benchmarks pass with a note; benchmarks that
    disappeared fail (a silently dropped benchmark is a coverage
    regression).
    """
    report: List[str] = []
    failures: List[str] = []
    common = sorted(set(current) & set(baseline))
    if not common:
        return (["no benchmarks in common with the baseline"], report)
    ratios = {n: current[n] / max(baseline[n], 1e-9) for n in common}
    srt = sorted(ratios.values())
    med = srt[len(srt) // 2]
    for n in common:
        norm = ratios[n] / max(med, 1e-9)
        line = (f"{n}: {baseline[n]:.1f}us -> {current[n]:.1f}us "
                f"(ratio {ratios[n]:.2f}, host-normalized {norm:.2f})")
        if norm > 1.0 + tol and ratios[n] > 1.0 + tol:
            failures.append(f"REGRESSION {line} exceeds +{tol:.0%}")
            report.append(f"FAIL {line}")
        else:
            report.append(f"ok   {line}")
    for n in sorted(set(current) - set(baseline)):
        report.append(f"new  {n}: {current[n]:.1f}us (no baseline; "
                      f"passes — reseed with --update-baseline)")
    for n in sorted(set(baseline) - set(current)):
        failures.append(f"MISSING benchmark {n} (present in baseline)")
        report.append(f"FAIL {n}: missing from this run")
    report.append(f"host-speed factor vs baseline (median ratio): "
                  f"{med:.2f}")
    return failures, report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL)
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the measured medians to --baseline "
                         "instead of gating against it")
    args = ap.parse_args(argv)

    current, violations = collect()
    payload = {
        "host": {"machine": platform.machine(),
                 "python": platform.python_version(),
                 "cpus": os.cpu_count()},
        "rows_us": current,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"bench_gate: wrote {args.out} ({len(current)} benchmarks)")

    if args.update_baseline:
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"bench_gate: baseline reseeded at {args.baseline}")
        return 0

    for v in violations:
        print(f"bench_gate: INVARIANT {v}")
    if not os.path.exists(args.baseline):
        print(f"bench_gate: no baseline at {args.baseline}; "
              f"run with --update-baseline to seed it")
        return 1 if violations else 0
    with open(args.baseline) as f:
        base = json.load(f)["rows_us"]
    failures, report = compare(current, base, tol=args.tol)
    for line in report:
        print(f"bench_gate: {line}")
    for fline in failures:
        print(f"bench_gate: {fline}")
    if failures or violations:
        return 1
    print(f"bench_gate: PASS ({len(current)} benchmarks within "
          f"+{args.tol:.0%} of the host-normalized baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
