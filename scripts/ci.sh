#!/usr/bin/env bash
# CI entry point, shared verbatim by GitHub Actions and local runs so the
# two can never drift (.github/workflows/ci.yml invokes these subcommands;
# the env vars for every job live HERE, not in the workflow).
#
#   scripts/ci.sh             # everything (tier1 + multidev + bench + robustness + analyze)
#   scripts/ci.sh tier1       # ROADMAP tier-1 pytest suite
#   scripts/ci.sh multidev    # fake-8-device sharded checks
#   scripts/ci.sh bench       # benchmark-regression gate (BENCH_ci.json)
#   scripts/ci.sh robustness  # fault-injection suite + guard-overhead row
#   scripts/ci.sh serve       # paged-scheduler suite + mixed-traffic throughput
#   scripts/ci.sh analyze     # HLO contract auditor vs HLO_CONTRACTS.json
#
# Dependency install is FULLY optional: the suite degrades gracefully
# without the dev extras (property tests fall back to smoke subsets), and
# offline machines must never die on a network call.  Set
# REPRO_SKIP_INSTALL=1 to skip pip entirely.
set -euo pipefail
cd "$(dirname "$0")/.."

install_extras() {
    if [[ "${REPRO_SKIP_INSTALL:-0}" == "1" ]]; then
        echo "ci.sh: REPRO_SKIP_INSTALL=1 -- using the preinstalled environment"
    elif python -m pip install -r requirements-dev.txt; then
        echo "ci.sh: dev extras installed"
    else
        echo "ci.sh: WARN dev extras unavailable (offline?) -- property tests fall back to smoke subsets"
    fi
    # report which optional extras are actually active, so a log reader
    # can tell WHICH flavor of the suite ran.  On a CI runner (network
    # available by definition) missing extras mean a broken requirements
    # pin silently downgrading coverage -- fail loudly there; local and
    # offline runs stay best-effort.
    python - <<'PY'
import importlib.util, os, sys
missing = []
for mod, why in (("hypothesis", "property tests"),
                 ("pytest", "test runner"),
                 ("jax", "required")):
    ok = importlib.util.find_spec(mod) is not None
    print(f"ci.sh: extra {mod:<12} {'active' if ok else 'MISSING':<8} ({why})")
    if not ok:
        missing.append(mod)
if missing and (os.environ.get("CI") or os.environ.get("GITHUB_ACTIONS")):
    sys.exit(f"ci.sh: refusing to run a downgraded suite on CI -- "
             f"missing extras: {missing}")
PY
}

tier1() {
    # exactly as ROADMAP.md specifies
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
}

multidev() {
    # fake-multidevice job: the sharded paths (xyz schedules, ring/bidir
    # collectives, overlapped gather, fused-SP packed QKV, epilogues,
    # grads) must pass on every PR.  Runs in its own process so the
    # tier-1 suite keeps a single jax device.
    JAX_PLATFORMS=cpu XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python tests/_multidev_checks.py
    # full schedule-equivalence property grid (multidev-marked, skipped
    # in tier-1): -v surfaces every per-cell check name for triage, and
    # each test's stdout carries the subprocess's ok equiv[...] lines.
    # The pytest parent process stays single-device: the 8-device flag is
    # set only inside the sweep subprocesses (dry-run isolation rule).
    REPRO_MULTIDEV=1 JAX_PLATFORMS=cpu \
        PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m pytest -m multidev -v -rA tests/test_schedule_equivalence.py
}

bench() {
    # benchmark-regression gate: writes BENCH_ci.json (uploaded as a CI
    # artifact) and fails on >25% host-normalized median regression vs
    # the committed BENCH_baseline.json
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python scripts/bench_gate.py "$@"
}

robustness() {
    # fault-injection suite: every injected fault class (NaN/Inf logits,
    # int8 saturation, checkpoint truncation/bit-flips, host stalls,
    # transient failures) must be recovered or converted to a structured
    # per-request error — the engine itself survives every drill.  Also
    # runs the standalone guard-overhead benchmark, which HARD-fails if
    # the guards change the decode HLO or exceed the 2% step budget
    # (unlike the bench gate's WARN, this run is the dedicated signal).
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m pytest -q tests/test_robustness.py
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python benchmarks/serve_guard_overhead.py
}

serve() {
    # continuous-batching scheduler suite (paged KV allocator, admission/
    # shed/churn isolation, zero-recompile pin, shim bitwise equivalence)
    # plus the standalone mixed-traffic throughput benchmark, which
    # HARD-fails if the scheduler loses to the fixed-batch loop on
    # useful tokens/s (unlike the bench gate's WARN, this run is the
    # dedicated signal).
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m pytest -q tests/test_scheduler.py
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python benchmarks/serve_throughput.py
}

analyze() {
    # HLO contract auditor: trace every registered production path
    # (train step, fp32/int8 prefill+decode, guarded decode, all four
    # collective-matmul schedules), run the static-analysis passes, and
    # diff against the committed HLO_CONTRACTS.json — any contract
    # violation or unexplained structural drift fails.  audit.py forces
    # 8 host devices itself (before jax init); JAX_PLATFORMS keeps the
    # job CPU-only like the multidev job.  The --selftest pass proves
    # the auditor still catches the three seeded regressions.
    JAX_PLATFORMS=cpu PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m repro.launch.audit "$@"
    JAX_PLATFORMS=cpu PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m repro.launch.audit --selftest
}

cmd="${1:-all}"
[[ $# -gt 0 ]] && shift
case "$cmd" in
    tier1)      install_extras; tier1 "$@" ;;
    multidev)   install_extras; multidev ;;
    bench)      install_extras; bench "$@" ;;
    robustness) install_extras; robustness ;;
    serve)      install_extras; serve ;;
    analyze)    install_extras; analyze "$@" ;;
    all)        install_extras; tier1; multidev; bench; robustness; serve; analyze ;;
    *) echo "usage: scripts/ci.sh [tier1|multidev|bench|robustness|serve|analyze|all]" >&2; exit 2 ;;
esac
