#!/usr/bin/env bash
# Tier-1 CI entry point: install dev extras (best effort — the suite
# degrades gracefully without them) and run the test suite exactly as
# ROADMAP.md specifies.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -r requirements-dev.txt || \
    echo "WARN: dev extras unavailable; property tests fall back to smoke subsets"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
