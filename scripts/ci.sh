#!/usr/bin/env bash
# Tier-1 CI entry point: install dev extras (best effort — the suite
# degrades gracefully without them) and run the test suite exactly as
# ROADMAP.md specifies.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -r requirements-dev.txt || \
    echo "WARN: dev extras unavailable; property tests fall back to smoke subsets"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# fake-multidevice job: the sharded paths (xyz schedules, ring collective,
# fused-SP packed QKV, epilogues, grads) must pass on every PR.  Runs in
# its own process so the test suite above keeps a single jax device.
JAX_PLATFORMS=cpu XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python tests/_multidev_checks.py
