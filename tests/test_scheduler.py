"""Continuous-batching scheduler suite: paged KV allocator units, the
typed request API, and the scheduler's core promises —

  * a request's tokens are BITWISE identical whether it decodes alone or
    amid arbitrary neighbor admit/retire churn (page recycling included);
  * after warmup the engine never recompiles, no matter how requests
    come and go (one decode shape, one prefill-chunk shape, one pick);
  * page exhaustion is a load condition: impossible fits shed with a
    structured status, transient exhaustion queues;
  * the ``generate(batch)`` shim is bitwise-equal to the retained
    fixed-batch loop, fp32 and int8.
"""
import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models.lm import Model
from repro.robust.guards import STATUS_OK, STATUS_SHED
from repro.serve.api import Request, RequestOutput, SamplingParams
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.kv_cache import PageAllocator, PagedKVCache

ARCH = "internlm2-1.8b"
PROMPT = 16
NEW = 6


@pytest.fixture(scope="module")
def mesh():
    return make_mesh(1, 1)


@pytest.fixture(scope="module")
def model(mesh):
    return Model(get_config(ARCH, smoke=True), mesh)


@pytest.fixture(scope="module")
def params(model):
    return model.init_params(0)


def _scfg(**kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return ServeConfig(**kw)


@pytest.fixture(scope="module")
def engine(model, params):
    return ServeEngine(model, params, _scfg(
        max_new_tokens=NEW, n_lanes=3, page_size=8, prefill_chunk=8,
        max_seq_len=64))


def _req(model, rid, n=PROMPT, seed0=0, **kw):
    v = model.cfg.vocab
    toks = (np.arange(seed0, seed0 + n) % v).astype(np.int32)
    return Request(id=rid, tokens=toks, **kw)


# ---------------------------------------------------------------------------
# page allocator units
# ---------------------------------------------------------------------------

def test_allocator_alloc_free_roundtrip():
    al = PageAllocator(4)
    a = al.alloc(2)
    b = al.alloc(2)
    assert sorted(a + b) == [0, 1, 2, 3]
    assert al.alloc(1) is None          # exhausted: None, not an exception
    al.free(a)
    assert al.n_free == 2
    c = al.alloc(2)
    assert sorted(c) == sorted(a)       # freed pages recycle


def test_allocator_handles_fragmented_free_list():
    al = PageAllocator(6)
    held = [al.alloc(1) for _ in range(6)]
    # free a non-contiguous subset; a multi-page alloc must still succeed
    for h in (held[0], held[2], held[4]):
        al.free(h)
    got = al.alloc(3)
    assert sorted(got) == sorted(held[0] + held[2] + held[4])


def test_allocator_rejects_double_and_unknown_free():
    al = PageAllocator(2)
    pages = al.alloc(1)
    al.free(pages)
    with pytest.raises(ValueError, match="double free"):
        al.free(pages)
    with pytest.raises(ValueError, match="unknown page"):
        al.free([99])


def test_allocator_validates_args():
    with pytest.raises(ValueError, match="n_pages"):
        PageAllocator(0)
    with pytest.raises(ValueError, match="alloc needs n >= 1"):
        PageAllocator(2).alloc(0)


# ---------------------------------------------------------------------------
# paged KV cache: lane page-table bookkeeping
# ---------------------------------------------------------------------------

def test_kv_cache_admit_release_recycles_pages(model):
    kv = PagedKVCache(model, n_lanes=2, n_pages=4, page_size=8,
                      pages_per_lane=2)
    assert kv.admit(0, total_len=16)    # 2 pages
    first = list(kv.lane_pages[0])
    assert (kv.table[0, :2] >= 0).all() and (kv.table[1] == -1).all()
    # logical order ascending: page p holds positions [p*8, p*8+8)
    assert kv.table[0, 0] == first[0] and kv.table[0, 1] == first[1]
    kv.release(0)
    assert (kv.table[0] == -1).all()
    assert kv.admit(1, total_len=9)     # 2 pages again, recycled
    assert sorted(kv.lane_pages[1]) == sorted(first)


def test_kv_cache_table_device_reuploads_only_on_change(model):
    kv = PagedKVCache(model, n_lanes=2, n_pages=4, page_size=8,
                      pages_per_lane=2)
    t0 = kv.table_device()
    assert kv.table_device() is t0      # steady state: cached array
    kv.admit(0, total_len=8)
    t1 = kv.table_device()
    assert t1 is not t0                 # admission dirtied the table
    assert kv.table_device() is t1


def test_kv_cache_fits_ever_bounds():
    class _NoModel:
        def paged_cache_defs(self, *_):
            return {}
    kv = PagedKVCache.__new__(PagedKVCache)
    kv.page_size, kv.pages_per_lane, kv.n_pages = 8, 2, 100
    assert kv.fits_ever(16)
    assert not kv.fits_ever(17)         # > pages_per_lane * page_size


# ---------------------------------------------------------------------------
# typed API validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kwargs,msg", [
    (dict(max_new_tokens=0), "max_new_tokens"),
    (dict(temperature=-0.5), "temperature"),
    (dict(temperature=float("nan")), "temperature"),
    (dict(eos_id=-1), "eos_id"),
])
def test_sampling_params_rejects_bad_values(kwargs, msg):
    with pytest.raises(ValueError, match=msg):
        SamplingParams(**kwargs)


def test_request_validates_tokens():
    with pytest.raises(ValueError, match="non-empty 1-D"):
        Request(id=0, tokens=np.zeros((0,), np.int32))
    with pytest.raises(ValueError, match="non-empty 1-D"):
        Request(id=0, tokens=np.zeros((2, 2), np.int32))
    with pytest.raises(ValueError, match="integer ids"):
        Request(id=0, tokens=np.zeros((4,), np.float32))
    r = Request(id=0, tokens=np.arange(4, dtype=np.int64))
    assert r.tokens.dtype == np.int32   # coerced


def test_serve_config_sampling_fields_warn_deprecated():
    with pytest.warns(DeprecationWarning, match="max_new_tokens"):
        ServeConfig(max_new_tokens=7)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ServeConfig()                   # defaults: silent


@pytest.mark.parametrize("kwargs,msg", [
    (dict(n_lanes=0), "n_lanes"),
    (dict(page_size=0), "page_size"),
    (dict(prefill_chunk=0), "prefill_chunk"),
    (dict(max_seq_len=1), "max_seq_len"),
    (dict(n_pages=0), "n_pages"),
])
def test_serve_config_rejects_bad_paged_geometry(kwargs, msg):
    with pytest.raises(ValueError, match=msg):
        ServeConfig(**kwargs)


def test_sampling_defaults_inherit_deprecated_fields():
    sp = _scfg(max_new_tokens=9, greedy=False,
               temperature=0.7).sampling_defaults()
    assert sp == SamplingParams(greedy=False, temperature=0.7,
                                max_new_tokens=9, eos_id=None)


# ---------------------------------------------------------------------------
# scheduler: admission / shed / churn / recompilation
# ---------------------------------------------------------------------------

def test_submit_step_collect_roundtrip(model, engine):
    engine.submit(_req(model, "a"))
    engine.submit(_req(model, "b", seed0=3))
    outs = {}
    while engine.pending:
        for o in engine.step():
            pass
    for o in engine.collect():
        outs[o.id] = o
    assert set(outs) == {"a", "b"}
    for o in outs.values():
        assert o.status == STATUS_OK and o.fault_step == -1
        assert o.tokens.shape == (NEW,) and o.prompt_len == PROMPT
        assert o.n_steps == NEW


def test_impossible_fit_sheds_structured(model, engine):
    # prompt + max_new > max_seq_len(64): can NEVER fit a lane
    engine.submit(_req(model, "big", n=70))
    (o,) = engine.drain()
    assert o.id == "big" and o.status == STATUS_SHED
    assert o.fault_step == -1 and o.tokens.size == 0 and o.n_steps == 0


def test_transient_page_exhaustion_queues_not_crashes(model, params):
    # pool of 4 pages x 8 positions; each request needs 3 pages, so the
    # second must WAIT for the first to retire, not shed and not crash
    eng = ServeEngine(model, params, _scfg(
        max_new_tokens=NEW, n_lanes=2, page_size=8, max_seq_len=24,
        n_pages=4, prefill_chunk=8))
    eng.submit(_req(model, "a"))
    eng.submit(_req(model, "b", seed0=5))
    outs = {o.id: o for o in eng.drain()}
    assert outs["a"].status == STATUS_OK
    assert outs["b"].status == STATUS_OK
    assert outs["b"].tokens.shape == (NEW,)


def test_request_tokens_bitwise_stable_under_churn(model, params):
    """The paged-isolation core claim: a request's tokens are identical
    alone vs amid neighbors admitting and retiring around it (page
    recycling, staggered prefills, different physical page ids)."""
    eng = ServeEngine(model, params, _scfg(
        max_new_tokens=NEW, n_lanes=3, page_size=8, prefill_chunk=8,
        max_seq_len=64))
    probe = _req(model, "probe", n=PROMPT, seed0=7,
                 sampling=SamplingParams(max_new_tokens=12))
    eng.submit(probe)
    alone = {o.id: o for o in eng.drain()}["probe"]

    # churn: re-submit the probe amid short neighbors with varied prompt
    # lengths and budgets that admit/retire repeatedly around it
    eng.submit(_req(model, "n0", n=11, seed0=1,
                    sampling=SamplingParams(max_new_tokens=2)))
    eng.submit(probe)
    eng.submit(_req(model, "n1", n=23, seed0=2,
                    sampling=SamplingParams(max_new_tokens=3)))
    eng.submit(_req(model, "n2", n=5, seed0=3,
                    sampling=SamplingParams(max_new_tokens=4)))
    eng.submit(_req(model, "n3", n=17, seed0=4,
                    sampling=SamplingParams(max_new_tokens=2)))
    churned = {o.id: o for o in eng.drain()}
    assert len(churned) == 5
    assert all(o.status == STATUS_OK for o in churned.values())
    np.testing.assert_array_equal(churned["probe"].tokens, alone.tokens)


def test_zero_recompilation_after_warmup_under_churn(model, params):
    eng = ServeEngine(model, params, _scfg(
        max_new_tokens=NEW, n_lanes=3, page_size=8, prefill_chunk=8,
        max_seq_len=64))
    # warmup: one drain that exercises prefill, decode and pick
    eng.submit(_req(model, "w0"))
    eng.submit(_req(model, "w1", n=20, seed0=2))
    eng.drain()
    warm = eng.jit_cache_sizes()
    assert warm["decode_paged"] == 1    # ONE decode shape per engine
    assert warm["prefill_chunk"] == 1
    # churn: many admit/retire cycles with varied prompts and budgets
    for i in range(7):
        eng.submit(_req(model, f"c{i}", n=5 + 7 * (i % 4), seed0=i,
                        sampling=SamplingParams(
                            max_new_tokens=1 + (i % 5))))
    outs = eng.drain()
    assert len(outs) == 7
    assert eng.jit_cache_sizes() == warm   # zero recompiles under churn


def test_per_request_sampling_params(model, params):
    eng = ServeEngine(model, params, _scfg(
        max_new_tokens=NEW, n_lanes=2, page_size=8, prefill_chunk=8,
        max_seq_len=64))
    eng.submit(_req(model, "short",
                    sampling=SamplingParams(max_new_tokens=2)))
    eng.submit(_req(model, "samp", seed0=3, seed=11,
                    sampling=SamplingParams(greedy=False,
                                            temperature=0.8,
                                            max_new_tokens=5)))
    outs = {o.id: o for o in eng.drain()}
    assert outs["short"].tokens.shape == (2,)
    assert outs["samp"].tokens.shape == (5,)
    # the sampled request's key stream is rooted at ITS seed: the same
    # submission replays bitwise even though the lane mix changed
    eng.submit(_req(model, "samp2", seed0=3, seed=11,
                    sampling=SamplingParams(greedy=False,
                                            temperature=0.8,
                                            max_new_tokens=5)))
    (replay,) = eng.drain()
    np.testing.assert_array_equal(replay.tokens, outs["samp"].tokens)


def test_eos_stops_request_early(model, params):
    eng = ServeEngine(model, params, _scfg(
        max_new_tokens=NEW, n_lanes=2, page_size=8, prefill_chunk=8,
        max_seq_len=64))
    eng.submit(_req(model, "free"))
    (free,) = eng.drain()
    stop = int(free.tokens[2])          # the token it will emit at step 2
    eng.submit(_req(model, "stopped",
                    sampling=SamplingParams(max_new_tokens=NEW,
                                            eos_id=stop)))
    (got,) = eng.drain()
    assert got.status == STATUS_OK
    # stops AT the first emission of the eos token (which may repeat in
    # the free-running stream before step 2)
    idx = int(np.argmax(free.tokens == stop))
    assert got.tokens.shape == (idx + 1,)
    np.testing.assert_array_equal(got.tokens, free.tokens[:idx + 1])


def test_chunked_prefill_matches_single_chunk(model, params):
    """A prompt spanning several chunks must produce the same tokens as
    the same prompt prefilled in one chunk — write-then-attend chunk math
    is position-exact."""
    one = ServeEngine(model, params, _scfg(
        max_new_tokens=NEW, n_lanes=2, page_size=8, prefill_chunk=64,
        max_seq_len=64))
    many = ServeEngine(model, params, _scfg(
        max_new_tokens=NEW, n_lanes=2, page_size=8, prefill_chunk=8,
        max_seq_len=64))
    req = _req(model, "x", n=29, seed0=4)
    one.submit(req)
    many.submit(req)
    (a,) = one.drain()
    (b,) = many.drain()
    np.testing.assert_array_equal(a.tokens, b.tokens)


# ---------------------------------------------------------------------------
# generate(batch) shim vs the retained fixed-batch loop
# ---------------------------------------------------------------------------

def _batch(model, b=3):
    v = model.cfg.vocab
    return {"tokens": (jnp.arange(b * PROMPT, dtype=jnp.int32)
                       .reshape(b, PROMPT) % v)}


def test_shim_bitwise_equals_fixed_loop_fp32(model, params):
    eng = ServeEngine(model, params, _scfg(max_new_tokens=NEW))
    p = _batch(model)
    shim = eng.generate_with_status(p)
    fixed = eng.generate_with_status_fixed(p)
    np.testing.assert_array_equal(shim.tokens, fixed.tokens)
    assert shim.status == fixed.status
    np.testing.assert_array_equal(shim.fault_step, fixed.fault_step)
    assert shim.n_steps == fixed.n_steps


def test_shim_bitwise_equals_fixed_loop_int8(model, params):
    eng = ServeEngine(model, params, _scfg(max_new_tokens=NEW, int8=True))
    p = _batch(model)
    shim = eng.generate_with_status(p)
    fixed = eng.generate_with_status_fixed(p)
    np.testing.assert_array_equal(shim.tokens, fixed.tokens)
    assert shim.status == fixed.status


def test_shed_lanes_report_minus_one_fault_step(model, params):
    """Regression: shed lanes used to report ``fault_step = 0`` (the
    np.zeros fill), claiming a step-0 fault; the documented sentinel for
    a lane that never ran is -1 — on BOTH serving paths."""
    eng = ServeEngine(model, params,
                      _scfg(max_new_tokens=NEW, max_lanes=2))
    p = _batch(model, b=4)
    for res in (eng.generate_with_status(p),
                eng.generate_with_status_fixed(p)):
        assert res.status[2:] == [STATUS_SHED, STATUS_SHED]
        assert (res.fault_step[2:] == -1).all()
        assert (res.fault_step[:2] == -1).all()
        assert res.admitted == 2


def test_fixed_loop_unavailable_models_reject_submit(model, params):
    eng = ServeEngine(model, params, _scfg(max_new_tokens=NEW))
    assert model.supports_paged_serving
    # simulate a non-paged family (the gate, not the model, is under test)
    eng._paged_ok = False
    with pytest.raises(NotImplementedError, match="paged serving"):
        eng.submit(_req(model, "x"))


# ---------------------------------------------------------------------------
# page-pool conservation: failed admissions never leak pages
# ---------------------------------------------------------------------------

def test_admit_failure_modes_leave_pool_intact(model):
    """Regression for the admission page leak: ``admit`` used to call
    the allocator first and die writing the page-table row, stranding
    the whole allocation.  Every failure mode — unservable width,
    zero length, transient exhaustion — must leave ``n_free`` exactly
    where it was."""
    kv = PagedKVCache(model, n_lanes=3, n_pages=4, page_size=8,
                      pages_per_lane=2)
    n0 = kv.allocator.n_free
    # unservable: wider than a lane's page-table row
    with pytest.raises(ValueError, match="unservable"):
        kv.admit(0, total_len=17)
    assert kv.allocator.n_free == n0
    # unservable: zero-length request
    with pytest.raises(ValueError, match="unservable"):
        kv.admit(0, total_len=0)
    assert kv.allocator.n_free == n0
    # transient exhaustion: neighbors drained the pool
    assert kv.admit(0, total_len=16)
    assert kv.admit(1, total_len=9)
    assert kv.allocator.n_free == 0
    assert not kv.admit(2, total_len=8)
    assert kv.allocator.n_free == 0
    kv.release(0)
    kv.release(1)
    assert kv.allocator.n_free == n0


def test_page_pool_conserved_under_randomized_churn(model):
    """Randomized admit/release churn — forced exhaustion, over-wide
    and zero-length admissions included — conserves the page pool: at
    every point ``n_free`` equals the initial count minus the pages the
    live lanes hold, and after releasing everything it returns EXACTLY
    to the initial count.  Any leak anywhere shows up here."""
    kv = PagedKVCache(model, n_lanes=4, n_pages=6, page_size=8,
                      pages_per_lane=3)
    n0 = kv.allocator.n_free
    rng = np.random.default_rng(1234)
    held = {}                            # lane -> pages it owns
    saw_exhaustion = saw_unservable = False
    for _ in range(400):
        lane = int(rng.integers(0, 4))
        if lane in held:
            kv.release(lane)
            del held[lane]
        else:
            total = int(rng.integers(-3, 32))
            free_before = kv.allocator.n_free
            if not kv.fits_ever(total):
                saw_unservable = True
                with pytest.raises(ValueError, match="unservable"):
                    kv.admit(lane, total)
                assert kv.allocator.n_free == free_before
            elif kv.admit(lane, total):
                held[lane] = kv.pages_needed(total)
            else:
                saw_exhaustion = True
                assert kv.allocator.n_free == free_before
        assert kv.allocator.n_free == n0 - sum(held.values())
    assert saw_exhaustion and saw_unservable   # the sweep hit both modes
    for lane in list(held):
        kv.release(lane)
    assert kv.allocator.n_free == n0


# ---------------------------------------------------------------------------
# zero-length requests: structured shed, never a crash
# ---------------------------------------------------------------------------

def test_zero_length_bookkeeping_rejected_structurally(model):
    kv = PagedKVCache(model, n_lanes=2, n_pages=4, page_size=8,
                      pages_per_lane=2)
    with pytest.raises(ValueError, match="total_len"):
        kv.pages_needed(0)
    with pytest.raises(ValueError, match="total_len"):
        kv.pages_needed(-1)
    assert not kv.fits_ever(0)
    assert not kv.fits_ever(-1)


def test_scheduler_sheds_zero_length_request(model, engine):
    """A zero-length request that bypasses the typed-API validation
    (``Request`` itself rejects empty prompts) must come back as a
    structured STATUS_SHED, not a ceil-div/alloc(0) crash inside
    ``pages_needed``/``admit``."""
    import types
    req = types.SimpleNamespace(
        id="empty", tokens=np.zeros((0,), np.int32),
        sampling=types.SimpleNamespace(max_new_tokens=0), seed=0)
    engine.submit(req)
    (o,) = engine.drain()
    assert o.id == "empty" and o.status == STATUS_SHED
    assert o.fault_step == -1 and o.tokens.size == 0
    assert o.n_steps == 0 and o.prompt_len == 0


def test_shims_shed_zero_length_batch(model, params):
    """Both serving shims — paged and the retained fixed loop — shed a
    ``(B, 0)`` token batch structurally instead of crashing at prefill."""
    eng = ServeEngine(model, params, _scfg(max_new_tokens=NEW))
    p = {"tokens": jnp.zeros((3, 0), jnp.int32)}
    for res in (eng.generate_with_status(p),
                eng.generate_with_status_fixed(p)):
        assert res.tokens.shape == (3, 0)
        assert res.status == [STATUS_SHED] * 3
        assert (res.fault_step == -1).all()
        assert res.n_steps == 0 and res.admitted == 0
