"""Shared pytest configuration.

Registers the ``multidev`` marker: tests carrying it drive the
8-fake-device schedule-equivalence sweeps through subprocesses and are
collected-but-skipped in the tier-1 run (they would roughly double its
wall clock).  The CI multidev job enables them by exporting
``REPRO_MULTIDEV=1`` and running ``pytest -m multidev -v`` (see
``scripts/ci.sh multidev``), which also surfaces every per-check name in
the log for triage.
"""
import os

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidev: full 8-fake-device sweep; enabled by REPRO_MULTIDEV=1 "
        "(run via scripts/ci.sh multidev)")


def pytest_collection_modifyitems(config, items):
    if os.environ.get("REPRO_MULTIDEV"):
        return
    skip = pytest.mark.skip(
        reason="multidev sweep: set REPRO_MULTIDEV=1 (scripts/ci.sh "
               "multidev runs it)")
    for item in items:
        if "multidev" in item.keywords:
            item.add_marker(skip)
