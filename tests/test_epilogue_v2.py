"""Epilogue algebra v2: two-operand gate and rmsnorm-fused outputs.

Property-style coverage of the v2 stages against the f64-capable oracle
(``ref.matmul_fused_ref`` with float64 inputs keeps the whole chain —
dot AND epilogue — at f64), plus the bitwise composition contracts:

  * gate      fused silu(g) * u == the unfused sequence, bit for bit on
              the XLA path; within an eps-derived budget of the f64
              oracle in BOTH kernel modes (xla / interpret), including
              non-divisible blocks, 1-column tiles and single-row gates.
  * norm      the value output is bitwise the plain cast GEMM, and the
              normed output is bitwise ``models.layers.rmsnorm(value)``
              — fusing deletes the HBM round trip, never a bit.
  * int8      the gated up-GEMM's fused ``(q, scale)`` handoff is exact
              across kernel modes (integer accumulation has no rounding).

Spec validation (``Epilogue.__post_init__`` raises ``ValueError``, not
``assert``, so invalid specs die under ``python -O`` too) and the
planner's v2 HBM accounting ride along.  Gradients flow through both new
stages on the XLA path (Pallas has no VJP).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.epilogue import Epilogue, apply_epilogue
from repro.models.layers import rmsnorm

# ---------------------------------------------------------------------------
# satellite: spec validation (ValueError, one test per rejection)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kwargs,match", [
    (dict(activation="tanh"), "activation"),
    (dict(gate="swish"), "gate"),
    (dict(norm="layernorm"), "norm"),
    (dict(quantize_axis="tile"), "quantize_axis"),
    (dict(quantize=True, norm="rmsnorm"), "mutually"),
    (dict(norm_eps=0.0), "norm_eps"),
    (dict(norm_eps=-1e-6), "norm_eps"),
], ids=["bad-act", "bad-gate", "bad-norm", "bad-qaxis", "q-and-norm",
        "eps-zero", "eps-negative"])
def test_epilogue_spec_rejections(kwargs, match):
    with pytest.raises(ValueError, match=match):
        Epilogue(**kwargs)


def test_epilogue_spec_valid_v2_fields():
    """The v2 fields round-trip through the frozen dataclass."""
    ep = Epilogue(gate="silu", quantize=True)
    assert ep.n_outputs == 2 and ep.out_itemsize() == 1
    ep = Epilogue(residual=True, norm="rmsnorm", norm_eps=1e-5,
                  out_dtype=jnp.bfloat16)
    assert ep.n_outputs == 2 and ep.out_itemsize() == 2
    assert not Epilogue(gate="mul").is_identity
    assert not Epilogue(norm="rmsnorm").is_identity


# ---------------------------------------------------------------------------
# shared operands / budgets
# ---------------------------------------------------------------------------

# edge shapes: non-divisible blocks (the 32-block kernel pads every axis),
# 1-column output tiles, single-row gates, and a K smaller than the block
EDGE_SHAPES = [(32, 32, 32), (7, 13, 129), (1, 5, 1), (33, 64, 31),
               (100, 130, 70)]

GATE_EPILOGUES = [
    Epilogue(gate="silu"),
    Epilogue(gate="mul"),
    Epilogue(gate="gelu", bias=True),
    Epilogue(gate="silu", residual=True),
    Epilogue(gate="silu", out_dtype=jnp.bfloat16),
    Epilogue(activation="relu", gate="silu", residual=True),
]
_GATE_IDS = ["silu", "mul", "gelu+b", "silu+r", "silu+cast", "relu+silu+r"]

NORM_EPILOGUES = [
    Epilogue(norm="rmsnorm"),
    Epilogue(residual=True, norm="rmsnorm"),
    Epilogue(residual=True, norm="rmsnorm", out_dtype=jnp.bfloat16),
    Epilogue(bias=True, norm="rmsnorm", norm_eps=1e-5),
]
_NORM_IDS = ["n", "r+n", "r+n+cast", "b+n+eps"]


def _operands(m, k, n, seed=0, dtype=jnp.float32):
    ka, kb, kc, kd, kg, kn = jax.random.split(jax.random.PRNGKey(seed), 6)
    a = jax.random.normal(ka, (m, k), dtype)
    b = jax.random.normal(kb, (k, n), dtype) / np.sqrt(k)
    bias = jax.random.normal(kc, (n,), jnp.float32)
    res = jax.random.normal(kd, (m, n), jnp.float32)
    op2 = jax.random.normal(kg, (m, n), jnp.float32)
    nsc = jax.random.normal(kn, (n,), jnp.float32) * 0.1
    return a, b, bias, res, op2, nsc


def _kw(ep, bias, res, op2, nsc):
    return dict(bias=bias if ep.bias else None,
                residual=res if ep.residual else None,
                operand2=op2 if ep.gate != "none" else None,
                norm_scale=nsc if ep.norm != "none" else None)


def _oracle_f64(a, b, ep, **kw):
    """The f64 oracle: same spec, every operand upcast to float64, so the
    dot and the whole epilogue chain run at f64 (no hand-tuned ref)."""
    from jax.experimental import enable_x64
    ep64 = Epilogue(**{**{f.name: getattr(ep, f.name)
                          for f in Epilogue.__dataclass_fields__.values()},
                       "out_dtype": jnp.float64})
    with enable_x64():
        up = {k: (None if v is None
                  else jnp.asarray(np.asarray(v, np.float64)))
              for k, v in kw.items()}
        out = ref.matmul_fused_ref(
            jnp.asarray(np.asarray(a, np.float64)),
            jnp.asarray(np.asarray(b, np.float64)), ep64, **up)
        if isinstance(out, tuple):
            return tuple(np.asarray(o, np.float64) for o in out)
        return np.asarray(out, np.float64)


def _f64_budget(k, want64):
    """eps-derived fp32 budget: accumulation + epilogue rounding, scaled
    to the oracle's magnitude — nothing hand-tuned per shape."""
    scale = max(1.0, float(np.max(np.abs(want64))))
    return 64 * np.finfo(np.float32).eps * np.sqrt(max(k, 2)) * scale


def _assert_close_f64(got, want64, k, bf16=False, tag=""):
    tol = _f64_budget(k, want64)
    if bf16:
        tol = max(tol, 1.5 * float(np.max(np.abs(want64)))
                  * np.finfo(np.float32).eps * 2 ** 16)
    err = float(np.max(np.abs(np.asarray(got, np.float64) - want64)))
    assert err <= tol, f"{tag}: err={err:.3e} > budget={tol:.3e}"


# ---------------------------------------------------------------------------
# gate stage vs the f64 oracle, both kernel modes, edge shapes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ep", GATE_EPILOGUES, ids=_GATE_IDS)
@pytest.mark.parametrize("mkn", EDGE_SHAPES)
def test_gate_epilogue_vs_f64_oracle_xla(mkn, ep):
    m, k, n = mkn
    a, b, bias, res, op2, nsc = _operands(m, k, n, seed=m + n)
    kw = _kw(ep, bias, res, op2, nsc)
    got = ops.matmul(a, b, mode="xla", epilogue=ep, **kw)
    want64 = _oracle_f64(a, b, ep, **kw)
    _assert_close_f64(got, want64, k, bf16=ep.out_dtype == jnp.bfloat16,
                      tag=f"xla {mkn}")


@pytest.mark.parametrize("ep", GATE_EPILOGUES, ids=_GATE_IDS)
@pytest.mark.parametrize("mkn", EDGE_SHAPES)
def test_gate_epilogue_vs_f64_oracle_interpret(mkn, ep):
    """The Pallas store phase (interpret mode; padded, non-divisible
    tiles) lands inside the same eps budget of the f64 oracle."""
    m, k, n = mkn
    a, b, bias, res, op2, nsc = _operands(m, k, n, seed=m + n)
    kw = _kw(ep, bias, res, op2, nsc)
    got = ops.matmul(a, b, block=(32, 32, 32), mode="interpret",
                     epilogue=ep, **kw)
    want64 = _oracle_f64(a, b, ep, **kw)
    _assert_close_f64(got, want64, k, bf16=ep.out_dtype == jnp.bfloat16,
                      tag=f"interpret {mkn}")


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
def test_gate_epilogue_random_shape_sweep(seed):
    """Seeded random-shape property sweep (hypothesis is unavailable in
    this image): xla and interpret agree and both track the f64 oracle."""
    rng = np.random.RandomState(1000 + seed)
    m = int(rng.randint(1, 70))
    k = int(rng.randint(1, 150))
    n = int(rng.randint(1, 150))
    ep = GATE_EPILOGUES[seed % len(GATE_EPILOGUES)]
    a, b, bias, res, op2, nsc = _operands(m, k, n, seed=seed)
    kw = _kw(ep, bias, res, op2, nsc)
    x = ops.matmul(a, b, mode="xla", epilogue=ep, **kw)
    p = ops.matmul(a, b, block=(32, 32, 32), mode="interpret",
                   epilogue=ep, **kw)
    want64 = _oracle_f64(a, b, ep, **kw)
    shape = (m, k, n)
    bf16 = ep.out_dtype == jnp.bfloat16
    _assert_close_f64(x, want64, k, bf16=bf16, tag=f"xla {shape}")
    _assert_close_f64(p, want64, k, bf16=bf16, tag=f"interpret {shape}")


def test_gate_fused_equals_unfused_sequence_xla():
    """On the XLA path fusion only moves op boundaries: the fused gate ==
    plain GEMM -> apply_epilogue, bit for bit."""
    a, b, bias, res, op2, nsc = _operands(64, 96, 128, seed=5)
    for ep in GATE_EPILOGUES:
        kw = _kw(ep, bias, res, op2, nsc)
        fused = ops.matmul(a, b, mode="xla", epilogue=ep, **kw)
        acc = ops.matmul(a, b, mode="xla")
        unfused = apply_epilogue(acc, ep, **kw)
        np.testing.assert_array_equal(np.asarray(fused, np.float32),
                                      np.asarray(unfused, np.float32))


# ---------------------------------------------------------------------------
# rmsnorm stage: bitwise composition + f64 oracle, both kernel modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ep", NORM_EPILOGUES, ids=_NORM_IDS)
def test_norm_value_and_normed_bitwise_composition(ep):
    """The two-output contract: ``value`` is bitwise the same GEMM
    without the norm stage, and ``normed`` is bitwise
    ``models.layers.rmsnorm(value)`` — the fold deletes the residual
    stream's HBM round trip without changing one bit."""
    import dataclasses
    a, b, bias, res, op2, nsc = _operands(48, 64, 40, seed=9)
    kw = _kw(ep, bias, res, op2, nsc)
    value, normed = ops.matmul(a, b, mode="xla", epilogue=ep, **kw)
    plain_ep = dataclasses.replace(ep, norm="none")
    plain = ops.matmul(a, b, mode="xla", epilogue=plain_ep,
                       **{**kw, "norm_scale": None})
    np.testing.assert_array_equal(np.asarray(value, np.float32),
                                  np.asarray(plain, np.float32))
    renormed = rmsnorm(value, nsc, ep.norm_eps)
    np.testing.assert_array_equal(np.asarray(normed, np.float32),
                                  np.asarray(renormed, np.float32))


@pytest.mark.parametrize("ep", NORM_EPILOGUES, ids=_NORM_IDS)
@pytest.mark.parametrize("mkn", EDGE_SHAPES)
def test_norm_epilogue_vs_f64_oracle_both_modes(mkn, ep):
    """Both outputs track the f64 oracle on edge shapes in both kernel
    modes (interpret pads the N tile; norm_n keeps the mean exact)."""
    m, k, n = mkn
    a, b, bias, res, op2, nsc = _operands(m, k, n, seed=m * 3 + n)
    kw = _kw(ep, bias, res, op2, nsc)
    want_v, want_n = _oracle_f64(a, b, ep, **kw)
    bf16 = ep.out_dtype == jnp.bfloat16
    for mode in ("xla", "interpret"):
        mkw = dict(kw)
        if mode == "interpret":
            mkw["block"] = (32, 32, 32)
        got_v, got_n = ops.matmul(a, b, mode=mode, epilogue=ep, **mkw)
        _assert_close_f64(got_v, want_v, k, bf16=bf16,
                          tag=f"{mode} value {mkn}")
        # the normed output divides by rms ~ O(1); same budget class,
        # with one extra reduction over n folded in
        _assert_close_f64(got_n, want_n, k + n, bf16=bf16,
                          tag=f"{mode} normed {mkn}")


# ---------------------------------------------------------------------------
# int8: the gated up-GEMM's fused (q, scale) handoff
# ---------------------------------------------------------------------------


def test_int8_gate_quantize_handoff_exact_across_modes():
    """silu(g) * (sa * sb * int32 acc) -> rowwise (q, scale): the fused
    handoff the int8 gated MLP feeds to the down GEMM.  Integer
    accumulation has no rounding, so xla and interpret agree exactly on
    q; scales are f32-identical math."""
    ka, kb, kg = jax.random.split(jax.random.PRNGKey(0), 3)
    a = jax.random.normal(ka, (64, 96), jnp.float32)
    b = jax.random.normal(kb, (96, 64), jnp.float32) / np.sqrt(96)
    g = jax.random.normal(kg, (64, 64), jnp.float32)
    qa, sa = ref.quantize_rowwise_ref(a)
    qb, sb = ref.quantize_colwise_ref(b)
    ep = Epilogue(gate="silu", quantize=True)
    qx, sx = ops.int8_matmul(qa, sa, qb, sb, mode="xla", epilogue=ep,
                             operand2=g)
    qi, si = ops.int8_matmul(qa, sa, qb, sb, block=(32, 32, 32),
                             mode="interpret", epilogue=ep, operand2=g)
    qr, sr = ref.int8_matmul_ref(qa, sa, qb, sb, ep, operand2=g)
    assert qx.dtype == jnp.int8 and sx.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(qx), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sx), np.asarray(sr), rtol=1e-6)
    dq = np.abs(np.asarray(qi, np.int32) - np.asarray(qr, np.int32))
    assert dq.max() <= 1, dq.max()  # tile-order rounding: one q step max
    np.testing.assert_allclose(np.asarray(si), np.asarray(sr), rtol=1e-5)


def test_int8_norm_fold_matches_ref():
    """The int8 down GEMM's residual + rmsnorm fold (the serving path's
    block boundary) against the shared-epilogue int8 oracle."""
    ka, kb, kr = jax.random.split(jax.random.PRNGKey(3), 3)
    a = jax.random.normal(ka, (32, 64), jnp.float32)
    b = jax.random.normal(kb, (64, 48), jnp.float32) / 8.0
    res = jax.random.normal(kr, (32, 48), jnp.float32)
    nsc = jnp.linspace(-0.1, 0.1, 48, dtype=jnp.float32)
    qa, sa = ref.quantize_rowwise_ref(a)
    qb, sb = ref.quantize_colwise_ref(b)
    ep = Epilogue(residual=True, norm="rmsnorm", out_dtype=jnp.bfloat16)
    got_v, got_n = ops.int8_matmul(qa, sa, qb, sb, mode="xla",
                                   epilogue=ep, residual=res,
                                   norm_scale=nsc)
    want_v, want_n = ref.int8_matmul_ref(qa, sa, qb, sb, ep, residual=res,
                                         norm_scale=nsc)
    np.testing.assert_array_equal(np.asarray(got_v, np.float32),
                                  np.asarray(want_v, np.float32))
    np.testing.assert_array_equal(np.asarray(got_n, np.float32),
                                  np.asarray(want_n, np.float32))
    # and the normed output is bitwise the standalone-norm composition
    renormed = rmsnorm(got_v, nsc, ep.norm_eps)
    np.testing.assert_array_equal(np.asarray(got_n, np.float32),
                                  np.asarray(renormed, np.float32))


# ---------------------------------------------------------------------------
# gradients (XLA path: Pallas has no VJP)
# ---------------------------------------------------------------------------


def test_gate_epilogue_gradients_match_unfused():
    """d/d{a, b, g, res} of the fused gate == the unfused composition."""
    a, b, bias, res, op2, nsc = _operands(24, 32, 16, seed=7)
    ep = Epilogue(gate="silu", residual=True)

    def loss_fused(a, b, g, res):
        out = ops.matmul(a, b, mode="xla", epilogue=ep, operand2=g,
                         residual=res)
        return jnp.sum(jnp.sin(out))

    def loss_unfused(a, b, g, res):
        acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
        return jnp.sum(jnp.sin(jax.nn.silu(g) * acc + res))

    gf = jax.grad(loss_fused, argnums=(0, 1, 2, 3))(a, b, op2, res)
    gu = jax.grad(loss_unfused, argnums=(0, 1, 2, 3))(a, b, op2, res)
    for got, want in zip(gf, gu):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def test_norm_epilogue_gradients_match_unfused():
    """Grads flow through BOTH outputs of the norm fold and match the
    store-then-rmsnorm composition."""
    a, b, bias, res, op2, nsc = _operands(16, 24, 20, seed=11)
    ep = Epilogue(residual=True, norm="rmsnorm")

    def loss_fused(a, b, res, nsc):
        value, normed = ops.matmul(a, b, mode="xla", epilogue=ep,
                                   residual=res, norm_scale=nsc)
        return jnp.sum(jnp.sin(normed)) + jnp.sum(jnp.cos(value))

    def loss_unfused(a, b, res, nsc):
        value = jnp.dot(a, b, preferred_element_type=jnp.float32) + res
        normed = rmsnorm(value, nsc, ep.norm_eps)
        return jnp.sum(jnp.sin(normed)) + jnp.sum(jnp.cos(value))

    gf = jax.grad(loss_fused, argnums=(0, 1, 2, 3))(a, b, res, nsc)
    gu = jax.grad(loss_unfused, argnums=(0, 1, 2, 3))(a, b, res, nsc)
    for got, want in zip(gf, gu):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# planner accounting for the v2 stages
# ---------------------------------------------------------------------------


def test_planner_gate_and_norm_accounting():
    from repro.core.perf_model import fused_epilogue_savings
    from repro.core.planner import epilogue_hbm_bytes
    m, n = 4096, 14336
    # gate: the g read is paid either way; unfused also re-reads the
    # output and re-writes the product (one extra elementwise pass)
    ep = Epilogue(gate="silu", out_dtype=jnp.bfloat16)
    item = ep.out_itemsize()
    fused = epilogue_hbm_bytes(m, n, ep, fused=True)
    unfused = epilogue_hbm_bytes(m, n, ep, fused=False)
    assert fused == m * n * item + m * n * item  # out + g operand
    assert unfused - fused == 2 * 4 * m * n + 2 * m * n * item
    # norm: second [m, n] output + [n] scale either way; unfused adds the
    # residual stream's standalone read + write
    ep = Epilogue(residual=True, norm="rmsnorm", out_dtype=jnp.bfloat16)
    fused = epilogue_hbm_bytes(m, n, ep, fused=True)
    unfused = epilogue_hbm_bytes(m, n, ep, fused=False)
    assert fused == 3 * m * n * item + 4 * n  # value + normed + residual
    assert unfused - fused == 2 * 4 * m * n + 2 * m * n * item
    sav = fused_epilogue_savings(m, n, ep)
    assert sav["bytes_saved"] == unfused - fused
    assert sav["seconds_saved"] > 0
