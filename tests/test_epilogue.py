"""Fused-epilogue numerics: the Pallas kernel's store-phase epilogue
(interpret mode) against the unfused reference sequence, the XLA dispatch
path, and gradients through `ops.matmul` with an epilogue.

The sharded cases (epilogues through ``xyz_matmul`` incl. the overlapped
'ring' schedule, and its gradients) live in ``_multidev_checks.py`` /
``test_maxeva_matmul.py`` because they need an 8-device subprocess.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.epilogue import Epilogue, apply_epilogue
from repro.kernels.matmul import matmul_pallas

EPILOGUES = [
    Epilogue(),
    Epilogue(out_dtype=jnp.bfloat16),
    Epilogue(bias=True),
    Epilogue(bias=True, activation="gelu"),
    Epilogue(activation="silu", residual=True),
    Epilogue(bias=True, activation="relu", residual=True,
             out_dtype=jnp.bfloat16),
    Epilogue(quantize=True),
    Epilogue(bias=True, activation="gelu", quantize=True),
]
_IDS = ["id", "cast", "b", "b+gelu", "silu+r", "b+relu+r+cast", "q", "b+gelu+q"]


def _operands(m, k, n, seed=0, dtype=jnp.float32):
    ka, kb, kc, kd = jax.random.split(jax.random.PRNGKey(seed), 4)
    a = jax.random.normal(ka, (m, k), dtype)
    b = jax.random.normal(kb, (k, n), dtype)
    bias = jax.random.normal(kc, (n,), jnp.float32)
    res = jax.random.normal(kd, (m, n), jnp.float32)
    return a, b, bias, res


def _check(got, want, ep, exact_q=True):
    if ep.quantize:
        gq, gs = got
        wq, ws = want
        assert gq.dtype == jnp.int8 and gs.dtype == jnp.float32
        dq = np.abs(np.asarray(gq, np.int32) - np.asarray(wq, np.int32))
        # blocked-K accumulation can flip a value across a rounding
        # boundary by at most one quantization step
        assert dq.max() <= (0 if exact_q else 1), dq.max()
        np.testing.assert_allclose(np.asarray(gs), np.asarray(ws),
                                   rtol=1e-5)
        return
    assert got.dtype == want.dtype, (got.dtype, want.dtype)
    rtol = 1e-5 if got.dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=1e-4)


@pytest.mark.parametrize("ep", EPILOGUES, ids=_IDS)
@pytest.mark.parametrize("mkn", [(32, 32, 32), (100, 130, 70), (1, 64, 256)])
def test_kernel_epilogue_matches_ref_interpret(mkn, ep):
    """Pallas store-phase epilogue (interpret) vs the XLA mirror."""
    m, k, n = mkn
    a, b, bias, res = _operands(m, k, n)
    got = matmul_pallas(a, b, block=(32, 32, 32), epilogue=ep,
                        bias=bias if ep.bias else None,
                        residual=res if ep.residual else None,
                        interpret=True)
    want = ref.matmul_fused_ref(a, b, ep, bias=bias if ep.bias else None,
                                residual=res if ep.residual else None)
    _check(got, want, ep, exact_q=(k <= 32))


@pytest.mark.parametrize("ep", EPILOGUES, ids=_IDS)
def test_ops_dispatch_xla_and_interpret_agree(ep):
    """The two kernel modes implement the same Epilogue semantics."""
    a, b, bias, res = _operands(48, 64, 40, seed=3)
    kw = dict(epilogue=ep, bias=bias if ep.bias else None,
              residual=res if ep.residual else None)
    x = ops.matmul(a, b, mode="xla", **kw)
    p = ops.matmul(a, b, block=(16, 16, 16), mode="interpret", **kw)
    _check(p, x, ep, exact_q=False)


def test_fused_equals_unfused_sequence_xla():
    """Fusion changes op boundaries, not numerics: one fused dispatch ==
    plain GEMM followed by a separate epilogue op."""
    a, b, bias, res = _operands(64, 96, 128, seed=5)
    for ep in EPILOGUES:
        kwargs = dict(bias=bias if ep.bias else None,
                      residual=res if ep.residual else None)
        fused = ops.matmul(a, b, mode="xla", epilogue=ep, **kwargs)
        acc = ops.matmul(a, b, mode="xla")  # fp32 accumulator to memory
        unfused = apply_epilogue(acc, ep, **kwargs)
        if ep.quantize:
            np.testing.assert_array_equal(np.asarray(fused[0]),
                                          np.asarray(unfused[0]))
            np.testing.assert_array_equal(np.asarray(fused[1]),
                                          np.asarray(unfused[1]))
        else:
            np.testing.assert_array_equal(np.asarray(fused),
                                          np.asarray(unfused))


def test_int8_pipeline_epilogue_exact():
    """int8 x int8 -> int32 accumulate -> fused rowwise requantize is
    exact in both kernel modes (integer accumulation has no rounding)."""
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.randint(ka, (64, 96), -127, 128, jnp.int32).astype(jnp.int8)
    b = jax.random.randint(kb, (96, 64), -127, 128, jnp.int32).astype(jnp.int8)
    ep = Epilogue(quantize=True)
    qi, si = matmul_pallas(a, b, block=(32, 32, 32), epilogue=ep,
                           interpret=True)
    qr, sr = ref.matmul_fused_ref(a, b, ep)
    np.testing.assert_array_equal(np.asarray(qi), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(si), np.asarray(sr), rtol=1e-6)


def test_epilogue_gradients_match_unfused():
    """d/d{a, b, bias, residual} of the fused path == the unfused
    composition (XLA mode; the differentiable epilogues)."""
    a, b, bias, res = _operands(24, 32, 16, seed=7)
    ep = Epilogue(bias=True, activation="gelu", residual=True)

    def loss_fused(a, b, bias, res):
        out = ops.matmul(a, b, mode="xla", epilogue=ep, bias=bias,
                         residual=res)
        return jnp.sum(jnp.sin(out))

    def loss_unfused(a, b, bias, res):
        acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
        return jnp.sum(jnp.sin(jax.nn.gelu(acc + bias) + res))

    gf = jax.grad(loss_fused, argnums=(0, 1, 2, 3))(a, b, bias, res)
    gu = jax.grad(loss_unfused, argnums=(0, 1, 2, 3))(a, b, bias, res)
    for got, want in zip(gf, gu):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def test_default_block_dtype_fallback():
    """Unlisted dtypes (float16, int32) fall back by itemsize instead of
    raising KeyError."""
    assert ops.default_block(256, 256, 256, "float16") == \
        ops.default_block(256, 256, 256, "bfloat16")
    assert ops.default_block(256, 256, 256, "int32") == \
        ops.default_block(256, 256, 256, "float32")
    assert ops.planner_dtype_key(jnp.float16) == "bf16"
    assert ops.planner_dtype_key(jnp.int32) == "fp32"
    assert ops.planner_dtype_key(jnp.uint8) == "int8"
    assert ops.planner_dtype_key("bf16") == "bf16"


def test_planner_epilogue_accounting():
    """Fused epilogues shrink the planner's modeled HBM bytes, and the
    savings model is consistent between planner and perf_model."""
    from repro.core.perf_model import fused_epilogue_savings
    from repro.core.planner import epilogue_hbm_bytes
    ep = Epilogue(bias=True, activation="gelu", out_dtype=jnp.bfloat16)
    m, n = 4096, 14336
    fused = epilogue_hbm_bytes(m, n, ep, fused=True)
    unfused = epilogue_hbm_bytes(m, n, ep, fused=False)
    assert fused < unfused
    # the unfused path pays the fp32 accumulator round trip
    assert unfused - fused == 2 * 4 * m * n
    sav = fused_epilogue_savings(m, n, ep)
    assert sav["bytes_saved"] == unfused - fused
    assert sav["seconds_saved"] > 0
