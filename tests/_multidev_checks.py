"""Multi-device correctness checks, run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the caller —
never globally, per the dry-run isolation rule).

Usage: python tests/_multidev_checks.py <check_name>
Exits 0 on success; raises (non-zero exit) on failure.
"""
import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    )

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.maxeva_matmul import (  # noqa: E402
    XYZConfig,
    shard_weight_xyz,
    unshard_weight_xyz,
    xyz_matmul,
    xyz_matmul_replicated_out,
)
from repro.core.sharding import use_mesh  # noqa: E402


def make_mesh():
    from repro.launch.mesh import make_mesh as mk
    return mk(2, 4)


def _data(b=4, s=8, k=32, n=64, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (b, s, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) / np.sqrt(k)
    return x, w


def check_weight_layout_roundtrip():
    _, w = _data(k=32, n=64)
    for y in (1, 2, 4):
        w_xyz = shard_weight_xyz(w, 4, y)
        back = unshard_weight_xyz(w_xyz, y)
        np.testing.assert_allclose(np.asarray(back), np.asarray(w))
    print("ok weight_layout_roundtrip")


def check_schedule_equivalence():
    """The registered schedule-equivalence sweep (raw GEMM): every
    (schedule x x_layout x Y x Z) cell is bitwise fp32 equal across
    schedules AND matches the ref oracle.  Replaces the old ad-hoc
    xyz_forward_all_schedules + ring_bitwise_matches_reduce_scatter
    checks; the full epilogue grid runs in tests/test_schedule_equivalence
    under the multidev CI job."""
    import _schedule_sweep as sweep
    mesh = make_mesh()
    sweep.run_sweep(mesh, ys=(1, 2, 4), epilogues=("none",))
    # extra seeds on the reduction-heavy cells (the old ring-bitwise
    # check swept 3 seeds; keep that depth on the new schedules)
    for seed in (1, 2):
        for y in (2, 4):
            sweep.run_combo(mesh, y=y, layout="replicated",
                            ep_name="none", shape=(4, 8, 64, 128),
                            seed=seed)
    print("ok schedule_equivalence")


def check_schedule_equivalence_epilogue():
    """Fused-epilogue cells of the equivalence sweep (reduced grid: the
    full one runs under pytest -m multidev)."""
    import _schedule_sweep as sweep
    mesh = make_mesh()
    for ep_name in ("bias_gelu_residual", "quantize", "gate_silu"):
        for layout in ("replicated", "ksharded"):
            for y in (2, 4):
                sweep.run_combo(mesh, y=y, layout=layout, ep_name=ep_name,
                                schedules=("reduce_scatter", "bidir_ring"))
    print("ok schedule_equivalence_epilogue")


def check_replicated_out():
    mesh = make_mesh()
    x, w = _data()
    want = np.asarray(jnp.einsum("bsk,kn->bsn", x, w))
    for layout in ("replicated", "ksharded"):
        cfg = XYZConfig(y=4, schedule="allreduce", x_layout=layout)
        w_xyz = shard_weight_xyz(w, 4, 4)
        with use_mesh(mesh):
            got = xyz_matmul_replicated_out(x, w_xyz, mesh=mesh, cfg=cfg)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5,
                                   atol=2e-5, err_msg=layout)
    print("ok replicated_out")


def check_overlapped_gather_hlo():
    """The 'ksharded' Z>1 Y>1 path must contain NO barrier all-gather of A
    in its compiled HLO — the chunked ppermute gather replaced it — and
    the ppermute chain must not trip the weight-concat detector."""
    from repro.launch.hlo_analysis import weight_concat_count
    mesh = make_mesh()
    x, w = _data(b=4, s=8, k=64, n=128)
    for sched in ("bidir_ring", "ring", "reduce_scatter"):
        cfg = XYZConfig(y=2, schedule=sched, x_layout="ksharded")
        w_xyz = shard_weight_xyz(w, 4, 2)
        f = jax.jit(lambda xx: xyz_matmul(xx, w_xyz, mesh=mesh, cfg=cfg))
        with use_mesh(mesh):
            txt = f.lower(x).compile().as_text()
        assert "all-gather" not in txt, f"{sched}: barrier all-gather of A"
        assert "collective-permute" in txt, sched
        assert weight_concat_count(txt, w.shape[0]) == 0, sched
    # Y == 1 keeps the barrier gather on purpose (whole epilogue stays
    # fused in the kernel store phase; nothing to overlap with)
    cfg1 = XYZConfig(y=1, x_layout="ksharded")
    w1 = shard_weight_xyz(w, 4, 1)
    f1 = jax.jit(lambda xx: xyz_matmul(xx, w1, mesh=mesh, cfg=cfg1))
    with use_mesh(mesh):
        txt1 = f1.lower(x).compile().as_text()
    assert "all-gather" in txt1
    print("ok overlapped_gather_hlo")


def check_xyz_epilogue():
    """Fused epilogues through the sharded path match the unfused
    reference (einsum + bias/act/residual) for every schedule."""
    from repro.kernels.epilogue import Epilogue
    mesh = make_mesh()
    x, w = _data()
    n = w.shape[1]
    kb, kr = jax.random.split(jax.random.PRNGKey(7))
    bias = jax.random.normal(kb, (n,), jnp.float32)
    res = jax.random.normal(kr, (*x.shape[:-1], n), jnp.float32)

    base = jnp.einsum("bsk,kn->bsn", x, w)
    for y, sched in [(1, "reduce_scatter"), (2, "ring"),
                     (4, "reduce_scatter"), (4, "ring"), (2, "allreduce"),
                     (2, "bidir_ring"), (4, "bidir_ring")]:
        ep = Epilogue(bias=True, activation="gelu", residual=True)
        want = jax.nn.gelu(base + bias) + res
        cfg = XYZConfig(y=y, schedule=sched, epilogue=ep)
        w_xyz = shard_weight_xyz(w, 4, y)
        with use_mesh(mesh):
            got = xyz_matmul(x, w_xyz, mesh=mesh, cfg=cfg, bias=bias,
                             residual=res)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5,
            err_msg=f"y={y} sched={sched}")

    # fused rowwise int8 quantize: per-N-shard scales, [..., model]
    epq = Epilogue(activation="silu", quantize=True)
    cfgq = XYZConfig(y=2, schedule="ring", epilogue=epq)
    w_xyz = shard_weight_xyz(w, 4, 2)
    with use_mesh(mesh):
        q, s = xyz_matmul(x, w_xyz, mesh=mesh, cfg=cfgq)
    assert q.shape == base.shape and q.dtype == jnp.int8, (q.shape, q.dtype)
    assert s.shape == (*base.shape[:-1], 4) and s.dtype == jnp.float32
    act = np.asarray(jax.nn.silu(base))
    nloc = n // 4
    for c in range(4):
        shard = act[..., c * nloc:(c + 1) * nloc]
        sc = np.asarray(s)[..., c:c + 1]
        back = np.asarray(q)[..., c * nloc:(c + 1) * nloc] * sc
        absmax = np.max(np.abs(shard), axis=-1, keepdims=True)
        assert np.all(np.abs(back - shard) <= absmax / 254 + 1e-5), c

    # replicated-out epilogue (full-row bias, replicated residual)
    epr = Epilogue(bias=True, activation="relu")
    cfgr = XYZConfig(y=4, schedule="allreduce", epilogue=epr)
    w_xyz = shard_weight_xyz(w, 4, 4)
    with use_mesh(mesh):
        got = xyz_matmul_replicated_out(x, w_xyz, mesh=mesh, cfg=cfgr,
                                        bias=bias)
    want = jax.nn.relu(base + bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    print("ok xyz_epilogue")


def check_grads():
    mesh = make_mesh()
    x, w = _data(k=16, n=32)

    for y, sched, layout in [
            (1, "allreduce", "replicated"), (4, "reduce_scatter", "replicated"),
            (2, "ring", "replicated"), (4, "ring", "replicated"),
            (4, "allreduce", "replicated"), (2, "bidir_ring", "replicated"),
            (4, "bidir_ring", "replicated"),
            # the overlapped-gather path: ppermute gather + K-piece GEMMs
            # must transpose correctly under AD
            (2, "bidir_ring", "ksharded"), (2, "reduce_scatter", "ksharded")]:
        cfg = XYZConfig(y=y, schedule=sched, x_layout=layout)
        w_xyz = shard_weight_xyz(w, 4, y)

        def loss_sharded(xx, ww):
            out = xyz_matmul(xx, ww, mesh=mesh, cfg=cfg)
            return jnp.sum(jnp.sin(out))

        def loss_ref(xx, ww):
            return jnp.sum(jnp.sin(jnp.einsum("bsk,kn->bsn", xx,
                                              unshard_weight_xyz(ww, y))))

        with use_mesh(mesh):
            gx, gw = jax.grad(loss_sharded, argnums=(0, 1))(x, w_xyz)
        gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w_xyz)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_r),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"gx y={y} {sched}")
        np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_r),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"gw y={y} {sched}")

    # gradients THROUGH the fused epilogue, with the overlapped ring
    from repro.kernels.epilogue import Epilogue
    kb = jax.random.PRNGKey(11)
    bias = jax.random.normal(kb, (w.shape[1],), jnp.float32)
    for y, sched in [(2, "ring"), (4, "ring"), (4, "reduce_scatter"),
                     (2, "bidir_ring"), (4, "bidir_ring")]:
        ep = Epilogue(bias=True, activation="gelu")
        cfg = XYZConfig(y=y, schedule=sched, epilogue=ep)
        w_xyz = shard_weight_xyz(w, 4, y)

        def loss_fused(xx, ww, bb):
            out = xyz_matmul(xx, ww, mesh=mesh, cfg=cfg, bias=bb)
            return jnp.sum(jnp.sin(out))

        def loss_unfused(xx, ww, bb):
            h = jnp.einsum("bsk,kn->bsn", xx, unshard_weight_xyz(ww, y))
            return jnp.sum(jnp.sin(jax.nn.gelu(h + bb)))

        with use_mesh(mesh):
            gx, gw, gb = jax.grad(loss_fused, argnums=(0, 1, 2))(
                x, w_xyz, bias)
        gx_r, gw_r, gb_r = jax.grad(loss_unfused, argnums=(0, 1, 2))(
            x, w_xyz, bias)
        for got, want, nm in [(gx, gx_r, "gx"), (gw, gw_r, "gw"),
                              (gb, gb_r, "gb")]:
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4,
                err_msg=f"epilogue {nm} y={y} {sched}")
    print("ok grads")


def check_packed_qkv_fused_sp():
    """Packed single-dispatch QKV through the fused SP shard_map on the
    2x4 mesh matches the per-view einsum reference (each model shard's
    local packed columns are [wq_i | wk_i | wv_i])."""
    from repro.configs.base import ArchConfig
    from repro.models import param as pm
    from repro.models.attention import attn_defs, fused_qkv_sp
    from repro.models.layers import TPCtx
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=32,
                     n_heads=8, n_kv_heads=4, head_dim=8, d_ff=64,
                     vocab=100)
    mesh = make_mesh()
    ctx = TPCtx(mesh=mesh, sp=True, compute_dtype=jnp.float32)
    params = pm.initialize({"a": attn_defs(cfg, 4, "float32", False)},
                           seed=5)["a"]
    views = pm.split_views(
        attn_defs(cfg, 4, "float32", False)["wqkv"], params["wqkv"])
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model),
                          jnp.float32)
    with use_mesh(mesh):
        q, k, v = fused_qkv_sp(params, x, cfg, ctx)
    b, s = x.shape[:2]
    for got, w, n in ((q, views["wq"], cfg.n_heads),
                      (k, views["wk"], cfg.n_kv_heads),
                      (v, views["wv"], cfg.n_kv_heads)):
        want = jnp.einsum("bsd,dn->bsn", x, w).reshape(b, s, n, cfg.hd)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
    print("ok packed_qkv_fused_sp")


def check_packed_model_forward():
    """Full smoke-model train forward on the 2x4 mesh with packed QKV:
    finite, and bitwise-stable across two jit calls."""
    from repro.configs import get_config
    from repro.models.lm import Model
    mesh = make_mesh()
    cfg = get_config("internlm2-1.8b", smoke=True)
    model = Model(cfg, mesh)
    with use_mesh(mesh):
        params = model.init_params(0)
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(k1, (4, 32), 0, cfg.vocab,
                                              jnp.int32),
                 "targets": jax.random.randint(k2, (4, 32), 0, cfg.vocab,
                                               jnp.int32)}
        f = jax.jit(model.loss)
        l1 = np.asarray(f(params, batch))
        l2 = np.asarray(f(params, batch))
    assert np.isfinite(l1), l1
    np.testing.assert_array_equal(l1, l2)
    print("ok packed_model_forward", float(l1))


def check_mlp_composition():
    """col-parallel up (Y=1) -> gelu -> row-parallel down (Y=model,
    ksharded): the Megatron pair with zero intermediate resharding."""
    mesh = make_mesh()
    x, w1 = _data(k=32, n=64)
    w2 = jax.random.normal(jax.random.PRNGKey(9), (64, 32), jnp.float32) / 8.0

    up = XYZConfig(y=1)
    down = XYZConfig(y=4, schedule="reduce_scatter", x_layout="ksharded")
    w1x = shard_weight_xyz(w1, 4, 1)
    w2x = shard_weight_xyz(w2, 4, 4)

    @jax.jit
    def mlp(xx):
        h = xyz_matmul(xx, w1x, mesh=mesh, cfg=up)
        h = jax.nn.gelu(h)
        return xyz_matmul(h, w2x, mesh=mesh, cfg=down)

    with use_mesh(mesh):
        got = mlp(x)
    want = jnp.einsum("bsk,kn->bsn", jax.nn.gelu(jnp.einsum(
        "bsk,kn->bsn", x, w1)), w2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)
    # verify the HLO contains no all-gather between the two GEMMs beyond
    # the reduce-scatter (composition is resharding-free)
    txt = jax.jit(mlp).lower(x).compile().as_text()
    assert txt.count("all-gather") <= 1, txt.count("all-gather")
    print("ok mlp_composition")


def check_collective_bytes_ordering():
    """reduce_scatter must move fewer wire bytes than allreduce (the P2 <
    P1 economics), measured from compiled HLO."""
    from repro.launch.roofline import collective_wire_bytes
    mesh = make_mesh()
    x, w = _data(b=8, s=32, k=128, n=256)

    def run(sched):
        cfg = XYZConfig(y=4, schedule=sched)
        w_xyz = shard_weight_xyz(w, 4, 4)
        f = jax.jit(lambda xx: xyz_matmul(xx, w_xyz, mesh=mesh, cfg=cfg))
        with use_mesh(mesh):
            comp = f.lower(x).compile()
        return collective_wire_bytes(comp.as_text())["total_wire_bytes"]

    ar = run("allreduce")
    rs = run("reduce_scatter")
    assert rs < ar, (rs, ar)
    # bidir moves the same TOTAL bytes as ring (each direction carries
    # half) — the win is per-link concurrency, not volume
    ring = run("ring")
    bidir = run("bidir_ring")
    assert abs(bidir - ring) <= 0.01 * ring, (bidir, ring)
    print("ok collective_bytes_ordering", rs, ar, ring, bidir)


CHECKS = {k[len("check_"):]: v for k, v in list(globals().items())
          if k.startswith("check_")}

if __name__ == "__main__":
    names = sys.argv[1:] or list(CHECKS)
    assert jax.device_count() == 8, jax.device_count()
    for nm in names:
        CHECKS[nm]()
    print("ALL_OK")
