"""Multi-device correctness checks, run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the caller —
never globally, per the dry-run isolation rule).

Usage: python tests/_multidev_checks.py <check_name>
Exits 0 on success; raises (non-zero exit) on failure.
"""
import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    )

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.maxeva_matmul import (  # noqa: E402
    XYZConfig,
    shard_weight_xyz,
    unshard_weight_xyz,
    xyz_matmul,
    xyz_matmul_replicated_out,
)


def make_mesh():
    from repro.launch.mesh import make_mesh as mk
    return mk(2, 4)


def _data(b=4, s=8, k=32, n=64, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (b, s, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) / np.sqrt(k)
    return x, w


def check_weight_layout_roundtrip():
    _, w = _data(k=32, n=64)
    for y in (1, 2, 4):
        w_xyz = shard_weight_xyz(w, 4, y)
        back = unshard_weight_xyz(w_xyz, y)
        np.testing.assert_allclose(np.asarray(back), np.asarray(w))
    print("ok weight_layout_roundtrip")


def check_xyz_forward_all_schedules():
    mesh = make_mesh()
    x, w = _data()
    want = np.asarray(jnp.einsum("bsk,kn->bsn", x, w))
    for y in (1, 2, 4):
        for sched in ("allreduce", "reduce_scatter", "ring"):
            for layout in ("replicated", "ksharded"):
                if y == 1 and layout == "ksharded" and sched != "allreduce":
                    continue
                cfg = XYZConfig(y=y, schedule=sched, x_layout=layout)
                w_xyz = shard_weight_xyz(w, 4, y)
                with jax.set_mesh(mesh):
                    got = xyz_matmul(x, w_xyz, mesh=mesh, cfg=cfg)
                np.testing.assert_allclose(
                    np.asarray(got), want, rtol=2e-5, atol=2e-5,
                    err_msg=f"y={y} sched={sched} layout={layout}")
    print("ok xyz_forward_all_schedules")


def check_replicated_out():
    mesh = make_mesh()
    x, w = _data()
    want = np.asarray(jnp.einsum("bsk,kn->bsn", x, w))
    for layout in ("replicated", "ksharded"):
        cfg = XYZConfig(y=4, schedule="allreduce", x_layout=layout)
        w_xyz = shard_weight_xyz(w, 4, 4)
        with jax.set_mesh(mesh):
            got = xyz_matmul_replicated_out(x, w_xyz, mesh=mesh, cfg=cfg)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5,
                                   atol=2e-5, err_msg=layout)
    print("ok replicated_out")


def check_grads():
    mesh = make_mesh()
    x, w = _data(k=16, n=32)

    for y, sched in [(1, "allreduce"), (4, "reduce_scatter"), (2, "ring"),
                     (4, "allreduce")]:
        cfg = XYZConfig(y=y, schedule=sched)
        w_xyz = shard_weight_xyz(w, 4, y)

        def loss_sharded(xx, ww):
            out = xyz_matmul(xx, ww, mesh=mesh, cfg=cfg)
            return jnp.sum(jnp.sin(out))

        def loss_ref(xx, ww):
            return jnp.sum(jnp.sin(jnp.einsum("bsk,kn->bsn", xx,
                                              unshard_weight_xyz(ww, y))))

        with jax.set_mesh(mesh):
            gx, gw = jax.grad(loss_sharded, argnums=(0, 1))(x, w_xyz)
        gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w_xyz)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_r),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"gx y={y} {sched}")
        np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_r),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"gw y={y} {sched}")
    print("ok grads")


def check_mlp_composition():
    """col-parallel up (Y=1) -> gelu -> row-parallel down (Y=model,
    ksharded): the Megatron pair with zero intermediate resharding."""
    mesh = make_mesh()
    x, w1 = _data(k=32, n=64)
    w2 = jax.random.normal(jax.random.PRNGKey(9), (64, 32), jnp.float32) / 8.0

    up = XYZConfig(y=1)
    down = XYZConfig(y=4, schedule="reduce_scatter", x_layout="ksharded")
    w1x = shard_weight_xyz(w1, 4, 1)
    w2x = shard_weight_xyz(w2, 4, 4)

    @jax.jit
    def mlp(xx):
        h = xyz_matmul(xx, w1x, mesh=mesh, cfg=up)
        h = jax.nn.gelu(h)
        return xyz_matmul(h, w2x, mesh=mesh, cfg=down)

    with jax.set_mesh(mesh):
        got = mlp(x)
    want = jnp.einsum("bsk,kn->bsn", jax.nn.gelu(jnp.einsum(
        "bsk,kn->bsn", x, w1)), w2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)
    # verify the HLO contains no all-gather between the two GEMMs beyond
    # the reduce-scatter (composition is resharding-free)
    txt = jax.jit(mlp).lower(x).compile().as_text()
    assert txt.count("all-gather") <= 1, txt.count("all-gather")
    print("ok mlp_composition")


def check_collective_bytes_ordering():
    """reduce_scatter must move fewer wire bytes than allreduce (the P2 <
    P1 economics), measured from compiled HLO."""
    from repro.launch.roofline import collective_wire_bytes
    mesh = make_mesh()
    x, w = _data(b=8, s=32, k=128, n=256)

    def run(sched):
        cfg = XYZConfig(y=4, schedule=sched)
        w_xyz = shard_weight_xyz(w, 4, 4)
        f = jax.jit(lambda xx: xyz_matmul(xx, w_xyz, mesh=mesh, cfg=cfg))
        with jax.set_mesh(mesh):
            comp = f.lower(x).compile()
        return collective_wire_bytes(comp.as_text())["total_wire_bytes"]

    ar = run("allreduce")
    rs = run("reduce_scatter")
    assert rs < ar, (rs, ar)
    print("ok collective_bytes_ordering", rs, ar)


CHECKS = {k[len("check_"):]: v for k, v in list(globals().items())
          if k.startswith("check_")}

if __name__ == "__main__":
    names = sys.argv[1:] or list(CHECKS)
    assert jax.device_count() == 8, jax.device_count()
    for nm in names:
        CHECKS[nm]()
    print("ALL_OK")
