"""Unit tests for the HLO contract auditor (``src/repro/analysis/``).

Four layers:

  * parser: typed graph construction, donation metadata, and the
    HARDENED trip-count extraction (multi-digit / scientific-notation /
    tuple-shaped condition constants — the old ``_trip_count`` silently
    returned 1 on all of these, captured here as HLO snippets);
  * passes: permutation validity, inverse rotations, barrier
    collectives, dtype taint, f64 leaks, donation/aliasing;
  * shims: ``launch/hlo_analysis`` reproduces the legacy fixpoint
    behavior on the deliberate-bounce fixture and on real traces;
  * baseline diff: the pure contract-vs-``HLO_CONTRACTS.json`` compare
    (violations, drift, coverage regressions).

The traced-from-jax cases stay on the default single CPU device; the
full multidev contract registry runs under ``scripts/ci.sh analyze``.
"""
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import parse_hlo, run_passes
from repro.analysis.contract import TraceReport, diff_baseline
from repro.analysis.hlo_graph import condition_trip_count
from repro.analysis.passes import (
    Finding,
    collective_schedule_pass,
    donation_pass,
    dtype_flow_pass,
)
from repro.launch.hlo_analysis import analyze_hlo, int8_bounce_count


def _hlo(body: str) -> str:
    return textwrap.dedent(body)


# ---------------------------------------------------------------------------
# parser: typed graph + donation metadata
# ---------------------------------------------------------------------------

HLO_ALIASED = _hlo("""
    HloModule decode, input_output_alias={ {1}: (1, {}, may-alias), {2}: (2, {}, may-alias) }, entry_computation_layout={()->()}

    ENTRY %main (p0: f32[4,8], p1: bf16[2,24], p2: bf16[2,24]) -> (f32[4,8], bf16[2,24], bf16[2,24]) {
      %p0 = f32[4,8] parameter(0)
      %p1 = bf16[2,24] parameter(1)
      %p2 = bf16[2,24] parameter(2)
      ROOT %t = (f32[4,8], bf16[2,24], bf16[2,24]) tuple(%p0, %p1, %p2)
    }
""")


def test_parser_module_alias_and_entry():
    m = parse_hlo(HLO_ALIASED)
    assert m.name == "decode"
    assert m.entry == "main"
    assert m.aliased_parameters() == {1: (1,), 2: (2,)}
    entry = m.entry_computation
    assert sorted(entry.params) == [0, 1, 2]
    assert entry.root.op == "tuple"
    assert entry.root.operands == ("p0", "p1", "p2")


def test_parser_def_use_edges():
    m = parse_hlo(HLO_ALIASED)
    users = m.entry_computation.users
    assert [u.name for u in users["p0"]] == ["t"]


# ---------------------------------------------------------------------------
# parser: hardened trip counts (PR 7 satellite)
# ---------------------------------------------------------------------------

def _cond(hlo: str):
    m = parse_hlo(hlo)
    return m.computations["cond"]


def test_trip_count_multi_digit():
    """Multi-digit bounds parse in full (a naive first-digit grab reads
    128 as 1)."""
    c = _cond(_hlo("""
        HloModule m
        %cond (p: (s32[], f32[4])) -> pred[] {
          %p = (s32[], f32[4]) parameter(0)
          %iv = s32[] get-tuple-element(%p), index=0
          %lim = s32[] constant(128)
          ROOT %lt = pred[] compare(%iv, %lim), direction=LT
        }
    """))
    assert condition_trip_count(c) == 128


def test_trip_count_scientific_notation():
    """fori over a float carry prints the bound as f32[] constant(1e+06)
    — the legacy parser only accepted s32 digits and fell back to 1,
    under-counting a million-step loop's FLOPs by 6 orders."""
    c = _cond(_hlo("""
        HloModule m
        %cond (p: (f32[], f32[4])) -> pred[] {
          %p = (f32[], f32[4]) parameter(0)
          %iv = f32[] get-tuple-element(%p), index=0
          %lim = f32[] constant(1e+06)
          ROOT %lt = pred[] compare(%iv, %lim), direction=LT
        }
    """))
    assert condition_trip_count(c) == 1_000_000


def test_trip_count_tuple_shaped_constant():
    """A tuple-shaped condition constant (bound folded together with a
    step) must surface the integral bound, not silently parse as 1."""
    c = _cond(_hlo("""
        HloModule m
        %cond (p: (s32[], f32[4])) -> pred[] {
          %p = (s32[], f32[4]) parameter(0)
          %iv = s32[] get-tuple-element(%p), index=0
          %k = (s32[], s32[]) constant((40, 1))
          %lim = s32[] get-tuple-element(%k), index=0
          ROOT %lt = pred[] compare(%iv, %lim), direction=LT
        }
    """))
    assert condition_trip_count(c) == 40


def test_trip_count_ignores_non_integral_floats():
    """Tolerances (1e-6) and fractional constants never become trip
    counts; the floor stays 1."""
    c = _cond(_hlo("""
        HloModule m
        %cond (p: (f32[], f32[4])) -> pred[] {
          %p = (f32[], f32[4]) parameter(0)
          %iv = f32[] get-tuple-element(%p), index=0
          %eps = f32[] constant(1e-06)
          %half = f32[] constant(2.5)
          ROOT %lt = pred[] compare(%iv, %eps), direction=LT
        }
    """))
    assert condition_trip_count(c) == 1


def test_analyze_hlo_scales_by_hardened_trip_count():
    """End to end through the shim: a 3-digit bound scales FLOPs (the
    legacy parser handled this; the hardened one must not regress it)."""
    hlo = _hlo("""
        HloModule m

        %cond (p: (s32[], f32[4,16])) -> pred[] {
          %p = (s32[], f32[4,16]) parameter(0)
          %iv = s32[] get-tuple-element(%p), index=0
          %lim = s32[] constant(250)
          ROOT %lt = pred[] compare(%iv, %lim), direction=LT
        }

        %body (bp: (s32[], f32[4,16])) -> (s32[], f32[4,16]) {
          %bp = (s32[], f32[4,16]) parameter(0)
          %a = f32[4,16] get-tuple-element(%bp), index=1
          %w = f32[16,16] constant({...})
          %d = f32[4,16] dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
          %i = s32[] get-tuple-element(%bp), index=0
          %one = s32[] constant(1)
          %n = s32[] add(%i, %one)
          ROOT %t = (s32[], f32[4,16]) tuple(%n, %d)
        }

        ENTRY %main (p0: (s32[], f32[4,16])) -> (s32[], f32[4,16]) {
          %p0 = (s32[], f32[4,16]) parameter(0)
          ROOT %w2 = (s32[], f32[4,16]) while(%p0), condition=%cond, body=%body
        }
    """)
    assert analyze_hlo(hlo)["flops"] == 250 * 2.0 * 4 * 16 * 16


# ---------------------------------------------------------------------------
# collective-schedule pass
# ---------------------------------------------------------------------------

def _permute_hlo(pairs: str, extra: str = "") -> str:
    return _hlo(f"""
        HloModule m
        ENTRY %main (p0: f32[8,8]) -> f32[8,8] {{
          %p0 = f32[8,8] parameter(0)
          %hop = f32[8,8] collective-permute(%p0), source_target_pairs={pairs}
          {extra}
          ROOT %out = f32[8,8] add(%p0, %hop)
        }}
    """)


def test_invalid_permutation_duplicate_target():
    m = parse_hlo(_permute_hlo("{{0,1},{2,1}}"))
    findings, _ = collective_schedule_pass(m, {})
    assert any(f.code == "invalid-permutation" and f.severity == "error"
               for f in findings)


def test_valid_rotation_no_finding():
    m = parse_hlo(_permute_hlo("{{0,1},{1,2},{2,3},{3,0}}"))
    findings, metrics = collective_schedule_pass(m, {})
    assert findings == []
    assert metrics["n_permutes"] == 1
    # a lone +1 rotation has no inverse partner in the module
    assert metrics["inverse_paired_permutes"] == 0


def test_missing_inverse_rotation_flagged_under_bidir_contract():
    m = parse_hlo(_permute_hlo("{{0,1},{1,2},{2,3},{3,0}}"))
    findings, _ = collective_schedule_pass(
        m, {"require_inverse_permutes": True})
    assert any(f.code == "missing-inverse-rotation" for f in findings)


def test_inverse_rotations_pair_up():
    fwd = "{{0,1},{1,2},{2,3},{3,0}}"
    bwd = ("%hop2 = f32[8,8] collective-permute(%p0), "
           "source_target_pairs={{1,0},{2,1},{3,2},{0,3}}")
    m = parse_hlo(_permute_hlo(fwd, extra=bwd))
    findings, metrics = collective_schedule_pass(
        m, {"require_inverse_permutes": True})
    assert findings == []
    assert metrics["inverse_paired_permutes"] == 2


def test_barrier_all_gather_on_overlapped_path_is_error():
    hlo = _hlo("""
        HloModule m
        ENTRY %main (p0: f32[8,8]) -> f32[8,16] {
          %p0 = f32[8,8] parameter(0)
          ROOT %ag = f32[8,16] all-gather(%p0), replica_groups={{0,1},{2,3}}, dimensions={1}
        }
    """)
    findings, _ = collective_schedule_pass(
        parse_hlo(hlo),
        {"allowed_collectives": ("collective-permute", "reduce-scatter")})
    hits = [f for f in findings if f.code == "barrier-all-gather"]
    assert hits and hits[0].severity == "error"
    # without a declared schedule the same module is clean
    clean, _ = collective_schedule_pass(parse_hlo(hlo), {})
    assert clean == []


# ---------------------------------------------------------------------------
# dtype-flow pass
# ---------------------------------------------------------------------------

def test_f64_leak_flagged_only_under_contract():
    hlo = _hlo("""
        HloModule m
        ENTRY %main (p0: f32[4]) -> f64[4] {
          %p0 = f32[4] parameter(0)
          ROOT %up = f64[4] convert(%p0)
        }
    """)
    m = parse_hlo(hlo)
    findings, metrics = dtype_flow_pass(m, {"forbid_f64": True})
    codes = {f.code for f in findings if f.severity == "error"}
    assert "f64-leak" in codes and "silent-upcast" in codes
    assert metrics["f64_instruction_count"] == 1
    relaxed, _ = dtype_flow_pass(m, {})
    assert all(f.severity != "error" for f in relaxed)


def test_int8_clean_promotes_bounce_to_error():
    hlo = _hlo("""
        HloModule m
        ENTRY %main (q: s8[4,8], w: f32[8,8]) -> f32[4,8] {
          %q = s8[4,8] parameter(0)
          %w = f32[8,8] parameter(1)
          %deq = f32[4,8] convert(%q)
          ROOT %d = f32[4,8] dot(%deq, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        }
    """)
    m = parse_hlo(hlo)
    strict, _ = dtype_flow_pass(m, {"int8_clean": True})
    assert any(f.code == "int8-bounce" and f.severity == "error"
               for f in strict)
    lax_, metrics = dtype_flow_pass(m, {})
    assert all(f.severity != "error" for f in lax_)
    assert metrics["int8_bounce_count"] == 1


# ---------------------------------------------------------------------------
# donation pass (satellite: non-donated decode trips, production doesn't)
# ---------------------------------------------------------------------------

def _smoke_model():
    import dataclasses
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.models.lm import Model
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              d_ff=96)
    return Model(cfg, make_mesh(1, 1))


def _decode_args(model, b=2, s=16, max_len=24):
    aparams = model.abstract_params()
    acache = model.abstract_cache(b, max_len)
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    n_p = len(jax.tree_util.tree_leaves(aparams))
    n_c = len(jax.tree_util.tree_leaves(acache))
    return (aparams, acache, tok, pos), tuple(range(n_p, n_p + n_c))


def test_non_donated_decode_trips_auditor():
    """A decode step jitted WITHOUT donate_argnums keeps two live copies
    of the KV cache — the donation pass must report every cache leaf."""
    model = _smoke_model()
    args, donated = _decode_args(model)
    hlo = jax.jit(model.decode_step).lower(*args).compile().as_text()
    findings, metrics = donation_pass(parse_hlo(hlo),
                                      {"donated_params": donated})
    errs = [f for f in findings if f.code == "non-donated-buffer"]
    assert len(errs) == len(donated)
    assert metrics["missing_donations"] == len(donated)


def test_production_donated_decode_is_clean():
    """The engine's production jit (donate_argnums=(1,), built through
    ``ServeEngine.decode_step_lowered``) aliases every cache leaf."""
    from repro.serve.engine import ServeConfig, ServeEngine
    model = _smoke_model()
    lowered, donated = ServeEngine.decode_step_lowered(
        model, ServeConfig(max_new_tokens=8), batch=2, prompt_len=16)
    m = parse_hlo(lowered.compile().as_text())
    findings, metrics = donation_pass(m, {"donated_params": donated})
    assert metrics["missing_donations"] == 0
    assert not [f for f in findings if f.severity == "error"]
    assert set(donated) <= set(m.aliased_parameters())


# ---------------------------------------------------------------------------
# shims: legacy fixpoint behavior preserved (satellite regression)
# ---------------------------------------------------------------------------

def test_shim_reproduces_fixpoint_on_deliberate_bounce():
    """The deliberate-bounce fixture from tests/test_int8_serving.py:
    dequantize -> float GEMM -> requantize.  The shim (now the taint
    pass) must agree with the legacy fixpoint: at least one bounce on
    the naive pipeline, zero on the clean one, and the count equals the
    dtype-flow pass metric (one shared code path)."""
    def bounced(qx, sx, w):
        x = qx.astype(jnp.float32) * sx   # s8 -> f32 dequant
        y = x @ w                         # fp32 GEMM consumes it
        s = jnp.max(jnp.abs(y), axis=-1, keepdims=True) / 127.0
        return jnp.clip(jnp.round(y / s), -127, 127).astype(jnp.int8), s

    qx = jax.ShapeDtypeStruct((4, 64), jnp.int8)
    sx = jax.ShapeDtypeStruct((4, 1), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    hlo = jax.jit(bounced).lower(qx, sx, w).compile().as_text()
    n = int8_bounce_count(hlo)
    assert n >= 1
    _, metrics = dtype_flow_pass(parse_hlo(hlo), {})
    assert metrics["int8_bounce_count"] == n

    def clean(qx, sx, w):
        return qx.astype(jnp.int32) @ w.astype(jnp.int32)

    hlo2 = jax.jit(clean).lower(qx, sx, w).compile().as_text()
    assert int8_bounce_count(hlo2) == 0


# ---------------------------------------------------------------------------
# baseline diff (pure function)
# ---------------------------------------------------------------------------

def _report(name, metrics=None, findings=(), skipped=""):
    return TraceReport(name, list(findings), dict(metrics or {}),
                       skipped=skipped)


def _err(code="non-donated-buffer"):
    return Finding("donation", code, "error", "main/p", "boom")


def _warn(code="full-tensor-copy"):
    return Finding("donation", code, "warning", "main/c", "copy")


def test_diff_matching_baseline_passes():
    r = _report("decode", {"dot_count": 8}, [_warn()])
    base = {"contracts": {"decode": {
        "metrics": {"dot_count": 8},
        "findings": {"warning:donation/full-tensor-copy": 1}}}}
    failures, _ = diff_baseline([r], base)
    assert failures == []


def test_diff_error_finding_always_fails():
    r = _report("decode", {"dot_count": 8}, [_err()])
    base = {"contracts": {"decode": {
        "metrics": {"dot_count": 8},
        "findings": {"error:donation/non-donated-buffer": 1}}}}
    failures, _ = diff_baseline([r], base)
    assert any("VIOLATION" in f for f in failures)


def test_diff_metric_drift_fails_with_update_hint():
    r = _report("decode", {"dot_count": 9}, [])
    base = {"contracts": {"decode": {"metrics": {"dot_count": 8},
                                     "findings": {}}}}
    failures, _ = diff_baseline([r], base)
    assert any("DRIFT" in f and "--update-baseline" in f
               for f in failures)


def test_diff_warning_count_drift_fails():
    r = _report("decode", {"dot_count": 8}, [_warn(), _warn()])
    base = {"contracts": {"decode": {
        "metrics": {"dot_count": 8},
        "findings": {"warning:donation/full-tensor-copy": 1}}}}
    failures, _ = diff_baseline([r], base)
    assert any("DRIFT" in f for f in failures)


def test_diff_new_and_missing_contracts_fail():
    r = _report("fresh", {"dot_count": 1})
    base = {"contracts": {"gone": {"metrics": {}, "findings": {}}}}
    failures, _ = diff_baseline([r], base)
    assert any("NEW contract fresh" in f for f in failures)
    assert any("MISSING contract gone" in f for f in failures)


def test_diff_device_skip_policy():
    r = _report("xyz", skipped="needs 8 devices, have 1")
    base = {"contracts": {"xyz": {"metrics": {}, "findings": {}}}}
    strict, _ = diff_baseline([r], base)
    assert any("SKIPPED" in f for f in strict)
    relaxed, lines = diff_baseline([r], base, allow_device_skips=True)
    assert relaxed == []
    assert any(line.startswith("skip xyz") for line in lines)


def test_diff_no_baseline_still_fails_on_violation():
    ok = _report("decode", {"dot_count": 8})
    bad = _report("decode2", {"dot_count": 8}, [_err()])
    failures, _ = diff_baseline([ok, bad], None)
    assert len(failures) == 1 and "VIOLATION" in failures[0]
