"""Per-kernel validation: sweep shapes/dtypes in interpret mode and
assert_allclose against the pure-jnp oracles in ``kernels/ref.py``.

The property-based section needs ``hypothesis`` (see requirements-dev.txt)
and degrades to a fixed-example smoke subset when it is absent.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to the fixed-example smoke subset below
    HAVE_HYPOTHESIS = False

from repro.kernels import ref
from repro.kernels.matmul import matmul_pallas
from repro.kernels.addertree import addertree_pallas
from repro.kernels.quantize import quantize_rowwise_pallas
from repro.kernels import ops


def _rand(key, shape, dtype):
    if dtype == jnp.int8:
        return jax.random.randint(key, shape, -127, 128, jnp.int32).astype(jnp.int8)
    return jax.random.normal(key, shape, dtype)


MM_SHAPES = [
    (8, 8, 8),
    (32, 128, 32),     # the paper's int8 AIE tile
    (32, 32, 32),      # the paper's fp32 AIE tile
    (128, 64, 256),
    (100, 130, 70),    # non-divisible -> exercises the padding path
    (1, 256, 512),
    (257, 33, 129),
]
MM_DTYPES = [jnp.float32, jnp.bfloat16, jnp.int8]


@pytest.mark.parametrize("dtype", MM_DTYPES, ids=["f32", "bf16", "i8"])
@pytest.mark.parametrize("mkn", MM_SHAPES)
def test_matmul_matches_ref(mkn, dtype):
    m, k, n = mkn
    ka, kb = jax.random.split(jax.random.PRNGKey(m * 7 + n))
    a = _rand(ka, (m, k), dtype)
    b = _rand(kb, (k, n), dtype)
    got = matmul_pallas(a, b, block=(32, 32, 32), interpret=True)
    want = ref.matmul_ref(a, b)
    assert got.dtype == want.dtype
    if dtype == jnp.int8:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block", [(8, 8, 8), (16, 64, 32), (64, 16, 128)])
def test_matmul_block_shapes(block):
    """Planner-chosen blocks vary per GEMM; all must be numerically exact."""
    a = jax.random.normal(jax.random.PRNGKey(0), (96, 80), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (80, 144), jnp.float32)
    got = matmul_pallas(a, b, block=block, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


def test_matmul_out_dtype_cast():
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 64), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(1), (64, 64), jnp.bfloat16)
    got = matmul_pallas(a, b, block=(32, 32, 32), out_dtype=jnp.bfloat16,
                        interpret=True)
    assert got.dtype == jnp.bfloat16
    want = ref.matmul_ref(a, b, jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8],
                         ids=["f32", "bf16", "i8"])
@pytest.mark.parametrize("s,m,n", [(2, 32, 32), (4, 64, 96), (7, 50, 33),
                                   (3, 1, 128)])
def test_addertree_matches_ref(s, m, n, dtype):
    p = _rand(jax.random.PRNGKey(s + m), (s, m, n), dtype)
    if dtype == jnp.int8:
        got = addertree_pallas(p, block=(32, 32), out_dtype=jnp.int32,
                               interpret=True)
        want = ref.addertree_ref(p, jnp.int32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        got = addertree_pallas(p, block=(32, 32), out_dtype=jnp.float32,
                               interpret=True)
        want = ref.addertree_ref(p, jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,n", [(8, 64), (100, 33), (256, 512), (1, 8)])
def test_quantize_matches_ref(m, n):
    x = jax.random.normal(jax.random.PRNGKey(m), (m, n), jnp.float32) * 3.0
    q, s = quantize_rowwise_pallas(x, block_rows=32, interpret=True)
    qr, sr = ref.quantize_rowwise_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


# ---------------------------------------------------------------------------
# properties — the checks run either under hypothesis (random sweep) or on
# the fixed smoke examples below when hypothesis is absent
# ---------------------------------------------------------------------------

def _check_matmul_linearity(m, k, n, seed):
    """(aA) @ B == a (A @ B): the kernel is linear in its inputs."""
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(ka, (m, k), jnp.float32)
    b = jax.random.normal(kb, (k, n), jnp.float32)
    lhs = matmul_pallas(2.0 * a, b, block=(16, 16, 16), interpret=True)
    rhs = 2.0 * matmul_pallas(a, b, block=(16, 16, 16), interpret=True)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-5,
                               atol=1e-5)


def _check_addertree_sequential(s, m, n, seed):
    """The tree result equals the paper's sequential Add-kernel chain."""
    p = jax.random.normal(jax.random.PRNGKey(seed), (s, m, n), jnp.float32)
    got = addertree_pallas(p, block=(16, 16), out_dtype=jnp.float32,
                           interpret=True)
    seq = p[0]
    for i in range(1, s):
        seq = seq + p[i]
    np.testing.assert_allclose(np.asarray(got), np.asarray(seq), rtol=1e-5,
                               atol=1e-5)


def _check_quantize_roundtrip(m, n, seed, scale):
    """|x - dequant(quant(x))| <= absmax/254 + eps, per row."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, n), jnp.float32) * scale
    q, s = ref.quantize_rowwise_ref(x)
    back = ref.dequantize_rowwise_ref(q, s)
    absmax = np.max(np.abs(np.asarray(x)), axis=1, keepdims=True)
    bound = absmax / 254.0 + 1e-6
    assert np.all(np.abs(np.asarray(back) - np.asarray(x)) <= bound + 1e-5)


def _check_quantize_pallas_props(m, n, block_rows, seed):
    """quantize_rowwise_pallas properties over awkward shapes: zero rows,
    a single row, block_rows not dividing M (the padding path) — the
    kernel must match the oracle exactly and the per-row round-trip error
    must stay within absmax/127."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, n),
                          jnp.float32) * 3.0
    q, s = quantize_rowwise_pallas(x, block_rows=block_rows,
                                   interpret=True)
    assert q.shape == (m, n) and q.dtype == jnp.int8
    assert s.shape == (m, 1) and s.dtype == jnp.float32
    qr, sr = ref.quantize_rowwise_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    back = np.asarray(q, np.float32) * np.asarray(s)
    absmax = np.max(np.abs(np.asarray(x)), axis=1, keepdims=True) \
        if m else np.zeros((0, 1), np.float32)
    assert np.all(np.abs(back - np.asarray(x)) <= absmax / 127.0 + 1e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64),
        seed=st.integers(0, 2 ** 16),
    )
    def test_matmul_linearity_property(m, k, n, seed):
        _check_matmul_linearity(m, k, n, seed)

    @settings(max_examples=20, deadline=None)
    @given(s=st.integers(1, 6), m=st.integers(1, 48), n=st.integers(1, 48),
           seed=st.integers(0, 2 ** 16))
    def test_addertree_equals_sequential_adds(s, m, n, seed):
        _check_addertree_sequential(s, m, n, seed)

    @settings(max_examples=25, deadline=None)
    @given(m=st.integers(1, 32), n=st.integers(2, 128),
           seed=st.integers(0, 2 ** 16), scale=st.floats(1e-3, 1e3))
    def test_quantize_roundtrip_error_bound(m, n, seed, scale):
        _check_quantize_roundtrip(m, n, seed, scale)

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(0, 48), n=st.integers(1, 96),
           block_rows=st.sampled_from([8, 32, 256]),
           seed=st.integers(0, 2 ** 16))
    def test_quantize_pallas_properties(m, n, block_rows, seed):
        _check_quantize_pallas_props(m, n, block_rows, seed)


@pytest.mark.parametrize("m,k,n,seed", [(1, 1, 1, 0), (8, 16, 4, 1),
                                        (33, 7, 20, 2), (64, 64, 64, 3)])
def test_matmul_linearity_smoke(m, k, n, seed):
    _check_matmul_linearity(m, k, n, seed)


@pytest.mark.parametrize("s,m,n,seed", [(1, 1, 1, 0), (3, 17, 9, 1),
                                        (6, 48, 48, 2)])
def test_addertree_sequential_smoke(s, m, n, seed):
    _check_addertree_sequential(s, m, n, seed)


@pytest.mark.parametrize("m,n,seed,scale", [(1, 2, 0, 1e-3), (7, 33, 1, 1.0),
                                            (32, 128, 2, 1e3)])
def test_quantize_roundtrip_smoke(m, n, seed, scale):
    _check_quantize_roundtrip(m, n, seed, scale)


@pytest.mark.parametrize("m,n,block_rows,seed", [
    (0, 8, 32, 0),      # zero rows: empty result, no 0-length grid
    (1, 64, 256, 1),    # single row, block larger than M
    (100, 33, 32, 2),   # block_rows does not divide M (padding path)
    (64, 16, 8, 3),     # exact multiple
])
def test_quantize_pallas_props_smoke(m, n, block_rows, seed):
    _check_quantize_pallas_props(m, n, block_rows, seed)


def test_quantized_matmul_close_to_float():
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 128), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (128, 96), jnp.float32)
    got = ref.quantized_matmul_ref(a, b)
    want = a @ b
    err = np.abs(np.asarray(got) - np.asarray(want))
    rel = np.linalg.norm(err) / np.linalg.norm(np.asarray(want))
    assert rel < 0.03  # int8 quantization noise


# ---------------------------------------------------------------------------
# ops dispatch
# ---------------------------------------------------------------------------

def test_ops_dispatch_xla_and_interpret_agree():
    a = jax.random.normal(jax.random.PRNGKey(0), (40, 56), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (56, 24), jnp.float32)
    x = ops.matmul(a, b, mode="xla")
    p = ops.matmul(a, b, block=(16, 16, 16), mode="interpret")
    np.testing.assert_allclose(np.asarray(x), np.asarray(p), rtol=1e-5,
                               atol=1e-5)


def test_ops_default_block_is_planned():
    blk = ops.default_block(4096, 4096, 4096, "bf16")
    assert all(v >= 128 for v in blk[1:])
    assert blk[0] % 8 == 0
