"""Flash-attention kernel validation (PR 9).

Three layers of checks:

  * oracle agreement — the Pallas prefill/decode/paged kernels (interpret
    mode) and their tiled XLA mirrors land within a consistency budget of
    the f64-anchored plain-softmax oracles in ``kernels/ref.py``.  The
    budget mirrors ``test_archs_smoke``: the f64 reference anchors an f32
    oracle run, and the kernel must sit within a small multiple of the
    f32 rounding distance plus the output-dtype quantization step.
  * bitwise contracts — decode output is bitwise-invariant to the
    flash-decode split count (the rank-order combine makes the partial
    fold order independent of which program computed which tile), and
    trash-page / idle-lane rows contribute exact zeros.
  * HLO regression — the traced decode step's pre-optimization module
    contains no full-cache fp32 upcast (the einsum bug this PR fixed),
    and the detector demonstrably fires on the old pattern.

The property-based section needs ``hypothesis`` (see requirements-dev.txt)
and degrades to a fixed-example smoke subset when it is absent.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to the fixed-example smoke subset below
    HAVE_HYPOTHESIS = False

from repro.kernels import flash_attention as fa
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.models import attention as A


def _bf16(key, *shape):
    return jax.random.normal(key, shape, jnp.float32).astype(jnp.bfloat16)


def _budget(got, want64, want32):
    """Consistency budget vs the f64 anchor: the kernel may be at most
    4x as far from f64 truth as the f32 oracle, plus the output dtype's
    quantization step (kernels return q.dtype = bf16; the f32 oracle
    does not pay that rounding)."""
    g = np.asarray(got, np.float64)
    w64 = np.asarray(want64, np.float64)
    w32 = np.asarray(want32, np.float64)
    scale = max(1.0, float(np.max(np.abs(w64))))
    eps_out = float(jnp.finfo(got.dtype).eps)
    err32 = float(np.max(np.abs(w32 - w64)))
    err = float(np.max(np.abs(g - w64)))
    assert err <= 4.0 * err32 + 4.0 * eps_out * scale, \
        f"err={err:.3e} budget={4.0 * err32 + 4.0 * eps_out * scale:.3e}"


def _f64_prefill_ref(q, k, v, **kw):
    from jax.experimental import enable_x64
    with enable_x64():
        return ref.flash_attention_ref(
            jnp.asarray(np.asarray(q, np.float64)),
            jnp.asarray(np.asarray(k, np.float64)),
            jnp.asarray(np.asarray(v, np.float64)), **kw)


# ---------------------------------------------------------------------------
# prefill kernel vs oracle (and the einsum-scan production fallback)
# ---------------------------------------------------------------------------

def _check_prefill(b, sq, n_h, n_kv, hd, kind, seed, *, window=0,
                   prefix_len=0, softcap=None, block=8):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _bf16(keys[0], b, sq, n_h, hd)
    k = _bf16(keys[1], b, sq, n_kv, hd)
    v = _bf16(keys[2], b, sq, n_kv, hd)
    kw = dict(kind=kind, window=window, prefix_len=prefix_len,
              softcap=softcap)
    want64 = _f64_prefill_ref(q, k, v, **kw)
    want32 = ref.flash_attention_ref(q.astype(jnp.float32),
                                     k.astype(jnp.float32),
                                     v.astype(jnp.float32), **kw)
    got = fa.flash_attention_pallas(q, k, v, block_q=block, block_k=block,
                                    interpret=True, **kw)
    assert got.shape == q.shape and got.dtype == q.dtype
    _budget(got, want64, want32)
    # the production einsum-scan fallback must satisfy the same budget
    # (it takes head-expanded k/v) — this pins the sq % q_chunk != 0
    # right-pad fix: before it, the last partial q-chunk's clamped
    # dynamic slice attended through mislabeled positions
    g = n_h // n_kv
    scan = A.flash_attention(q, jnp.repeat(k, g, axis=2),
                             jnp.repeat(v, g, axis=2), q_chunk=4,
                             kv_chunk=4, **kw)
    _budget(scan, want64, want32)


PREFILL_CASES = [
    # (b, sq, n_h, n_kv, hd, kind, seed, extra)
    (1, 10, 4, 2, 16, "global", 0, {}),
    (2, 12, 2, 2, 16, "local", 1, dict(window=4)),     # g=1 GQA edge
    (1, 10, 4, 2, 16, "chunked", 2, dict(window=4)),
    (1, 10, 4, 2, 16, "prefix", 3, dict(prefix_len=3)),
    (1, 10, 4, 2, 16, "full", 4, {}),
    (1, 10, 4, 2, 16, "global", 5, dict(softcap=5.0)),
    (1, 1, 4, 2, 16, "global", 6, {}),                 # S=1 prefill
    (1, 6, 4, 2, 12, "global", 7, {}),                 # hd % 8 != 0
    (1, 10, 4, 4, 20, "local", 8, dict(window=3)),     # sq % q_chunk != 0
    (2, 5, 2, 1, 16, "global", 9, {}),                 # KV < one tile
]


@pytest.mark.parametrize("b,sq,n_h,n_kv,hd,kind,seed,extra", PREFILL_CASES)
def test_prefill_matches_oracle(b, sq, n_h, n_kv, hd, kind, seed, extra):
    _check_prefill(b, sq, n_h, n_kv, hd, kind, seed, **extra)


# ---------------------------------------------------------------------------
# dense flash decode: oracle agreement + split-count bitwise invariance
# ---------------------------------------------------------------------------

def _decode_inputs(b, kv_len, n_kv, g, hd, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _bf16(keys[0], b, 1, n_kv, g, hd)
    kc = _bf16(keys[1], b, kv_len, n_kv, hd)
    vc = _bf16(keys[2], b, kv_len, n_kv, hd)
    return q, kc, vc


def _check_decode(b, kv_len, n_kv, g, hd, pos, seed, *, kind="global",
                  kv_tile=8):
    q, kc, vc = _decode_inputs(b, kv_len, n_kv, g, hd, seed)
    from jax.experimental import enable_x64
    with enable_x64():
        want64 = ref.flash_decode_ref(
            jnp.asarray(np.asarray(q, np.float64)),
            jnp.asarray(np.asarray(kc, np.float64)),
            jnp.asarray(np.asarray(vc, np.float64)), pos, kind=kind)
    want32 = ref.flash_decode_ref(q.astype(jnp.float32),
                                  kc.astype(jnp.float32),
                                  vc.astype(jnp.float32), pos, kind=kind)
    xla = fa.flash_decode_xla(q, kc, vc, jnp.int32(pos), kind=kind,
                              kv_tile=kv_tile)
    _budget(xla, want64, want32)
    outs = []
    for ns in (1, 2, 4):
        pal = fa.flash_decode_pallas(q, kc, vc, jnp.int32(pos), kind=kind,
                                     kv_tile=kv_tile, n_splits=ns,
                                     interpret=True)
        _budget(pal, want64, want32)
        outs.append(np.asarray(pal.astype(jnp.float32)))
    # THE determinism contract: n_splits only changes which program
    # computes which tile partials; the ascending rank-order combine
    # makes the result bitwise-identical across split counts
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[1], outs[2])


DECODE_CASES = [
    # (b, kv_len, n_kv, g, hd, pos, seed)
    (2, 22, 2, 2, 16, 13, 0),
    (1, 7, 2, 2, 16, 6, 1),     # KV < one tile
    (1, 33, 1, 1, 12, 32, 2),   # g=1, hd % 8 != 0, tile straddle
    (2, 16, 2, 4, 16, 0, 3),    # pos=0: single valid slot
    (1, 40, 2, 2, 20, 25, 4),
]


@pytest.mark.parametrize("b,kv_len,n_kv,g,hd,pos,seed", DECODE_CASES)
def test_decode_matches_oracle_and_split_invariant(b, kv_len, n_kv, g, hd,
                                                   pos, seed):
    _check_decode(b, kv_len, n_kv, g, hd, pos, seed)


def test_decode_full_kind():
    _check_decode(1, 22, 2, 2, 16, 4, 5, kind="full")


def test_decode_einsum_fallback_same_budget():
    """The fixed einsum fallback stays within the same budget (it is the
    ring-buffer path's production implementation)."""
    q, kc, vc = _decode_inputs(2, 22, 2, 2, 16, 0)
    from jax.experimental import enable_x64
    with enable_x64():
        want64 = ref.flash_decode_ref(
            jnp.asarray(np.asarray(q, np.float64)),
            jnp.asarray(np.asarray(kc, np.float64)),
            jnp.asarray(np.asarray(vc, np.float64)), 13)
    want32 = ref.flash_decode_ref(q.astype(jnp.float32),
                                  kc.astype(jnp.float32),
                                  vc.astype(jnp.float32), 13)
    got = A.decode_attention_einsum(q, kc, vc, jnp.int32(13))
    _budget(got, want64, want32)


# ---------------------------------------------------------------------------
# paged flash decode: oracle agreement + exact-zero isolation
# ---------------------------------------------------------------------------

def _paged_inputs(n_pool, ps, n_kv, g, hd, table, positions, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    b = len(table)
    q = _bf16(keys[0], b, 1, n_kv, g, hd)
    kp = _bf16(keys[1], n_pool, ps, n_kv, hd)
    vp = _bf16(keys[2], n_pool, ps, n_kv, hd)
    return (q, kp, vp, jnp.asarray(table, jnp.int32),
            jnp.asarray(positions, jnp.int32))


def _check_paged(n_pool, ps, n_kv, g, hd, table, positions, seed):
    q, kp, vp, tab, pos = _paged_inputs(n_pool, ps, n_kv, g, hd, table,
                                        positions, seed)
    from jax.experimental import enable_x64
    with enable_x64():
        want64 = ref.paged_flash_decode_ref(
            jnp.asarray(np.asarray(q, np.float64)),
            jnp.asarray(np.asarray(kp, np.float64)),
            jnp.asarray(np.asarray(vp, np.float64)), tab, pos)
    want32 = ref.paged_flash_decode_ref(q.astype(jnp.float32),
                                        kp.astype(jnp.float32),
                                        vp.astype(jnp.float32), tab, pos)
    xla = fa.paged_flash_decode_xla(q, kp, vp, tab, pos, kv_tile=8)
    _budget(xla, want64, want32)
    pal = fa.paged_flash_decode_pallas(q, kp, vp, tab, pos.reshape(-1),
                                       interpret=True)
    _budget(pal, want64, want32)
    return xla, pal


PAGED_CASES = [
    # (n_pool, ps, table, positions, seed)
    (9, 8, [[0, 1, -1, -1], [3, 4, 5, -1]], [[13], [20]], 0),
    (5, 8, [[0, -1], [2, 3]], [[7], [15]], 1),
    (3, 4, [[1]], [[2]], 2),               # single page, KV < one tile
]


@pytest.mark.parametrize("n_pool,ps,table,positions,seed", PAGED_CASES)
def test_paged_matches_oracle(n_pool, ps, table, positions, seed):
    _check_paged(n_pool, ps, 2, 2, 16, table, positions, seed)


def test_paged_idle_lane_exact_zero():
    """An unmapped lane (all pages -1, position -1) produces EXACT zeros
    on both the Pallas kernel and the XLA mirror — the PR 8 bitwise
    lane-isolation invariant depends on it."""
    q, kp, vp, tab, pos = _paged_inputs(
        9, 8, 2, 2, 16, [[0, 1, -1, -1], [-1, -1, -1, -1]],
        [[13], [-1]], 3)
    xla = fa.paged_flash_decode_xla(q, kp, vp, tab, pos, kv_tile=8)
    pal = fa.paged_flash_decode_pallas(q, kp, vp, tab, pos.reshape(-1),
                                       interpret=True)
    assert float(jnp.max(jnp.abs(xla[1].astype(jnp.float32)))) == 0.0
    assert float(jnp.max(jnp.abs(pal[1].astype(jnp.float32)))) == 0.0


def test_paged_neighbor_isolation_bitwise():
    """Lane 0's output is bitwise independent of what lane 1's pages
    hold — remapping lane 1 must not change lane 0."""
    q, kp, vp, tab, pos = _paged_inputs(
        9, 8, 2, 2, 16, [[0, 1, -1, -1], [3, 4, 5, -1]], [[13], [20]], 4)
    a = fa.paged_flash_decode_xla(q, kp, vp, tab, pos, kv_tile=8)
    tab2 = tab.at[1].set(jnp.asarray([6, 7, -1, -1], jnp.int32))
    pos2 = pos.at[1].set(9)
    b = fa.paged_flash_decode_xla(q, kp, vp, tab2, pos2, kv_tile=8)
    np.testing.assert_array_equal(
        np.asarray(a[0].astype(jnp.float32)),
        np.asarray(b[0].astype(jnp.float32)))


def test_paged_prefill_chunk_s_gt_1():
    """The XLA mirror serves chunked prefill (S > 1) — same oracle."""
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    q = _bf16(keys[0], 1, 4, 2, 2, 16)
    kp = _bf16(keys[1], 5, 8, 2, 16)
    vp = _bf16(keys[2], 5, 8, 2, 16)
    tab = jnp.asarray([[0, 1]], jnp.int32)
    pos = jnp.asarray([[8, 9, 10, 11]], jnp.int32)
    from jax.experimental import enable_x64
    with enable_x64():
        want64 = ref.paged_flash_decode_ref(
            jnp.asarray(np.asarray(q, np.float64)),
            jnp.asarray(np.asarray(kp, np.float64)),
            jnp.asarray(np.asarray(vp, np.float64)), tab, pos)
    want32 = ref.paged_flash_decode_ref(q.astype(jnp.float32),
                                        kp.astype(jnp.float32),
                                        vp.astype(jnp.float32), tab, pos)
    got = kops.paged_flash_decode(q, kp, vp, tab, pos, mode="xla")
    _budget(got, want64, want32)


# ---------------------------------------------------------------------------
# properties — random sweeps under hypothesis, fixed smoke subset without
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(sq=st.integers(1, 14), n_kv=st.sampled_from([1, 2]),
           g=st.sampled_from([1, 2]), hd=st.sampled_from([12, 16, 20]),
           kind=st.sampled_from(["global", "local", "full"]),
           seed=st.integers(0, 2 ** 16))
    def test_prefill_shape_sweep(sq, n_kv, g, hd, kind, seed):
        _check_prefill(1, sq, n_kv * g, n_kv, hd, kind, seed,
                       window=3 if kind == "local" else 0)

    @settings(max_examples=10, deadline=None)
    @given(kv_len=st.integers(1, 40), n_kv=st.sampled_from([1, 2]),
           g=st.sampled_from([1, 2, 4]), hd=st.sampled_from([12, 16]),
           frac=st.floats(0.0, 1.0), seed=st.integers(0, 2 ** 16))
    def test_decode_shape_sweep(kv_len, n_kv, g, hd, frac, seed):
        pos = min(kv_len - 1, int(frac * kv_len))
        _check_decode(1, kv_len, n_kv, g, hd, pos, seed)


# ---------------------------------------------------------------------------
# HLO regression: no full-cache fp32 upcast in the traced decode step
# ---------------------------------------------------------------------------

def _decode_unopt_hlo(model, b, s, new):
    from repro.serve.engine import ServeConfig, ServeEngine
    scfg = ServeConfig(max_new_tokens=new, guards=False,
                       on_nonfinite="off")
    lowered, _ = ServeEngine.decode_step_lowered(model, scfg, b, s)
    return lowered.as_text(dialect="hlo")


def _big_upcasts(hlo_text, limit):
    from repro.analysis.hlo_graph import parse_hlo
    from repro.analysis.passes import dtype_flow_pass
    findings, metrics = dtype_flow_pass(
        parse_hlo(hlo_text), {"forbid_big_upcast_elems": limit})
    return ([f for f in findings if f.code == "full-pool-upcast"],
            metrics)


def _smoke_model():
    import dataclasses as dc
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.models.lm import Model
    cfg = dc.replace(get_config("internlm2-1.8b", smoke=True), d_ff=96)
    return Model(cfg, make_mesh(1, 1))


def test_decode_trace_has_no_full_cache_upcast():
    """Satellite-1 regression: with flash decode wired in, the traced
    decode step's program never widens a whole KV cache in one convert.
    max_len=64 spans two kv tiles, so the per-tile converts (<= 2048
    elems) sit well under the full-view threshold (4096)."""
    b, s, new = 2, 16, 48
    model = _smoke_model()
    limit = b * (s + new) * model.cfg.n_kv_heads * model.cfg.head_dim
    assert A.use_flash_attention()
    found, metrics = _big_upcasts(_decode_unopt_hlo(model, b, s, new),
                                  limit)
    assert not found, [f.format() for f in found]
    # the parse actually saw the program (guard against a silent
    # parser miss making this test vacuous)
    assert metrics["float_widening_converts"] > 0
    assert 0 < metrics["max_widening_convert_elems"] < limit


def test_full_cache_upcast_detector_fires_on_old_pattern():
    """Negative control: resurrect the pre-fix einsum decode (explicit
    .astype(f32) on the whole cache) and assert the detector fires —
    without this, the positive test could pass vacuously."""
    b, s, new = 2, 16, 48
    model = _smoke_model()
    limit = b * (s + new) * model.cfg.n_kv_heads * model.cfg.head_dim

    def buggy(q, k_cache, v_cache, pos, *, kind="global", window=0,
              softcap=None):
        hd = q.shape[-1]
        qf = q.astype(jnp.float32) * (hd ** -0.5)
        s_ = jnp.einsum("bqkgd,bKkd->bkgqK", qf,
                        k_cache.astype(jnp.float32))
        slots = jnp.arange(k_cache.shape[1])
        valid = slots >= 0 if kind == "full" else slots <= pos
        s_ = jnp.where(valid[None, None, None, None, :], s_, A._NEG)
        m = jnp.max(s_, axis=-1, keepdims=True)
        p = jnp.where(valid[None, None, None, None, :],
                      jnp.exp(s_ - m), 0.0)
        out = jnp.einsum("bkgqK,bKkd->bkgqd", p,
                         v_cache.astype(jnp.float32))
        out = out / jnp.maximum(jnp.sum(p, axis=-1)[..., None], 1e-30)
        return jnp.einsum("bkgqd->bqkgd", out).astype(q.dtype)

    orig_flash, orig_einsum = A.use_flash_attention(), \
        A.decode_attention_einsum
    A.set_flash_attention(False)
    A.decode_attention_einsum = buggy
    try:
        found, _ = _big_upcasts(_decode_unopt_hlo(model, b, s, new),
                                limit)
    finally:
        A.set_flash_attention(orig_flash)
        A.decode_attention_einsum = orig_einsum
    assert len(found) >= 2, [f.format() for f in found]  # K and V pools


# ---------------------------------------------------------------------------
# dispatch + toggle plumbing
# ---------------------------------------------------------------------------

def test_flash_toggle_roundtrip():
    on = A.use_flash_attention()
    try:
        A.set_flash_attention(False)
        assert not A.use_flash_attention()
        A.set_flash_attention(True)
        assert A.use_flash_attention()
    finally:
        A.set_flash_attention(on)


def test_decode_dispatch_flash_vs_einsum_agree():
    """decode_attention routes global/full kinds to flash_decode; the
    two implementations must agree within the oracle budget of each
    other (they share the masked-softmax semantics)."""
    q, kc, vc = _decode_inputs(2, 22, 2, 2, 16, 7)
    flash = A.decode_attention(q, kc, vc, jnp.int32(13))
    ein = A.decode_attention_einsum(q, kc, vc, jnp.int32(13))
    np.testing.assert_allclose(
        np.asarray(flash, np.float32), np.asarray(ein, np.float32),
        atol=3e-2, rtol=0)
