"""Shared schedule-equivalence sweep for the XYZ collective matmul.

One parametrized harness replaces the old ad-hoc per-schedule checks: for
every swept ``(schedule x x_layout x Y x Z x epilogue)`` combination on
the 8-fake-device mesh it asserts

  (a) BITWISE fp32 equality across all schedules (the determinism
      contract of ``core/maxeva_matmul.py``: shared chunk GEMMs +
      rank-order reductions make 'allreduce', 'reduce_scatter', 'ring'
      and 'bidir_ring' interchangeable bit-for-bit), and
  (b) closeness to the ``kernels.ref`` oracle (einsum + the shared
      ``apply_epilogue`` mirror).

Run either as registered checks from ``tests/_multidev_checks.py`` (the
reduced tier-1 subset) or directly as a subprocess from
``tests/test_schedule_equivalence.py`` (the full multidev-marked grid):

    python tests/_schedule_sweep.py --ys 2,4 --layouts ksharded \
        --epilogues bias_gelu --schedules all --shape 4,8,32,64 --seed 0

Every combination prints one ``ok equiv[...]`` line, so the CI multidev
log names each check individually for triage.
"""
import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    )

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.maxeva_matmul import (  # noqa: E402
    SCHEDULES,
    XYZConfig,
    shard_weight_xyz,
    xyz_matmul,
)
from repro.core.sharding import use_mesh  # noqa: E402
from repro.kernels.epilogue import Epilogue  # noqa: E402

MODEL = 4
DEFAULT_SHAPE = (4, 8, 32, 64)  # (b, s, k, n)

# epilogue grid: name -> Epilogue spec (None = raw GEMM).  Operands
# (bias / residual) are derived from the spec by the runner.
EPILOGUES = {
    "none": None,
    "bias_gelu": Epilogue(bias=True, activation="gelu"),
    "bias_gelu_residual": Epilogue(bias=True, activation="gelu",
                                   residual=True),
    "quantize": Epilogue(activation="silu", quantize=True),
    # v2 two-operand gate: silu(g) * u on the accumulator; with Y > 1
    # the gate applies post-reduction inside the shard_map (elementwise,
    # so the bitwise schedule-invariance contract must keep holding)
    "gate_silu": Epilogue(gate="silu"),
    "gate_silu_residual": Epilogue(gate="silu", residual=True),
}


def make_mesh():
    from repro.launch.mesh import make_mesh as mk
    return mk(2, MODEL)


def _data(b, s, k, n, seed):
    kx, kw, kb, kr, kg = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(kx, (b, s, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) / np.sqrt(k)
    bias = jax.random.normal(kb, (n,), jnp.float32)
    res = jax.random.normal(kr, (b, s, n), jnp.float32)
    op2 = jax.random.normal(kg, (b, s, n), jnp.float32)
    return x, w, bias, res, op2


def _flat(out):
    return list(out) if isinstance(out, tuple) else [out]


def _oracle_check(ep_name, ep, outs, x, w, bias, res, op2, tag):
    """(b): the swept result matches the unsharded einsum + shared
    ``apply_epilogue`` mirror within fp32 tolerance."""
    from repro.kernels.epilogue import apply_epilogue
    base = jnp.einsum("bsk,kn->bsn", x, w)
    got = outs
    if ep is None:
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   rtol=2e-5, atol=2e-5, err_msg=tag)
        return
    if ep.quantize:
        q, s = got
        act = np.asarray(apply_epilogue(
            base, Epilogue(activation=ep.activation)))
        n = act.shape[-1]
        nloc = n // MODEL
        assert q.shape == act.shape and q.dtype == np.int8, (q.shape, q.dtype)
        assert s.shape == (*act.shape[:-1], MODEL), s.shape
        for c in range(MODEL):
            shard = act[..., c * nloc:(c + 1) * nloc]
            back = q[..., c * nloc:(c + 1) * nloc] * s[..., c:c + 1]
            absmax = np.max(np.abs(shard), axis=-1, keepdims=True)
            assert np.all(np.abs(back - shard) <= absmax / 254 + 1e-5), \
                (tag, c)
        return
    want = apply_epilogue(base, ep, bias=bias if ep.bias else None,
                          residual=res if ep.residual else None,
                          operand2=op2 if ep.gate != "none" else None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5, err_msg=tag)


def run_combo(mesh, *, y, layout, ep_name, schedules=None,
              shape=DEFAULT_SHAPE, seed=0):
    """Run one (Y, layout, epilogue) cell across ``schedules`` and assert
    the bitwise + oracle invariants.  Returns the per-schedule outputs."""
    b, s, k, n = shape
    schedules = list(schedules or SCHEDULES)
    ep = EPILOGUES[ep_name]
    x, w, bias, res, op2 = _data(b, s, k, n, seed)
    w_xyz = shard_weight_xyz(w, MODEL, y)
    kwargs = {}
    if ep is not None and ep.bias:
        kwargs["bias"] = bias
    if ep is not None and ep.residual:
        kwargs["residual"] = res
    if ep is not None and ep.gate != "none":
        kwargs["operand2"] = op2

    outs = {}
    for sched in schedules:
        cfg = XYZConfig(y=y, schedule=sched, x_layout=layout, epilogue=ep)
        with use_mesh(mesh):
            out = xyz_matmul(x, w_xyz, mesh=mesh, cfg=cfg, **kwargs)
        outs[sched] = [np.asarray(o) for o in _flat(out)]

    z = MODEL // y
    tag = (f"y={y} z={z} layout={layout} ep={ep_name} "
           f"shape={b}x{s}x{k}x{n} seed={seed}")
    # (a) bitwise fp32 equality across schedules (int8 q and f32 scales
    # must match exactly too under the quantize epilogue)
    ref_sched = ("reduce_scatter" if "reduce_scatter" in schedules
                 else schedules[0])
    for sched in schedules:
        for got, want in zip(outs[sched], outs[ref_sched]):
            np.testing.assert_array_equal(
                got, want,
                err_msg=f"{sched} != {ref_sched} bitwise [{tag}]")
    # (b) oracle
    ref_out = outs[ref_sched]
    _oracle_check(ep_name, ep, tuple(ref_out) if len(ref_out) > 1
                  else ref_out[0], x, w, bias, res, op2, tag)
    print(f"ok equiv[{tag} schedules={','.join(schedules)}]")
    return outs


def run_sweep(mesh=None, *, ys=(1, 2, 4), layouts=("replicated", "ksharded"),
              epilogues=("none",), schedules=None, shape=DEFAULT_SHAPE,
              seed=0):
    """The full cartesian sweep.  At Y == 1 there is no reduction, so the
    schedule dimension collapses — every schedule still runs (same single
    GEMM path) when explicitly requested, but the default sweep visits it
    once to keep the check cheap."""
    mesh = mesh or make_mesh()
    for ep_name in epilogues:
        for layout in layouts:
            for y in ys:
                scheds = list(schedules or SCHEDULES)
                if y == 1 and schedules is None:
                    scheds = ["reduce_scatter"]
                run_combo(mesh, y=y, layout=layout, ep_name=ep_name,
                          schedules=scheds, shape=shape, seed=seed)


def _parse_args(argv):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ys", default="1,2,4")
    ap.add_argument("--layouts", default="replicated,ksharded")
    ap.add_argument("--epilogues", default="none")
    ap.add_argument("--schedules", default="all",
                    help="'all' or a comma list from "
                         f"{','.join(SCHEDULES)}")
    ap.add_argument("--shape", default=",".join(map(str, DEFAULT_SHAPE)),
                    help="b,s,k,n")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


if __name__ == "__main__":
    args = _parse_args(sys.argv[1:])
    assert jax.device_count() == 8, jax.device_count()
    scheds = None if args.schedules == "all" else args.schedules.split(",")
    run_sweep(
        ys=tuple(int(v) for v in args.ys.split(",")),
        layouts=tuple(args.layouts.split(",")),
        epilogues=tuple(args.epilogues.split(",")),
        schedules=scheds,
        shape=tuple(int(v) for v in args.shape.split(",")),
        seed=args.seed,
    )
    print("SWEEP_OK")
