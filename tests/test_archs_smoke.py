"""Per-arch smoke tests: REDUCED config of the same family, one forward +
train-loss step + prefill/decode consistency on CPU; asserts shapes and
finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_mesh
from repro.models.lm import Model

SEQ = 32


def _batch(cfg, b=2, s=SEQ, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    text = s - (cfg.prefix_tokens or 0)
    batch = {
        "tokens": jax.random.randint(k1, (b, text), 0, cfg.vocab, jnp.int32),
        "targets": jax.random.randint(k2, (b, text), 0, cfg.vocab,
                                      jnp.int32),
    }
    if cfg.prefix_tokens:
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.prefix_tokens, cfg.d_model),
            jnp.float32)
    if cfg.encdec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(4), (b, cfg.enc_frames, cfg.d_model),
            jnp.float32)
    return batch


@pytest.fixture(scope="module")
def mesh():
    return make_mesh(1, 1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_loss(arch, mesh):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg, mesh)
    params = model.init_params(0)
    batch = _batch(cfg)

    h, _, _ = jax.jit(
        lambda p, b: model.forward(p, b, mode="train"))(params, batch)
    assert h.shape == (2, SEQ, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))

    loss = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    # untrained model should sit near uniform over the vocab
    assert 0.2 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(
        cfg.padded_vocab())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_grads_finite(arch, mesh):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg, mesh)
    params = model.init_params(0)
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in flat)
    # gradients actually flow (at least one nonzero leaf per tree)
    assert any(float(jnp.max(jnp.abs(g.astype(jnp.float32)))) > 0
               for g in flat)


def f64_reference_logits(cfg, params, fbatch, mesh):
    """Last-token teacher-forced logits with f64 params + f64 compute: the
    precision reference the consistency budget is measured against (norms
    still run their internal fp32 stages — the GEMM chain, where prefill
    vs decode rounding can diverge, is what runs at f64)."""
    import dataclasses as dc
    from jax.experimental import enable_x64
    from repro.models.loss import vocab_parallel_logits
    with enable_x64():
        cfg64 = dc.replace(cfg, compute_dtype="float64",
                           param_dtype="float64")
        model64 = Model(cfg64, mesh)
        params64 = jax.tree.map(
            lambda x: x.astype(jnp.float64)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
        fbatch64 = {k: (v.astype(jnp.float64)
                        if jnp.issubdtype(v.dtype, jnp.floating) else v)
                    for k, v in fbatch.items()}
        h64, _, _ = model64.forward(params64, fbatch64, mode="train")
        ref64 = vocab_parallel_logits(h64[:, -1:],
                                      model64.head_weights(params64),
                                      model64.ctx, cfg.final_softcap)[:, 0]
        return np.asarray(ref64, np.float64)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch, mesh):
    """Greedy decode after prefill must match the teacher-forced forward.

    Budget policy (see ROADMAP): both paths are compared against an f64
    reference of the same computation; decode may be at most a small
    multiple of the teacher-forced path's own measured rounding error.
    The budget is derived from the pipeline's noise, not hand-tuned — a
    QKV-path change that rounds differently between prefill and decode
    (e.g. the old apply-time wq/wk/wv concat) blows it."""
    cfg = get_config(arch, smoke=True)
    model = Model(cfg, mesh)
    params = model.init_params(0)
    batch = _batch(cfg)

    logits_p, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=SEQ + 8))(params, batch)
    vp = cfg.padded_vocab()
    assert logits_p.shape == (2, vp)
    assert bool(jnp.all(jnp.isfinite(logits_p)))

    # one decode step
    tok = jnp.argmax(logits_p[:, :cfg.vocab], axis=-1)[:, None] \
        .astype(jnp.int32)
    pos = jnp.asarray(SEQ, jnp.int32)
    logits_d, cache2 = jax.jit(model.decode_step)(params, cache, tok, pos)
    assert logits_d.shape == (2, vp)
    assert bool(jnp.all(jnp.isfinite(logits_d)))

    # consistency: decode logits at step S for token t_S == forward logits
    # at position S when the same token is appended (teacher forcing)
    from repro.models.loss import vocab_parallel_logits
    full_tokens = jnp.concatenate([batch["tokens"], tok], axis=1)
    fbatch = dict(batch, tokens=full_tokens)
    h, _, _ = jax.jit(lambda p, b: model.forward(p, b, mode="train"))(
        params, fbatch)
    ref = vocab_parallel_logits(h[:, -1:], model.head_weights(params),
                                model.ctx, cfg.final_softcap)[:, 0]

    ref64 = f64_reference_logits(cfg, params, fbatch, mesh)
    scale = max(1.0, float(np.max(np.abs(ref64))))
    err_fwd = float(np.max(np.abs(np.asarray(ref, np.float64) - ref64)))
    err_dec = float(np.max(np.abs(np.asarray(logits_d, np.float64)
                                  - ref64)))
    # the low-precision pipeline itself must sit near the f64 reference
    assert err_fwd < 0.25 * scale, (err_fwd, scale)
    # decode accuracy within a small multiple of the forward path's own
    # rounding noise (floor: a few fp32 ulps of the logit scale)
    budget = 4.0 * err_fwd + 64 * np.finfo(np.float32).eps * scale
    assert err_dec <= budget, (
        f"decode drifted from the f64 reference: err_dec={err_dec:.3e} "
        f"> budget={budget:.3e} (err_fwd={err_fwd:.3e})")
