"""Packed-QKV parameter tests: view split/pack inverses, init equivalence
with the legacy schema, single-GEMM dispatch (no apply-time weight concat,
asserted on traced HLO), numeric equivalence of packed vs legacy apply,
and legacy-checkpoint migration round-trips."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.launch.hlo_analysis import gemm_dispatches, weight_concat_count
from repro.launch.mesh import make_mesh
from repro.models import param as pm
from repro.models.attention import (
    attn_defs,
    attention_apply,
    qkv_packing,
    qkv_sizes,
)
from repro.models.layers import TPCtx
from repro.models.lm import Model


def _tiny_cfg(**kw) -> ArchConfig:
    base = dict(name="tiny", family="dense", n_layers=2, d_model=32,
                n_heads=8, n_kv_heads=4, head_dim=8, d_ff=64, vocab=100)
    base.update(kw)
    return ArchConfig(**base)


@pytest.fixture(scope="module")
def mesh():
    return make_mesh(1, 1)


# -- view split / pack -----------------------------------------------------------

@pytest.mark.parametrize("packing", [1, 4, 32])
def test_split_pack_views_roundtrip(packing):
    cfg = _tiny_cfg()
    base = attn_defs(cfg, 1, "float32", False)["wqkv"]
    d = dataclasses.replace(base, packing=packing)
    arr = np.random.default_rng(0).standard_normal(d.shape).astype(
        np.float32)
    views = pm.split_views(d, arr)
    assert {k: v.shape for k, v in views.items()} == {
        "wq": (cfg.d_model, cfg.q_dim), "wk": (cfg.d_model, cfg.kv_dim),
        "wv": (cfg.d_model, cfg.kv_dim)}
    back = pm.pack_views(d, views)
    np.testing.assert_array_equal(np.asarray(back), arr)
    # and the other direction: pack(split-of-random-views)
    rng = np.random.default_rng(1)
    vs = {k: rng.standard_normal(v.shape).astype(np.float32)
          for k, v in views.items()}
    again = pm.split_views(d, pm.pack_views(d, vs))
    for k in vs:
        np.testing.assert_array_equal(np.asarray(again[k]), vs[k])


def test_packed_init_matches_legacy_views():
    """Each view of the packed init is bitwise the legacy per-view init
    (same <path>/<view> seed stream) — legacy checkpoints line up."""
    cfg = _tiny_cfg()
    for model in (1, 4):
        packed = pm.initialize(
            {"attn": attn_defs(cfg, model, "float32", False)}, seed=7)
        legacy = pm.initialize(
            {"attn": attn_defs(cfg, model, "float32", False,
                               packed=False)}, seed=7)
        d = attn_defs(cfg, model, "float32", False)["wqkv"]
        views = pm.split_views(d, packed["attn"]["wqkv"])
        for name in ("wq", "wk", "wv"):
            np.testing.assert_array_equal(np.asarray(views[name]),
                                          np.asarray(legacy["attn"][name]))


# -- apply equivalence -----------------------------------------------------------

def test_packed_apply_matches_legacy(mesh):
    """attention_apply with the packed schema == the legacy three-GEMM
    schema at f32 (train and decode modes)."""
    cfg = _tiny_cfg()
    ctx = TPCtx(mesh=mesh, sp=False, compute_dtype=jnp.float32)
    defs_p = attn_defs(cfg, 1, "float32", False)
    packed = pm.initialize({"a": defs_p}, seed=3)["a"]
    legacy = dict(pm.split_views(defs_p["wqkv"], packed["wqkv"]),
                  wo=packed["wo"])
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, cfg.d_model),
                          jnp.float32)
    positions = jnp.arange(16)
    kw = dict(kind="global", theta=1e4, positions=positions)
    out_p, _, _ = attention_apply(packed, x, cfg, ctx, **kw)
    out_l, _, _ = attention_apply(legacy, x, cfg, ctx, **kw)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_l),
                               rtol=1e-5, atol=1e-5)

    # decode mode against the same cache
    cache = {"k": jnp.zeros((2, 20, cfg.n_kv_heads, cfg.hd), jnp.float32),
             "v": jnp.zeros((2, 20, cfg.n_kv_heads, cfg.hd), jnp.float32)}
    xd = x[:, :1]
    kwd = dict(kind="global", theta=1e4, positions=jnp.zeros((1,),
                                                             jnp.int32))
    dp, cp, _ = attention_apply(packed, xd, cfg, ctx, cache=cache,
                                pos=jnp.asarray(0), **kwd)
    dl, cl, _ = attention_apply(legacy, xd, cfg, ctx, cache=cache,
                                pos=jnp.asarray(0), **kwd)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dl), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(cp["k"]), np.asarray(cl["k"]),
                               rtol=1e-6, atol=1e-6)


# -- single-dispatch / no-weight-concat HLO asserts ------------------------------

def _attn_hlo(params, x, cfg, ctx, **kw):
    f = jax.jit(lambda p, xx: attention_apply(p, xx, cfg, ctx, **kw)[0])
    return f.lower(params, x).compile().as_text()


def test_single_qkv_gemm_dispatch_no_weight_concat(mesh):
    """Acceptance: ONE QKV GEMM dispatch per attention apply, and no
    concatenate of weight shards anywhere in the traced step."""
    cfg = _tiny_cfg()
    ctx = TPCtx(mesh=mesh, sp=False, compute_dtype=jnp.float32)
    params = pm.initialize({"a": attn_defs(cfg, 1, "float32", False)},
                           seed=0)["a"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    packed_cols = sum(qkv_sizes(cfg))
    assert packed_cols != cfg.d_model  # keep the two signatures distinct

    hlo = _attn_hlo(params, x, cfg, ctx, kind="global", theta=1e4,
                    positions=jnp.arange(16))
    assert weight_concat_count(hlo, cfg.d_model) == 0, hlo
    assert gemm_dispatches(hlo, packed_cols) == 1

    # decode step: same properties
    cache = {"k": jnp.zeros((2, 20, cfg.n_kv_heads, cfg.hd), jnp.float32),
             "v": jnp.zeros((2, 20, cfg.n_kv_heads, cfg.hd), jnp.float32)}
    f = jax.jit(lambda p, xx, c: attention_apply(
        p, xx, cfg, ctx, kind="global", theta=1e4,
        positions=jnp.zeros((1,), jnp.int32), cache=c,
        pos=jnp.asarray(0))[0])
    hlo_d = f.lower(params, x[:, :1], cache).compile().as_text()
    assert weight_concat_count(hlo_d, cfg.d_model) == 0
    assert gemm_dispatches(hlo_d, packed_cols) == 1


def test_detector_fails_on_apply_time_concat(mesh):
    """Regression guard: the OLD apply-time wq/wk/wv concat produces
    exactly the HLO signature weight_concat_count flags — if that path
    ever comes back, the assert above catches it."""
    cfg = _tiny_cfg()
    defs_l = attn_defs(cfg, 1, "float32", False, packed=False)
    legacy = pm.initialize({"a": defs_l}, seed=0)["a"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)

    def old_path(p, xx):  # the PR-1 approach this PR removes
        w = jnp.concatenate([p["wq"], p["wk"], p["wv"]], axis=1)
        return jnp.einsum("bsd,dn->bsn", xx, w)

    hlo = jax.jit(old_path).lower(legacy, x).compile().as_text()
    assert weight_concat_count(hlo, cfg.d_model) >= 1


def test_full_model_step_has_no_weight_concat(mesh):
    """Whole-model guard on the real config: neither the train step nor a
    decode step concatenates weight shards."""
    cfg = get_config("internlm2-1.8b", smoke=True)
    model = Model(cfg, mesh)
    params = model.init_params(0)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(k1, (2, 16), 0, cfg.vocab,
                                          jnp.int32),
             "targets": jax.random.randint(k2, (2, 16), 0, cfg.vocab,
                                           jnp.int32)}
    hlo_t = jax.jit(model.loss).lower(params, batch).compile().as_text()
    assert weight_concat_count(hlo_t, cfg.d_model) == 0

    _, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=24))(params, batch)
    tok = jnp.zeros((2, 1), jnp.int32)
    hlo_d = jax.jit(model.decode_step).lower(
        params, cache, tok, jnp.asarray(16, jnp.int32)).compile().as_text()
    assert weight_concat_count(hlo_d, cfg.d_model) == 0


# -- checkpoint migration --------------------------------------------------------

def test_checkpoint_legacy_migration_roundtrip(tmp_path, mesh):
    """export_legacy writes wq/wk/wv leaves; restore(defs=...) packs them
    back bitwise.  Native packed checkpoints restore unchanged through the
    same call."""
    cfg = get_config("internlm2-1.8b", smoke=True)
    model = Model(cfg, mesh)
    defs = model.param_defs()
    params = model.init_params(0)
    like = pm.abstract(defs)
    n_packed = len(jax.tree.leaves(params))

    mgr = CheckpointManager(str(tmp_path / "legacy"))
    mgr.export_legacy(3, params, defs)
    import json, os
    with open(os.path.join(str(tmp_path / "legacy"), "step_00000003",
                           "manifest.json")) as f:
        manifest = json.load(f)
    assert len(manifest["leaves"]) > n_packed  # views really were split

    step, out = mgr.restore(None, like, defs=defs)
    assert step == 3
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    mgr2 = CheckpointManager(str(tmp_path / "native"))
    mgr2.save(1, params, blocking=True)
    _, out2 = mgr2.restore(None, like, defs=defs)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_true_pre_packing_checkpoint_migrates(tmp_path, mesh):
    """A checkpoint written by an ACTUAL pre-packing model (packed_qkv
    False: wq/wk/wv are siblings of wo, the real legacy flatten order)
    restores bitwise onto the packed schema — per-view init equivalence
    makes the expected result exactly the packed init."""
    cfg = get_config("internlm2-1.8b", smoke=True)
    legacy_model = Model(dataclasses.replace(cfg, packed_qkv=False), mesh)
    packed_model = Model(cfg, mesh)
    legacy_params = legacy_model.init_params(0)
    packed_params = packed_model.init_params(0)

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, legacy_params, blocking=True)  # the pre-PR on-disk layout
    step, out = mgr.restore(None, pm.abstract(packed_model.param_defs()),
                            defs=packed_model.param_defs())
    assert step == 7
    flat_want = jax.tree_util.tree_flatten_with_path(packed_params)[0]
    flat_got = jax.tree.leaves(out)
    assert len(flat_want) == len(flat_got)
    for (path, want), got in zip(flat_want, flat_got):
        np.testing.assert_array_equal(
            np.asarray(want), np.asarray(got),
            err_msg=jax.tree_util.keystr(path))


def test_trainer_resumes_from_pre_packing_checkpoint(tmp_path, mesh):
    """A training run checkpointed under the legacy schema (packed_qkv
    False: separate wq/wk/wv param AND Adam-moment leaves) resumes onto
    the packed schema and keeps training — params and fp32 moments are
    packed in place by the restore migration."""
    from repro.data import DataConfig, SyntheticTokenSource, TokenPipeline
    from repro.optim import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig
    cfg = get_config("internlm2-1.8b", smoke=True)
    dcfg = DataConfig(global_batch=2, seq_len=32)
    src = SyntheticTokenSource(cfg.vocab)

    def trainer(model_cfg, steps):
        model = Model(model_cfg, mesh)
        tcfg = TrainerConfig(steps=steps, ckpt_every=4,
                             ckpt_dir=str(tmp_path), keep=2, log_every=100)
        return Trainer(model, AdamWConfig(lr=1e-3), tcfg,
                       lambda s: TokenPipeline(src, dcfg, mesh, model_cfg,
                                               start_step=s))

    trainer(dataclasses.replace(cfg, packed_qkv=False), 4).run(0)
    t2 = trainer(cfg, 8)  # packed schema resumes the legacy checkpoint
    step, params, opt = t2.restore()
    assert step == 4
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(params)[0]]
    assert any("wqkv" in p for p in paths) and not any(
        "'wq'" in p for p in paths)
    t2.run(0)  # continues training from the migrated state
    assert t2.metrics[-1]["step"] == 7
    assert np.isfinite(t2.metrics[-1]["loss"])


def test_serve_engine_from_legacy_checkpoint(tmp_path, mesh):
    """A legacy checkpoint serves end-to-end through
    ServeEngine.from_checkpoint (migration inside restore)."""
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              compute_dtype="float32")
    model = Model(cfg, mesh)
    params = model.init_params(0)
    CheckpointManager(str(tmp_path)).export_legacy(
        1, params, model.param_defs())
    eng = ServeEngine.from_checkpoint(model, str(tmp_path),
                                      scfg=ServeConfig(max_new_tokens=3))
    ref = ServeEngine(model, params, ServeConfig(max_new_tokens=3))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (2, 8),
                                          0, cfg.vocab, jnp.int32)}
    np.testing.assert_array_equal(eng.generate(batch), ref.generate(batch))


def test_packing_factor_policy():
    """Packing is gcd(q_dim, kv_dim) — a pure function of the arch, never
    of the mesh, so the on-disk packed layout (and therefore checkpoints)
    is identical across model-parallel sizes, and every model degree the
    fused path can use divides it."""
    cfg = _tiny_cfg()  # q_dim 64, kv_dim 32
    assert qkv_packing(cfg) == 32
    cfg2 = _tiny_cfg(n_kv_heads=3, n_heads=6, head_dim=6)  # 36 / 18
    assert qkv_packing(cfg2) == 18
    # the defs carry the same packing no matter the model size passed in
    for model in (1, 2, 4):
        d = attn_defs(cfg, model, "float32", False)["wqkv"]
        assert d.packing == qkv_packing(cfg)
        assert cfg.q_dim % model == 0 and qkv_packing(cfg) % model == 0


def test_packed_layout_mesh_independent():
    """The packed wqkv array is bitwise identical whether initialized for
    a model=1 or model=4 mesh — the elastic-restore guarantee."""
    cfg = _tiny_cfg()
    a1 = pm.initialize({"attn": attn_defs(cfg, 1, "float32", False)},
                       seed=11)["attn"]["wqkv"]
    a4 = pm.initialize({"attn": attn_defs(cfg, 4, "float32", False)},
                       seed=11)["attn"]["wqkv"]
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a4))
