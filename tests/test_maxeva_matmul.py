"""Sharded XYZ matmul correctness, run on an 8-device CPU mesh in a
subprocess (the main test process must keep a single device)."""
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SCRIPT = os.path.join(_ROOT, "tests", "_multidev_checks.py")


def _run(*checks):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run(
        [sys.executable, _SCRIPT, *checks],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ALL_OK" in r.stdout


def test_weight_layout_roundtrip():
    _run("weight_layout_roundtrip")


def test_schedule_equivalence():
    """Registered sweep: bitwise fp32 equality across all four schedules
    (incl. 'bidir_ring') + ref-oracle closeness, both layouts, Y in
    {1, 2, 4}.  The full epilogue grid runs under ``pytest -m multidev``
    (scripts/ci.sh multidev)."""
    _run("schedule_equivalence")


def test_schedule_equivalence_epilogue():
    _run("schedule_equivalence_epilogue")


def test_replicated_out():
    _run("replicated_out")


def test_overlapped_gather_hlo():
    """ksharded Z>1 Y>1: no barrier all-gather of A in the compiled HLO
    (the chunked ppermute gather replaced it)."""
    _run("overlapped_gather_hlo")


def test_xyz_epilogue():
    _run("xyz_epilogue")


def test_grads():
    _run("grads")


def test_mlp_composition():
    _run("mlp_composition")


def test_collective_bytes_ordering():
    _run("collective_bytes_ordering")


# ---------------------------------------------------------------------------
# config validation (pure; no mesh needed)
# ---------------------------------------------------------------------------

def test_unknown_schedule_raises():
    """A typo like 'ring ' must raise, not silently run some default
    schedule (the regression this pins: the old if/elif chain fell
    through for Y == 1 and the model==1 path never looked at the
    string)."""
    from repro.core.maxeva_matmul import SCHEDULES, XYZConfig
    for bad in ("ring ", "Ring", "reduce-scatter", "none", "", "bidir"):
        with pytest.raises(ValueError, match="schedule"):
            XYZConfig(y=2, schedule=bad)
    for good in SCHEDULES:
        XYZConfig(y=2, schedule=good)  # all four construct cleanly


def test_unknown_x_layout_raises():
    from repro.core.maxeva_matmul import X_LAYOUTS, XYZConfig
    for bad in ("replicatedd", "k_sharded", "KSHARDED", ""):
        with pytest.raises(ValueError, match="x_layout"):
            XYZConfig(y=2, x_layout=bad)
    for good in X_LAYOUTS:
        XYZConfig(y=2, x_layout=good)


def test_dataclasses_replace_revalidates():
    """dataclasses.replace re-runs __post_init__, so a plan mutated with
    a bad schedule string still fails loudly."""
    import dataclasses
    from repro.core.maxeva_matmul import XYZConfig
    cfg = XYZConfig(y=2, schedule="bidir_ring")
    with pytest.raises(ValueError, match="schedule"):
        dataclasses.replace(cfg, schedule="ringg")
