"""Sharded XYZ matmul correctness, run on an 8-device CPU mesh in a
subprocess (the main test process must keep a single device)."""
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SCRIPT = os.path.join(_ROOT, "tests", "_multidev_checks.py")


def _run(*checks):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run(
        [sys.executable, _SCRIPT, *checks],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ALL_OK" in r.stdout


def test_weight_layout_roundtrip():
    _run("weight_layout_roundtrip")


def test_xyz_forward_all_schedules():
    _run("xyz_forward_all_schedules")


def test_replicated_out():
    _run("replicated_out")


def test_ring_bitwise_matches_reduce_scatter():
    _run("ring_bitwise_matches_reduce_scatter")


def test_xyz_epilogue():
    _run("xyz_epilogue")


def test_grads():
    _run("grads")


def test_mlp_composition():
    _run("mlp_composition")


def test_collective_bytes_ordering():
    _run("collective_bytes_ordering")
