"""End-to-end int8 serving path.

Covers the four layers of the quantized decode stack:
  * kernel: the int8 x int8 -> int32 Pallas GEMM with row/col scales
    folded into the fused epilogue, against the jnp oracle;
  * weight pass: ``Model.quantize_params_for_serving`` (column-wise
    scales, the ROADMAP column-wise quantize) — coverage, skips,
    idempotence;
  * numerics: int8 decode against the f64-referenced consistency-budget
    machinery from PR 2, under the documented WIDER int8 budget (see
    ``test_int8_decode_consistency``);
  * HLO: ``int8_bounce_count == 0`` (no fp32 dequant -> requant between
    GEMMs), single packed-QKV GEMM dispatch preserved, and a regression
    proof that a deliberately-bounced fp32 layer trips the detector.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.kernels.epilogue import Epilogue
from repro.kernels.quantize import QuantizedWeight, quantize_weight_colwise
from repro.launch.hlo_analysis import (
    gemm_dispatches,
    int8_bounce_count,
    weight_concat_count,
)
from repro.launch.mesh import make_mesh
from repro.models.lm import Model

SEQ = 32


@pytest.fixture(scope="module")
def mesh():
    return make_mesh(1, 1)


# ---------------------------------------------------------------------------
# kernel: int8 GEMM with scales in the fused epilogue
# ---------------------------------------------------------------------------

_EPS = [
    None,
    Epilogue(bias=True, activation="gelu", out_dtype=jnp.bfloat16),
    Epilogue(activation="silu", out_dtype=jnp.bfloat16),
    Epilogue(quantize=True),                        # rowwise (q, scale)
    Epilogue(quantize=True, quantize_axis="col"),   # colwise (weight-grad)
]


@pytest.mark.parametrize("mkn", [(8, 16, 8), (33, 70, 52), (1, 128, 64),
                                 (100, 130, 70)])
@pytest.mark.parametrize("ep", _EPS,
                         ids=["id", "bias_gelu", "silu", "qrow", "qcol"])
def test_int8_matmul_interpret_matches_ref(mkn, ep):
    m, k, n = mkn
    ka, kb, kc = jax.random.split(jax.random.PRNGKey(m + n), 3)
    a = jax.random.normal(ka, (m, k), jnp.float32)
    w = jax.random.normal(kb, (k, n), jnp.float32)
    bias = jax.random.normal(kc, (n,), jnp.float32)
    qa, sa = ref.quantize_rowwise_ref(a)
    qb, sb = ref.quantize_colwise_ref(w)
    kwargs = dict(bias=bias) if (ep is not None and ep.bias) else {}
    want = ops.int8_matmul(qa, sa, qb, sb, mode="xla", epilogue=ep,
                           **kwargs)
    got = ops.int8_matmul(qa, sa, qb, sb, mode="interpret",
                          block=(16, 16, 16), epilogue=ep, **kwargs)
    if isinstance(want, tuple):
        for g, wnt in zip(got, want):
            assert g.shape == wnt.shape and g.dtype == wnt.dtype
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(wnt, np.float32),
                                       rtol=1e-5, atol=1e-5)
    else:
        assert got.dtype == want.dtype
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-4, atol=1e-4)


def test_int8_matmul_accuracy_vs_float():
    """The full quantize -> int8 GEMM -> rescale pipeline sits within int8
    noise of the float product (paper §IV-C1 pipeline)."""
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 96), jnp.float32)
    qw = quantize_weight_colwise(w)
    got = np.asarray(ops.matmul(a, qw, mode="xla"))
    want = np.asarray(a @ w)
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 0.03, rel


def test_quantize_colwise_matches_transposed_rowwise():
    w = jax.random.normal(jax.random.PRNGKey(2), (70, 52), jnp.float32)
    q, s = ops.quantize_colwise(w, mode="interpret")
    qt, st = ref.quantize_rowwise_ref(w.T)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qt.T))
    np.testing.assert_allclose(np.asarray(s), np.asarray(st.reshape(1, -1)),
                               rtol=1e-6)
    # per-column round-trip bound
    back = np.asarray(q, np.float32) * np.asarray(s)
    absmax = np.max(np.abs(np.asarray(w)), axis=0, keepdims=True)
    assert np.all(np.abs(back - np.asarray(w)) <= absmax / 127.0 + 1e-6)


def test_quantized_weight_stacked_leading_axes():
    """Group-stacked weights ([G, K, N]) quantize with lockstep leading
    axes on q and scale, so a lax.scan slices both together."""
    w = jax.random.normal(jax.random.PRNGKey(3), (3, 16, 24), jnp.float32)
    qw = quantize_weight_colwise(w)
    assert qw.q.shape == (3, 16, 24) and qw.scale.shape == (3, 1, 24)
    leaves, treedef = jax.tree_util.tree_flatten(qw)
    assert len(leaves) == 2  # registered pytree: jit/scan can carry it
    one = jax.tree_util.tree_unflatten(
        treedef, [l[1] for l in leaves])
    got, want = one.as_matrix(), quantize_weight_colwise(w[1]).as_matrix()
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]))


# ---------------------------------------------------------------------------
# the one-shot serving weight-quantization pass
# ---------------------------------------------------------------------------

def _quantized_paths(tree, path=""):
    out = []
    if isinstance(tree, dict):
        for k, v in tree.items():
            out += _quantized_paths(v, f"{path}/{k}")
    elif isinstance(tree, QuantizedWeight):
        out.append(path)
    return out


def test_quantize_pass_coverage_and_skips(mesh):
    cfg = get_config("whisper-small", smoke=True)
    model = Model(cfg, mesh)
    params = model.init_params(0)
    qparams = model.quantize_params_for_serving(params)
    paths = _quantized_paths(qparams)
    # decoder-stack projections are quantized ...
    assert any("/attn/wqkv" in p for p in paths)
    assert any("/attn/wo" in p for p in paths)
    assert any("/ffn/up" in p for p in paths)
    # ... while embeddings, norms, cross-attention and the encoder stay fp
    assert not any("/xattn/" in p for p in paths)
    assert not any("/encoder/" in p for p in paths)
    assert not isinstance(qparams["embed"], QuantizedWeight)
    assert not isinstance(qparams["final_norm"], QuantizedWeight)
    # idempotent: a second pass is a no-op
    q2 = model.quantize_params_for_serving(qparams)
    assert _quantized_paths(q2) == paths


# ---------------------------------------------------------------------------
# numerics: int8 decode vs the f64-referenced budget (PR 2 machinery)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["internlm2-1.8b", "whisper-small"])
def test_int8_decode_consistency(arch, mesh):
    """Int8 decode against the f64 reference, under the int8 budget.

    Budget policy (the int8 extension of PR 2's, recorded in ROADMAP):
    the f64 reference stays the FULL-PRECISION model — quantization error
    is part of the measured path, not the reference — and the reference
    noise becomes the int8 *teacher-forced forward* error ``err_fwd8``
    (the same quantized GEMM chain run without a cache).  Decode must
    land within 4x that, plus the fp32-ulp floor: identical structure to
    the fp32 policy, with the quantization noise measured rather than
    hand-tuned.  The absolute sanity bound is WIDER than fp32's
    (err_fwd8 < 5% of logit scale vs the fp path's rounding-level error):
    that 1-2% is the int8 pipeline's real, irreducible quantization
    noise."""
    from test_archs_smoke import _batch, f64_reference_logits
    from repro.models.loss import vocab_parallel_logits

    cfg = get_config(arch, smoke=True)
    model = Model(cfg, mesh)
    params = model.init_params(0)
    qparams = model.quantize_params_for_serving(params)
    batch = _batch(cfg)

    logits_p, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=SEQ + 8))(qparams, batch)
    tok = jnp.argmax(logits_p[:, :cfg.vocab], axis=-1)[:, None] \
        .astype(jnp.int32)
    logits_d, _ = jax.jit(model.decode_step)(
        qparams, cache, tok, jnp.asarray(SEQ, jnp.int32))
    assert bool(jnp.all(jnp.isfinite(logits_d)))

    full = jnp.concatenate([batch["tokens"], tok], axis=1)
    fbatch = dict(batch, tokens=full)
    h8, _, _ = jax.jit(lambda p, b: model.forward(p, b, mode="train"))(
        qparams, fbatch)
    ref8 = vocab_parallel_logits(h8[:, -1:], model.head_weights(qparams),
                                 model.ctx, cfg.final_softcap)[:, 0]
    ref64 = f64_reference_logits(cfg, params, fbatch, mesh)

    scale = max(1.0, float(np.max(np.abs(ref64))))
    err_fwd8 = float(np.max(np.abs(np.asarray(ref8, np.float64) - ref64)))
    err_dec8 = float(np.max(np.abs(np.asarray(logits_d, np.float64)
                                   - ref64)))
    # the quantized pipeline itself must sit within int8 noise of f64
    assert err_fwd8 < 0.05 * scale, (err_fwd8, scale)
    budget = 4.0 * err_fwd8 + 64 * np.finfo(np.float32).eps * scale
    assert err_dec8 <= budget, (
        f"int8 decode drifted from the f64 reference: "
        f"err_dec8={err_dec8:.3e} > budget={budget:.3e} "
        f"(err_fwd8={err_fwd8:.3e})")


# ---------------------------------------------------------------------------
# HLO guards: zero fp32 bounces, packed-QKV invariant preserved
# ---------------------------------------------------------------------------

def _int8_decode_hlo(cfg, mesh):
    model = Model(cfg, mesh)
    qparams = model.quantize_params_for_serving(model.init_params(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=24))(
        qparams, batch)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.asarray(16, jnp.int32)
    fn = jax.jit(model.decode_step)
    return fn.lower(qparams, cache, tok, pos).compile().as_text(), model


def test_int8_decode_hlo_no_bounce_single_qkv_dispatch(mesh):
    """Acceptance: traced int8 decode HLO has ZERO fp32 dequant->requant
    round trips between GEMMs, exactly one packed-QKV GEMM dispatch per
    traced attention apply (the scanned group body appears once), and no
    apply-time weight-shard concatenate."""
    cfg = get_config("internlm2-1.8b", smoke=True)
    # keep the packed-QKV width unique in the module (the smoke config's
    # d_ff collides with q_dim + 2*kv_dim, which would overcount dots)
    cfg = dataclasses.replace(cfg, d_ff=96)
    packed_cols = cfg.q_dim + 2 * cfg.kv_dim
    assert packed_cols not in (cfg.d_model, cfg.d_ff, cfg.padded_vocab())

    hlo, model = _int8_decode_hlo(cfg, mesh)
    assert int8_bounce_count(hlo) == 0
    # layers run as a scanned group: the body (and its single QKV dot) is
    # traced ONCE for the whole stack
    assert gemm_dispatches(hlo, packed_cols) == 1
    assert weight_concat_count(hlo, cfg.d_model) == 0


def test_int8_prefill_hlo_has_no_bounce(mesh):
    """Prefill shares the quantized weights; it must not bounce either."""
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              d_ff=96)
    model = Model(cfg, mesh)
    qparams = model.quantize_params_for_serving(model.init_params(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    fn = jax.jit(lambda p, b: model.prefill(p, b, max_len=24))
    hlo = fn.lower(qparams, batch).compile().as_text()
    assert int8_bounce_count(hlo) == 0


def test_bounce_detector_trips_on_deliberate_bounce():
    """Regression guard: the naive implementation — dequantize the int8
    activations to fp32, run a float GEMM, requantize — produces exactly
    the dequant-feeds-a-dot HLO signature the detector counts."""
    def bounced(qx, sx, w):
        x = ops.dequantize_rowwise(qx, sx)          # s8 -> f32 bounce
        y = x @ w                                   # fp32 GEMM consumes it
        return ref.quantize_rowwise_ref(y)          # ... and requantizes

    qx = jnp.ones((4, 64), jnp.int8)
    sx = jnp.ones((4, 1), jnp.float32)
    w = jnp.ones((64, 32), jnp.float32)
    hlo = jax.jit(bounced).lower(qx, sx, w).compile().as_text()
    assert int8_bounce_count(hlo) >= 1

    # the clean pipeline over the same operands reports zero
    def clean(qx, sx, w):
        qw = quantize_weight_colwise(w)
        return ops.int8_matmul(qx, sx, *qw.as_matrix(), mode="xla",
                               epilogue=Epilogue(quantize=True))
    hlo2 = jax.jit(clean).lower(qx, sx, w).compile().as_text()
    assert int8_bounce_count(hlo2) == 0


def test_int8_gated_mlp_fused_handoff_hlo(mesh):
    """The gated int8 MLP's fused (q, scale) handoff, proven in traced
    HLO: exactly ONE standalone rowwise quantize (the shared input — the
    up GEMM's requantize lives in its store phase), zero fp dequant ->
    requant bounces between the up and down GEMMs, no unfused
    ``silu(g) * u`` multiply, and the down GEMM's residual + rmsnorm
    fold is the module's only norm (fused, not standalone)."""
    from repro.analysis.hlo_graph import parse_hlo
    from repro.analysis.passes import run_passes
    from repro.models.layers import TPCtx, _mlp_apply_int8

    d, dff = 64, 96
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    params = {
        "gate": quantize_weight_colwise(
            jax.random.normal(keys[0], (d, dff), jnp.float32) / 8),
        "up": quantize_weight_colwise(
            jax.random.normal(keys[1], (d, dff), jnp.float32) / 8),
        "down": quantize_weight_colwise(
            jax.random.normal(keys[2], (dff, d), jnp.float32) / 8),
    }
    x = jax.random.normal(keys[3], (4, d), jnp.bfloat16)
    res = jax.random.normal(keys[4], (4, d), jnp.bfloat16)
    nsc = jnp.zeros((d,), jnp.float32)
    ctx = TPCtx(mesh=make_mesh(1, 1), sp=False)

    def f(params, x, res, nsc):
        return _mlp_apply_int8(params, x, ctx, True, residual=res,
                               norm_scale=nsc)

    hlo = jax.jit(f).lower(params, x, res, nsc).compile().as_text()
    # dtype flow: no fp32 bounce anywhere; the only GEMMs at the d_ff
    # width are the gate and up dispatches (the requantize is fused)
    assert int8_bounce_count(hlo) == 0
    assert gemm_dispatches(hlo, dff) == 2
    findings, metrics = run_passes(parse_hlo(hlo), dict(
        expect_standalone_rmsnorm=0,
        forbid_unfused_gate_mul=True,
        expect_standalone_quantize=1))
    errors = [fi for fi in findings if fi.severity == "error"]
    assert not errors, [fi.format() for fi in errors]
    assert metrics["standalone_quantize_sites"] == 1
    assert metrics["unfused_gate_mul_sites"] == 0
    assert metrics["standalone_rmsnorm_sites"] == 0
    assert metrics["fused_rmsnorm_sites"] == 1


# ---------------------------------------------------------------------------
# serving engine integration
# ---------------------------------------------------------------------------

def test_serve_engine_int8_from_checkpoint(tmp_path, mesh):
    """from_checkpoint + ServeConfig(int8=True): restore fp weights, run
    the one-shot quantization pass, generate."""
    from repro.checkpoint import CheckpointManager
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = get_config("internlm2-1.8b", smoke=True)
    model = Model(cfg, mesh)
    params = model.init_params(0)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, params, blocking=True)

    eng = ServeEngine.from_checkpoint(
        model, str(tmp_path), scfg=ServeConfig(max_new_tokens=4,
                                               int8=True))
    assert any(_quantized_paths(eng.params))
    prompt = {"tokens": (jnp.arange(2 * 16, dtype=jnp.int32)
                         .reshape(2, 16) % cfg.vocab)}
    out = eng.generate(prompt)
    assert out.shape == (2, 4)
    assert np.all((out >= 0) & (out < cfg.vocab))

    # greedy int8 decode agrees with the fp engine on this tiny model
    fp = ServeEngine(model, params, ServeConfig(max_new_tokens=4))
    np.testing.assert_array_equal(out, fp.generate(prompt))


# ---------------------------------------------------------------------------
# precision-aware planner / perf-model costs
# ---------------------------------------------------------------------------

def test_int8_cost_models():
    from repro.core.perf_model import (gemm_arithmetic_intensity,
                                       int8_serving_savings)
    from repro.core.planner import int8_gemm_hbm_bytes, plan_tpu_block, \
        plan_tpu_shard

    m, k, n = 128, 2048, 8192
    assert int8_gemm_hbm_bytes(m, k, n, fused=True) < \
        int8_gemm_hbm_bytes(m, k, n, fused=False)
    sav = int8_serving_savings(m, k, n)
    # deleting the dequant round trips buys > 4x HBM bytes on a
    # weight-dominated decode GEMM
    assert sav["hbm_speedup"] > 4.0
    assert sav["compute_speedup"] >= 4.0
    ai8 = gemm_arithmetic_intensity(m, k, n, "int8", out_itemsize=1)
    assert ai8 > gemm_arithmetic_intensity(m, k, n, "bf16")
    assert ai8 > gemm_arithmetic_intensity(m, k, n, "fp32")

    blk = plan_tpu_block(512, 2048, 8192, "int8")
    assert blk.bm % 8 == 0 and blk.bk % 128 == 0 and blk.bn % 128 == 0
    # schedule choice is precision-aware: both precisions produce a valid
    # plan over the same mesh, with the int8 plan seeing 4x the intensity
    p8 = plan_tpu_shard(m, k, n, "int8", {"data": 1, "model": 4})
    pf = plan_tpu_shard(m, k, n, "fp32", {"data": 1, "model": 4})
    assert p8.est_hbm_s < pf.est_hbm_s
    assert p8.y_shards * p8.z_shards == 4
