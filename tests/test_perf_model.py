"""Validate the analytical performance model against every number the
paper reports (Tables I-III, Fig. 8, CHARM comparisons)."""
import pytest

from repro.core.planner import ArrayConfig
from repro.core import perf_model as pm

CONFIGS = [(13, 4, 6), (10, 3, 10), (11, 4, 7), (11, 3, 9), (12, 4, 6),
           (12, 3, 8)]


# --- Table I ---------------------------------------------------------------

def test_table1_int8_matmul_kernel():
    t = pm.kernel_tile("int8")
    assert t.as_tuple() == (32, 128, 32)
    assert pm.matmul_kernel_cycles(t, "int8") == 1075
    assert pm.matmul_kernel_efficiency(t, "int8") == pytest.approx(0.9526, abs=1e-3)


def test_table1_fp32_matmul_kernel():
    t = pm.kernel_tile("fp32")
    assert t.as_tuple() == (32, 32, 32)
    assert pm.matmul_kernel_cycles(t, "fp32") == 4329
    # paper reports 94.70% (from the rounded 7.57 MACs/cyc); the exact
    # latency 4329 gives 94.62% -- accept both roundings.
    assert pm.matmul_kernel_efficiency(t, "fp32") == pytest.approx(0.947, abs=2e-3)


def test_table1_add_kernels():
    assert pm.add_kernel_cycles(32, 32, "int8") == 164
    assert pm.add_kernel_cycles(32, 32, "fp32") == 167
    assert pm.add_kernel_efficiency(32, 32, "int8") == pytest.approx(0.7805, abs=1e-3)
    assert pm.add_kernel_efficiency(32, 32, "fp32") == pytest.approx(0.7665, abs=1e-3)


def test_adder_tree_latency_below_matmul_latency():
    """§IV-B/V-A: the whole (Y-1)-adder tree on one core is faster than one
    MatMul kernel, for both precisions and Y in {3, 4}."""
    for prec in ("int8", "fp32"):
        mm = pm.matmul_kernel_cycles(pm.kernel_tile(prec), prec)
        for y in (3, 4):
            assert pm.adder_tree_cycles(y, 32, 32, prec) < mm


# --- Tables II / III -------------------------------------------------------

@pytest.mark.parametrize("prec,tol", [("fp32", 0.01), ("int8", 0.01)])
def test_throughput_reproduces_paper_tables(prec, tol):
    for (x, y, z) in CONFIGS:
        d = pm.evaluate_design(ArrayConfig(x, y, z), prec)
        paper = pm.PAPER_THROUGHPUT[(prec, x, y, z)]
        assert d.throughput == pytest.approx(paper, rel=tol), (prec, x, y, z)


@pytest.mark.parametrize("prec,tol", [("fp32", 0.01), ("int8", 0.015)])
def test_power_reproduces_paper_tables(prec, tol):
    # int8 10x3x10 is the paper's internally inconsistent row (core 47.44 +
    # memory 19.08 != reported total 65.52); 1.5% tolerance absorbs it.
    for (x, y, z) in CONFIGS:
        d = pm.evaluate_design(ArrayConfig(x, y, z), prec)
        paper = pm.PAPER_TOTAL_POWER_W[(prec, x, y, z)]
        assert d.total_power_w == pytest.approx(paper, rel=tol), (prec, x, y, z)


# --- Headline claims --------------------------------------------------------

def test_claim_fp32_throughput_gain_over_charm():
    best = pm.evaluate_design(ArrayConfig(13, 4, 6), "fp32")
    gain = best.throughput / pm.CHARM["fp32"]["throughput_gflops"]
    assert gain == pytest.approx(1.208, abs=0.01)   # +20.8%


def test_claim_fp32_energy_gain_over_charm():
    best = pm.evaluate_design(ArrayConfig(13, 4, 6), "fp32")
    gain = best.energy_eff / pm.CHARM["fp32"]["energy_eff"]
    assert gain == pytest.approx(1.204, abs=0.01)   # +20.4%


def test_claim_int8_throughput_gain_over_charm():
    best = pm.evaluate_design(ArrayConfig(13, 4, 6), "int8")
    gain = best.throughput / pm.CHARM["int8"]["throughput_tops"]
    assert gain == pytest.approx(2.19, abs=0.02)    # 2.19x


def test_claim_peak_numbers():
    fp32 = pm.evaluate_design(ArrayConfig(13, 4, 6), "fp32")
    int8 = pm.evaluate_design(ArrayConfig(13, 4, 6), "int8")
    assert fp32.throughput == pytest.approx(5442.11, rel=0.01)  # 5.44 TFLOPs
    assert int8.throughput == pytest.approx(77.01, rel=0.01)    # 77.01 TOPs
    assert fp32.energy_eff == pytest.approx(124.16, rel=0.01)   # GFLOPs/W


def test_claim_mlp_inference_gain():
    # §V-B4: +29% over CHARM on the MLP from [19].
    ratio = (pm.CHARM["mlp_fp32"]["maxeva_gflops"]
             / pm.CHARM["mlp_fp32"]["charm_gflops"])
    assert ratio == pytest.approx(1.29, abs=0.01)


# --- Fig. 8 -----------------------------------------------------------------

def test_fig8_monotone_convergence():
    cfg = ArrayConfig(13, 4, 6)
    sizes = [256, 512, 1024, 2048, 4096, 8192]
    tputs = [pm.throughput_vs_size(s, cfg, "fp32") for s in sizes]
    assert all(b >= a - 1e-6 for a, b in zip(tputs, tputs[1:]))
    peak = pm.design_throughput(cfg, "fp32")
    # >= 2K x 2K: "almost peak performance" (§V-B4)
    assert tputs[3] / peak > 0.93
    assert tputs[0] / peak < 0.5  # small sizes heavily padded


def test_fig8_int8():
    cfg = ArrayConfig(13, 4, 6)
    peak = pm.design_throughput(cfg, "int8")
    assert pm.throughput_vs_size(4096, cfg, "int8") / peak > 0.93
