"""Substrate tests: data determinism/resume, checkpoint roundtrip +
atomicity + corruption detection, trainer fault tolerance + straggler
watchdog, optimizer semantics, serving consistency, HLO analyzer."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokenSource, TokenPipeline
from repro.checkpoint import CheckpointManager
from repro.launch.mesh import make_mesh
from repro.models.lm import Model
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def mesh():
    return make_mesh(1, 1)


# -- data ---------------------------------------------------------------------

def test_data_deterministic_and_resumable(mesh):
    cfg = DataConfig(global_batch=4, seq_len=16, seed=3)
    src = SyntheticTokenSource(vocab=100, seed=3)
    p1 = TokenPipeline(src, cfg, mesh)
    first = [next(p1) for _ in range(5)]
    p1.close()
    # resume at step 3: identical stream
    p2 = TokenPipeline(src, cfg, mesh, start_step=3)
    s, b = next(p2)
    p2.close()
    assert s == 3
    np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                  np.asarray(first[3][1]["tokens"]))
    # targets are tokens shifted by one
    np.testing.assert_array_equal(np.asarray(first[0][1]["tokens"])[:, 1:],
                                  np.asarray(first[0][1]["targets"])[:, :-1])


def test_data_tokens_in_vocab(mesh):
    cfg = DataConfig(global_batch=2, seq_len=8)
    src = SyntheticTokenSource(vocab=50)
    p = TokenPipeline(src, cfg, mesh)
    _, b = next(p)
    p.close()
    assert int(jnp.max(b["tokens"])) < 50
    assert int(jnp.min(b["tokens"])) >= 0


# -- checkpoint ------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)}}
    mgr.save(5, tree, blocking=True)
    step, out = mgr.restore(None, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    assert step == 5
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3):
        mgr.save(s, tree, blocking=True)
    assert mgr.all_steps() == [2, 3]
    assert mgr.latest_step() == 3


def test_checkpoint_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.arange(4.0)}
    mgr.save(1, tree, blocking=True)
    # corrupt the array file
    f = os.path.join(str(tmp_path), "step_00000001", "arr_0.npy")
    arr = np.load(f)
    arr[0] = 999.0
    np.save(f, arr)
    with pytest.raises(IOError):
        mgr.restore(None, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))


def test_checkpoint_tmp_dir_ignored(tmp_path):
    """A crash mid-write leaves a .tmp dir that restore must ignore."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.zeros((2,))}
    mgr.save(1, tree, blocking=True)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert mgr.latest_step() == 1


# -- optimizer ---------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_opt_state(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_int8_state_tracks_fp32():
    k = jax.random.PRNGKey(0)
    w0 = jax.random.normal(k, (16, 64))
    tgt = jax.random.normal(jax.random.PRNGKey(1), (16, 64))

    def run(mode):
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0, grad_clip=0.0,
                          state_mode=mode)
        params = {"w": w0}
        state = init_opt_state(params, cfg)
        for _ in range(100):
            grads = {"w": params["w"] - tgt}
            params, state = adamw_update(params, grads, state, cfg)
        return float(jnp.mean((params["w"] - tgt) ** 2))

    fp32 = run("fp32")
    int8 = run("int8")
    assert fp32 < 1e-2
    assert int8 < 5e-2  # quantized moments still converge


def test_grad_accumulation_equivalence(mesh):
    """1 big batch == mean of microbatches (up to fp tolerance)."""
    from repro.train.step import make_train_step
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              compute_dtype="float32")
    model = Model(cfg, mesh)
    params = model.init_params(0)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = init_opt_state(params, opt_cfg)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(k1, (4, 16), 0, cfg.vocab,
                                          jnp.int32),
             "targets": jax.random.randint(k2, (4, 16), 0, cfg.vocab,
                                           jnp.int32)}
    p1, _, m1 = jax.jit(make_train_step(model, opt_cfg, 1))(params, opt,
                                                            batch)
    p2, _, m2 = jax.jit(make_train_step(model, opt_cfg, 2))(params, opt,
                                                            batch)
    # losses per microbatch average to the full-batch value only when the
    # token counts match per microbatch (they do here)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-3


# -- trainer fault tolerance ----------------------------------------------------------

def _tiny_trainer(tmp_path, mesh, steps=12, fail_at=None):
    cfg = get_config("internlm2-1.8b", smoke=True)
    model = Model(cfg, mesh)
    opt_cfg = AdamWConfig(lr=1e-3)
    tcfg = TrainerConfig(steps=steps, ckpt_every=4,
                         ckpt_dir=str(tmp_path), keep=2, log_every=100,
                         fail_at_step=fail_at)
    dcfg = DataConfig(global_batch=2, seq_len=32)
    src = SyntheticTokenSource(cfg.vocab)

    def factory(start):
        return TokenPipeline(src, dcfg, mesh, cfg, start_step=start)

    return Trainer(model, opt_cfg, tcfg, factory)


def test_trainer_loss_decreases(tmp_path, mesh):
    tr = _tiny_trainer(tmp_path, mesh, steps=30)
    tr.run(0)
    losses = [m["loss"] for m in tr.metrics]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    assert all(np.isfinite(losses))


def test_trainer_recovers_from_injected_failure(tmp_path, mesh):
    tr = _tiny_trainer(tmp_path, mesh, steps=10, fail_at=6)
    tr.run(0)
    steps_seen = [m["step"] for m in tr.metrics]
    # step 6 failed once, trainer restored from the step-4 checkpoint and
    # re-ran 4..9
    assert steps_seen.count(5) == 2
    assert steps_seen[-1] == 9
    assert tr.ckpt.latest_step() == 10


def test_trainer_resume_matches_uninterrupted(tmp_path, mesh):
    """checkpoint/restart must land on the same trajectory."""
    a = _tiny_trainer(os.path.join(tmp_path, "a"), mesh, steps=8)
    pa, _ = a.run(0)
    b = _tiny_trainer(os.path.join(tmp_path, "b"), mesh, steps=4)
    b.run(0)
    b2 = _tiny_trainer(os.path.join(tmp_path, "b"), mesh, steps=8)
    pb, _ = b2.run(0)
    d = max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(
            x.astype(jnp.float32) - y.astype(jnp.float32)))), pa, pb)))
    assert d < 2e-2, d


def test_straggler_watchdog_flags_slow_steps():
    from repro.train.trainer import StragglerWatchdog
    wd = StragglerWatchdog(factor=3.0, alpha=0.2)
    for s in range(10):
        wd.observe(s, 0.1)
    assert not wd.events
    wd.observe(10, 1.0)  # 10x slower
    assert len(wd.events) == 1 and wd.events[0]["step"] == 10


# -- serving ------------------------------------------------------------------------

def test_serve_engine_greedy_matches_manual_decode(mesh):
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              compute_dtype="float32")
    model = Model(cfg, mesh)
    params = model.init_params(0)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (2, 16),
                                          0, cfg.vocab, jnp.int32)}
    eng = ServeEngine(model, params, ServeConfig(max_new_tokens=4))
    out = eng.generate(batch)
    assert out.shape == (2, 4)
    # manual: teacher-forced forward over prompt+generated must reproduce
    # the same greedy choices
    toks = batch["tokens"]
    for i in range(3):
        full = jnp.concatenate([toks, jnp.asarray(out[:, :i + 1])], axis=1)
        h, _, _ = model.forward(params, {"tokens": full}, mode="train")
        from repro.models.loss import vocab_parallel_logits
        ref = vocab_parallel_logits(h[:, -1:], model.head_weights(params),
                                    model.ctx)[:, 0, :cfg.vocab]
        np.testing.assert_array_equal(np.argmax(np.asarray(ref), -1),
                                      out[:, i + 1])


# -- packed-view sharding ------------------------------------------------------------

def test_packed_qkv_specs_match_views_2d_mesh():
    """param.specs / param.abstract on a packed def agree with the
    unpacked per-view schema under the 2D (data x model) mesh mapping:
    same PartitionSpecs, same logical shapes, and each model-column shard
    of the packed array is exactly [wq_i | wk_i | wv_i]."""
    from repro.configs.base import ArchConfig
    from repro.models import param as pm
    from repro.models.attention import attn_defs
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=32,
                     n_heads=8, n_kv_heads=4, head_dim=8, d_ff=64,
                     vocab=100)
    MODEL = 4
    for fsdp in (False, True):
        packed = attn_defs(cfg, MODEL, "bfloat16", fsdp)
        legacy = attn_defs(cfg, MODEL, "bfloat16", fsdp, packed=False)
        d = packed["wqkv"]
        assert d.packing % MODEL == 0  # mesh-independent G refines m
        views = pm.view_defs(d)
        for name in ("wq", "wk", "wv"):
            assert views[name].spec == legacy[name].spec, (name, fsdp)
            assert views[name].shape == legacy[name].shape
            assert views[name].dtype == legacy[name].dtype
        # abstract trees line up (packed leaf vs per-view leaves)
        ab_p = pm.abstract({"a": d})["a"]
        ab_l = pm.abstract(views)
        assert ab_p.shape[-1] == sum(s.shape[-1] for s in ab_l.values())
        assert pm.specs({"a": d})["a"] == legacy["wq"].spec
        # shard alignment, for EVERY model size m dividing G: column block
        # i split with the local interleave G/m yields exactly the views'
        # i-th column shards (the property the fused SP body relies on,
        # and what makes the layout mesh-independent)
        arr = np.arange(np.prod(d.shape), dtype=np.float32).reshape(d.shape)
        vs = {k: np.asarray(v) for k, v in pm.split_views(d, arr).items()}
        for m in (1, 2, MODEL, d.packing):
            L = d.shape[-1] // m
            qloc, kvloc = cfg.q_dim // m, cfg.kv_dim // m
            for i in range(m):
                shard = arr[:, i * L:(i + 1) * L]
                ql, kl, vl = pm.split_packed_columns(
                    shard, (qloc, kvloc, kvloc), d.packing // m)
                np.testing.assert_array_equal(
                    ql, vs["wq"][:, i * qloc:(i + 1) * qloc])
                np.testing.assert_array_equal(
                    kl, vs["wk"][:, i * kvloc:(i + 1) * kvloc])
                np.testing.assert_array_equal(
                    vl, vs["wv"][:, i * kvloc:(i + 1) * kvloc])


def test_packed_defs_survive_group_stacking():
    """_stack_defs keeps views/packing (the scanned-group schema packs the
    same way), and initialization of stacked packed defs splits back to
    per-view arrays of the right shape."""
    from repro.configs import get_config
    from repro.models import param as pm
    from repro.models.lm import Model, _stack_defs
    from repro.models.attention import attn_defs
    cfg = get_config("internlm2-1.8b", smoke=True)
    defs = _stack_defs({"attn": attn_defs(cfg, 1, "float32", False)}, 3)
    d = defs["attn"]["wqkv"]
    assert d.views is not None and d.shape[0] == 3
    arr = pm.initialize(defs, 0)["attn"]["wqkv"]
    views = pm.split_views(d, arr)
    assert views["wq"].shape == (3, cfg.d_model, cfg.q_dim)
    assert views["wk"].shape == (3, cfg.d_model, cfg.kv_dim)


# -- HLO analyzer ------------------------------------------------------------------

def test_hlo_analyzer_counts_loop_trips():
    from repro.launch.hlo_analysis import analyze_hlo

    def body(c, w):
        return jnp.tanh(c @ w), None

    w = jnp.zeros((8, 64, 64))
    x = jnp.zeros((64, 64))
    scanned = jax.jit(lambda x, w: jax.lax.scan(body, x, w)[0])
    txt = scanned.lower(x, w).compile().as_text()
    out = analyze_hlo(txt)
    assert out["flops"] == 8 * 2 * 64 ** 3


def test_hlo_analyzer_nested_loops():
    from repro.launch.hlo_analysis import analyze_hlo

    def inner(c, w):
        return jnp.tanh(c @ w), None

    def outer(c, ws):
        c2, _ = jax.lax.scan(inner, c, ws)
        return c2, None

    x = jnp.zeros((32, 32))
    ws = jnp.zeros((3, 5, 32, 32))
    f = jax.jit(lambda x, ws: jax.lax.scan(outer, x, ws)[0])
    out = analyze_hlo(f.lower(x, ws).compile().as_text())
    assert out["flops"] == 3 * 5 * 2 * 32 ** 3
