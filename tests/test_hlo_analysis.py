"""Negative-path tests for ``launch/hlo_analysis``.

The detectors (``int8_bounce_count``, ``gemm_dispatches``,
``weight_concat_count``) are CI gates: a false positive blocks a good PR
and a false negative lets a regression ship.  These tests pin down the
must-NOT-fire cases: HLO with zero dots, nested ``while`` loops, and the
chunked-gather trace (a ``collective-permute`` chain with
activation-piece concatenates) that must not be mistaken for apply-time
weight concats.  The REAL compiled chunked-gather HLO is asserted in the
multidev job (``_multidev_checks.check_overlapped_gather_hlo``); the
snippets here keep the tier-1 suite single-device.
"""
import textwrap

from repro.launch.hlo_analysis import (
    analyze_hlo,
    gemm_dispatches,
    int8_bounce_count,
    weight_concat_count,
)


def _hlo(body: str) -> str:
    return textwrap.dedent(body)


# ---------------------------------------------------------------------------
# zero-dot modules
# ---------------------------------------------------------------------------

HLO_NO_DOTS = _hlo("""
    HloModule nodots

    ENTRY %main (p0: s8[4,8], p1: f32[4,8]) -> f32[4,8] {
      %p0 = s8[4,8] parameter(0)
      %p1 = f32[4,8] parameter(1)
      %deq = f32[4,8] convert(%p0)
      ROOT %add = f32[4,8] add(%deq, %p1)
    }
""")


def test_no_dots_no_bounce():
    """A dequantized int8 tensor that never reaches a dot is NOT a
    bounce (elementwise consumers are exactly what the serving path's
    norms/embeddings do legitimately)."""
    assert int8_bounce_count(HLO_NO_DOTS) == 0


def test_no_dots_no_gemm_dispatches():
    assert gemm_dispatches(HLO_NO_DOTS, 8) == 0
    assert gemm_dispatches(HLO_NO_DOTS, 4) == 0


def test_no_dots_analyze_flops_zero():
    assert analyze_hlo(HLO_NO_DOTS)["flops"] == 0.0


# ---------------------------------------------------------------------------
# nested while loops
# ---------------------------------------------------------------------------

HLO_NESTED_WHILE = _hlo("""
    HloModule nested

    %inner_cond (ip: (s32[], f32[4,16])) -> pred[] {
      %ip = (s32[], f32[4,16]) parameter(0)
      %iv = s32[] get-tuple-element(%ip), index=0
      %ilim = s32[] constant(3)
      ROOT %ilt = pred[] compare(%iv, %ilim), direction=LT
    }

    %inner_body (ibp: (s32[], f32[4,16])) -> (s32[], f32[4,16]) {
      %ibp = (s32[], f32[4,16]) parameter(0)
      %ia = f32[4,16] get-tuple-element(%ibp), index=1
      %iw = f32[16,16] constant({...})
      %idot = f32[4,16] dot(%ia, %iw), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ii = s32[] get-tuple-element(%ibp), index=0
      %ione = s32[] constant(1)
      %inext = s32[] add(%ii, %ione)
      ROOT %it = (s32[], f32[4,16]) tuple(%inext, %idot)
    }

    %outer_cond (op: (s32[], f32[4,16])) -> pred[] {
      %op = (s32[], f32[4,16]) parameter(0)
      %ov = s32[] get-tuple-element(%op), index=0
      %olim = s32[] constant(5)
      ROOT %olt = pred[] compare(%ov, %olim), direction=LT
    }

    %outer_body (obp: (s32[], f32[4,16])) -> (s32[], f32[4,16]) {
      %obp = (s32[], f32[4,16]) parameter(0)
      ROOT %ow = (s32[], f32[4,16]) while(%obp), condition=%inner_cond, body=%inner_body
    }

    ENTRY %main (p0: f32[4,16]) -> (s32[], f32[4,16]) {
      %p0 = f32[4,16] parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[4,16]) tuple(%zero, %p0)
      ROOT %w = (s32[], f32[4,16]) while(%init), condition=%outer_cond, body=%outer_body
    }
""")


def test_nested_while_no_bounce_without_int8():
    """Trip-count recursion over nested whiles must not conjure bounces
    out of float-only loops."""
    assert int8_bounce_count(HLO_NESTED_WHILE) == 0


def test_nested_while_gemm_dispatch_static_count():
    """gemm_dispatches is a STATIC dot count (dispatch sites, not
    executions): the loop nest contributes its single traced dot."""
    assert gemm_dispatches(HLO_NESTED_WHILE, 16) == 1
    assert gemm_dispatches(HLO_NESTED_WHILE, 99) == 0


def test_nested_while_flops_scale_by_trip_counts():
    """analyze_hlo DOES multiply trip counts through the NESTING: the
    entry while runs %outer_body 5 times, whose inner while runs
    %inner_body 3 times, one dot each = 5 * 3 * (2 * 4 * 16 * 16)."""
    assert analyze_hlo(HLO_NESTED_WHILE)["flops"] == \
        5 * 3 * 2.0 * 4 * 16 * 16


HLO_NESTED_WHILE_BOUNCE = HLO_NESTED_WHILE.replace(
    "  %p0 = f32[4,16] parameter(0)\n",
    "  %q0 = s8[4,16] parameter(0)\n"
    "  %p0 = f32[4,16] convert(%q0)\n",
).replace("ENTRY %main (p0: f32[4,16])", "ENTRY %main (q0: s8[4,16])")
assert HLO_NESTED_WHILE_BOUNCE != HLO_NESTED_WHILE  # the rewrite applied


def test_nested_while_dequant_reaching_loop_dot_is_one_bounce():
    """An s8->f32 convert whose value flows INTO the loop and reaches the
    dot is exactly ONE bounce (a dispatch site), however many times the
    nested loops iterate it."""
    assert int8_bounce_count(HLO_NESTED_WHILE_BOUNCE) == 1


# ---------------------------------------------------------------------------
# the chunked-gather trace shape
# ---------------------------------------------------------------------------

# Mirrors the compiled ksharded Z>1 path: rotation collective-permutes of
# the activation piece, per-piece dots, buffer concatenates whose
# trailing-2 dim is ROWS (=32), never the weight's K (=16).  d_model in
# the detector call is the weight K dimension.
HLO_CHUNKED_GATHER = _hlo("""
    HloModule gather

    ENTRY %main (p0: f32[32,8], p1: f32[16,64]) -> f32[32,128] {
      %p0 = f32[32,8] parameter(0)
      %p1 = f32[16,64] parameter(1)
      %hop1 = f32[32,8] collective-permute(%p0), source_target_pairs={{0,2},{2,0},{1,3},{3,1}}
      %w0 = f32[8,64] slice(%p1), slice={[0:8], [0:64]}
      %w1 = f32[8,64] slice(%p1), slice={[8:16], [0:64]}
      %g0 = f32[32,64] dot(%p0, %w0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %g1 = f32[32,64] dot(%hop1, %w1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %sum = f32[32,64] add(%g0, %g1)
      %lo = f32[32,32] slice(%sum), slice={[0:32], [0:32]}
      %hi = f32[32,32] slice(%sum), slice={[0:32], [32:64]}
      %hop2 = f32[32,32] collective-permute(%lo), source_target_pairs={{0,1},{1,0}}
      %hop3 = f32[32,32] collective-permute(%hi), source_target_pairs={{1,0},{0,1}}
      %merge = f32[32,64] concatenate(%hop2, %hop3), dimensions={1}
      ROOT %out = f32[32,128] concatenate(%sum, %merge), dimensions={1}
    }
""")


def test_chunked_gather_permutes_not_weight_concats():
    """The ppermute chain's half-chunk merges concatenate ACTIVATION
    pieces ([rows, half]); with rows != d_model they must not be counted
    as apply-time weight concats."""
    assert weight_concat_count(HLO_CHUNKED_GATHER, 16) == 0


def test_chunked_gather_no_bounce_and_dot_count():
    assert int8_bounce_count(HLO_CHUNKED_GATHER) == 0
    assert gemm_dispatches(HLO_CHUNKED_GATHER, 64) == 2


def test_chunked_gather_wire_counts_permutes():
    res = analyze_hlo(HLO_CHUNKED_GATHER)
    assert res["wire_collective-permute"] > 0
    assert res.get("wire_all-gather", 0.0) == 0.0
