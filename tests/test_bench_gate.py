"""Unit tests for the CI benchmark-regression gate's comparison logic
(scripts/bench_gate.py) — pure function, no timing involved."""
import importlib.util
import os

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_gate():
    spec = importlib.util.spec_from_file_location(
        "bench_gate", os.path.join(_ROOT, "scripts", "bench_gate.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_identical_runs_pass():
    g = _load_gate()
    rows = {"a": 100.0, "b": 200.0, "c": 50.0}
    failures, _ = g.compare(rows, dict(rows))
    assert failures == []


def test_uniform_host_slowdown_is_normalized_away():
    """A runner uniformly 3x slower than the baseline host must NOT trip
    the gate: the median-ratio normalization cancels host speed."""
    g = _load_gate()
    base = {"a": 100.0, "b": 200.0, "c": 50.0, "d": 75.0}
    cur = {k: 3.0 * v for k, v in base.items()}
    failures, _ = g.compare(cur, base)
    assert failures == []


def test_single_benchmark_regression_fails():
    """One benchmark regressing 2x while its peers stay flat sticks out
    of the normalized ratios and fails the gate."""
    g = _load_gate()
    base = {"a": 100.0, "b": 200.0, "c": 50.0, "d": 75.0}
    cur = dict(base, a=2.0 * base["a"])
    failures, _ = g.compare(cur, base)
    assert len(failures) == 1 and "a" in failures[0]
    assert "REGRESSION" in failures[0]


def test_regression_within_tolerance_passes():
    g = _load_gate()
    base = {"a": 100.0, "b": 200.0, "c": 50.0, "d": 75.0}
    cur = dict(base, a=1.2 * base["a"])  # +20% < default 25% tolerance
    failures, _ = g.compare(cur, base)
    assert failures == []


def test_new_benchmark_passes_missing_fails():
    g = _load_gate()
    base = {"a": 100.0, "b": 200.0}
    cur = {"a": 100.0, "new": 10.0}
    failures, report = g.compare(cur, base)
    assert any("MISSING benchmark b" in f for f in failures)
    assert any(line.startswith("new  new:") for line in report)


def test_no_common_benchmarks_fails():
    g = _load_gate()
    failures, _ = g.compare({"x": 1.0}, {"y": 2.0})
    assert failures
