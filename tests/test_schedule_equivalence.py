"""Schedule-equivalence property harness (the full multidev grid).

Parametrizes ``tests/_schedule_sweep.py`` over every
``(schedule x x_layout x Y x Z x epilogue)`` cell on the 8-fake-device
mesh and asserts, per cell,

  (a) bitwise fp32 equality across 'allreduce' / 'reduce_scatter' /
      'ring' / 'bidir_ring' (int8 q + f32 scales exactly equal under the
      quantize epilogue), and
  (b) closeness to the ``kernels.ref`` oracle.

Shapes are hypothesis-driven when hypothesis is installed (edge cases
like 1-column chunks, where 'bidir_ring' falls back to the
unidirectional merge, get generated) and fixed-seed otherwise.  Each test
runs the sweep in its own subprocess so this process keeps a single jax
device (the dry-run isolation rule); the subprocess prints one
``ok equiv[...]`` line per cell, surfaced by ``pytest -m multidev -v``
in the CI multidev job.
"""
import os
import subprocess
import sys

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to the fixed-seed grid below
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.multidev

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SWEEP = os.path.join(_ROOT, "tests", "_schedule_sweep.py")

LAYOUTS = ("replicated", "ksharded")
EPILOGUES = ("none", "bias_gelu", "bias_gelu_residual", "quantize",
             "gate_silu", "gate_silu_residual")


def _run_sweep(*args):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run([sys.executable, _SWEEP, *args],
                       capture_output=True, text=True, timeout=1200,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SWEEP_OK" in r.stdout
    # surface the per-cell check names in the pytest log
    for line in r.stdout.splitlines():
        if line.startswith("ok equiv["):
            print(line)


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("epilogue", EPILOGUES)
def test_schedule_equivalence_grid(layout, epilogue):
    """One (layout, epilogue) column of the grid: Y in {1, 2, 4} x all
    four schedules, bitwise + oracle, fixed seed."""
    _run_sweep("--layouts", layout, "--epilogues", epilogue,
               "--ys", "1,2,4", "--schedules", "all")


def test_schedule_equivalence_multi_seed_reduction_cells():
    """Extra seeds on the reduction-heavy raw-GEMM cells (the successor
    of the old 3-seed ring-bitwise check, now across all schedules)."""
    for seed in (1, 2, 3):
        _run_sweep("--layouts", "replicated,ksharded", "--epilogues",
                   "none", "--ys", "2,4", "--schedules", "all",
                   "--shape", "4,8,64,128", "--seed", str(seed))


if HAVE_HYPOTHESIS:
    @settings(max_examples=3, deadline=None)
    @given(
        s=st.integers(min_value=1, max_value=8),
        # K and N at model granularity; small multipliers generate the
        # 1-column-chunk edge where bidir_ring's split collapses
        k_mult=st.integers(min_value=1, max_value=16),
        n_mult=st.integers(min_value=1, max_value=16),
        seed=st.integers(min_value=0, max_value=2 ** 16),
        y=st.sampled_from([2, 4]),
        layout=st.sampled_from(LAYOUTS),
    )
    def test_schedule_equivalence_hypothesis_shapes(s, k_mult, n_mult,
                                                    seed, y, layout):
        _run_sweep("--layouts", layout, "--epilogues", "none",
                   "--ys", str(y), "--schedules", "all",
                   "--shape", f"4,{s},{4 * k_mult},{4 * n_mult}",
                   "--seed", str(seed))
else:
    @pytest.mark.parametrize("shape,seed,y,layout", [
        ("4,3,4,4", 7, 4, "replicated"),      # 1-column chunks: bidir
                                              # split-merge fallback
        ("4,1,8,16", 11, 2, "ksharded"),      # single-row, odd chunk=4
        ("4,5,64,32", 13, 4, "ksharded"),     # K-heavy, narrow N
    ])
    def test_schedule_equivalence_fixed_shapes(shape, seed, y, layout):
        """Fixed-seed stand-ins for the hypothesis shape generator
        (hypothesis unavailable), covering the same edge cells."""
        _run_sweep("--layouts", layout, "--epilogues", "none",
                   "--ys", str(y), "--schedules", "all",
                   "--shape", shape, "--seed", str(seed))
