"""End-to-end behaviour tests for the system: a real train->checkpoint->
serve round trip, and a miniature dry-run (lower+compile+roofline) on an
8-device subprocess mesh."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_train_then_serve_roundtrip(tmp_path):
    """Train a tiny LM on a repeating corpus until it memorizes local
    bigram structure, checkpoint it, restore into a fresh model, and
    verify the served continuation beats chance."""
    from repro.configs import get_config
    from repro.data import DataConfig, SyntheticTokenSource, TokenPipeline
    from repro.launch.mesh import make_mesh
    from repro.models.lm import Model
    from repro.optim import AdamWConfig
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.train.trainer import Trainer, TrainerConfig

    mesh = make_mesh(1, 1)
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              vocab=64, compute_dtype="float32")
    model = Model(cfg, mesh)

    class CyclicSource:
        """tokens follow t_{i+1} = (t_i + 1) % vocab — learnable."""
        def batch(self, step, rows, dcfg):
            n = rows.stop - rows.start
            start = (np.arange(n) + step) % cfg.vocab
            return ((start[:, None] + np.arange(dcfg.seq_len + 1))
                    % cfg.vocab).astype(np.int32)

    tcfg = TrainerConfig(steps=60, ckpt_every=30, ckpt_dir=str(tmp_path),
                         log_every=100)
    dcfg = DataConfig(global_batch=4, seq_len=32)
    trainer = Trainer(model, AdamWConfig(lr=3e-3), tcfg,
                      lambda s: TokenPipeline(CyclicSource(), dcfg, mesh,
                                              cfg, start_step=s))
    trainer.run(0)
    assert trainer.metrics[-1]["loss"] < trainer.metrics[0]["loss"]

    # restore into a FRESH model instance (as a new process would)
    model2 = Model(cfg, mesh)
    t2 = Trainer(model2, AdamWConfig(lr=3e-3), tcfg,
                 lambda s: TokenPipeline(CyclicSource(), dcfg, mesh, cfg,
                                         start_step=s))
    step, params, _ = t2.restore()
    assert step == 60

    prompt = (np.arange(16)[None] % cfg.vocab).astype(np.int32)
    eng = ServeEngine(model2, params, ServeConfig(max_new_tokens=8))
    out = eng.generate({"tokens": jnp.asarray(prompt)})
    want = (16 + np.arange(8)) % cfg.vocab
    acc = float(np.mean(out[0] == want))
    assert acc > 0.5, (out[0], want)  # learned the +1 structure


MINI_DRYRUN = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, REPO_SRC)
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.core.sharding import use_mesh
from repro.launch.hlo_analysis import analyze_hlo
from repro.models.lm import Model
from repro.optim import AdamWConfig, abstract_opt_state, opt_state_specs
from repro.train.step import batch_specs, make_train_step
import dataclasses

mesh = make_mesh(2, 4)
for arch in ("internlm2-1.8b", "gemma2-27b"):
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              seq_shard_activations=True)
    model = Model(cfg, mesh)
    opt_cfg = AdamWConfig()
    fn = make_train_step(model, opt_cfg)
    ap = model.abstract_params()
    ao = abstract_opt_state(ap, opt_cfg)
    ab = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
          "targets": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
    with use_mesh(mesh):
        jf = jax.jit(fn, in_shardings=(ns(model.param_specs()),
                     ns(opt_state_specs(model.param_specs(), opt_cfg)),
                     ns(batch_specs(cfg, mesh, "train"))))
        compiled = jf.lower(ap, ao, ab).compile()
    an = analyze_hlo(compiled.as_text())
    assert an["flops"] > 0
    assert an["total_wire_bytes"] > 0  # TP collectives present
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes > 0
    print(arch, "flops", an["flops"], "wire", an["total_wire_bytes"])
print("ALL_OK")
"""


def test_mini_dryrun_multidev(tmp_path):
    """lower+compile a sharded train step for two archs on an 8-device
    mesh; collective parser and memory analysis must produce signals."""
    script = tmp_path / "mini.py"
    script.write_text(MINI_DRYRUN.replace(
        "REPO_SRC", repr(os.path.join(_ROOT, "src"))))
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run([sys.executable, str(script)], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:{r.stdout}\nstderr:{r.stderr}"
    assert "ALL_OK" in r.stdout
