"""int8 error-feedback gradient compression: exactness properties on one
device; wire-byte reduction + convergence on an 8-device subprocess."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_quantize_error_feedback_accumulates():
    """EF: the long-run average of compressed values converges to the true
    value (residual is carried, not dropped)."""
    from repro.optim.compression import compressed_psum_mean
    # single shard via a fake axis: emulate with axis over 1-device mesh
    from repro.launch.mesh import make_mesh
    mesh = make_mesh(1, 1)
    from repro.core.maxeva_matmul import _shard_map
    from jax.sharding import PartitionSpec as P

    x = jnp.full((64,), 0.001234, jnp.float32)  # small vs absmax
    big = jnp.zeros((64,)).at[0].set(1.0)       # forces coarse scale
    v = x + big

    def body(v):
        err = jnp.zeros_like(v)
        tot = jnp.zeros_like(v)
        for _ in range(64):
            out, err = compressed_psum_mean(v, "data", err)
            tot = tot + out
        return tot / 64

    avg = _shard_map(body, mesh, (P(),), P())(v)
    np.testing.assert_allclose(np.asarray(avg)[1:], 0.001234, rtol=0.02)


MULTIDEV = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.core.sharding import use_mesh
from repro.optim import AdamWConfig
from repro.optim.compression import init_error_state, make_dp_train_step

mesh = make_mesh(8, 1)

def loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2)

kw, kx = jax.random.split(jax.random.PRNGKey(0))
w_true = jax.random.normal(kw, (16, 4))
params = {"w": jnp.zeros((16, 4))}

def data(step):
    k = jax.random.PRNGKey(step)
    x = jax.random.normal(k, (64, 16))
    return {"x": x, "y": x @ w_true}

results = {}
for mode in ("none", "int8_ef"):
    from repro.optim import init_opt_state
    p = {"w": jnp.zeros((16, 4))}
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, grad_clip=0.0)
    opt = init_opt_state(p, cfg)
    err = init_error_state(p, 8)
    step = make_dp_train_step(loss_fn, cfg, mesh, "data", mode)
    with use_mesh(mesh):
        for s in range(150):
            loss, p, opt, err = step(p, opt, err, data(s))
    results[mode] = (float(loss), float(jnp.max(jnp.abs(p["w"] - w_true))))

print("none", results["none"], "int8_ef", results["int8_ef"])
assert results["none"][1] < 0.05, results
assert results["int8_ef"][1] < 0.1, results

# wire bytes: the compressed step's all-reduce payload must be ~4x smaller
from repro.launch.hlo_analysis import analyze_hlo
from repro.optim import init_opt_state
outs = {}
for mode in ("none", "int8_ef"):
    cfg = AdamWConfig(lr=0.05)
    p = {"w": jnp.zeros((256, 256))}
    opt = init_opt_state(p, cfg)
    err = init_error_state(p, 8)
    step = make_dp_train_step(loss_fn, cfg, mesh, "data", mode)
    b = {"x": jnp.zeros((64, 256)), "y": jnp.zeros((64, 256))}
    with use_mesh(mesh):
        txt = step.lower(p, opt, err, b).compile().as_text()
    an = analyze_hlo(txt)
    outs[mode] = an["total_wire_bytes"]
print("wire none:", outs["none"], "int8:", outs["int8_ef"])
# int16 transport: ~2x fewer wire bytes than fp32 (+ tiny scale pmax)
assert outs["int8_ef"] < 0.65 * outs["none"], outs
print("ALL_OK")
"""


def test_dp_train_step_compression_multidev(tmp_path):
    script = tmp_path / "check.py"
    script.write_text(MULTIDEV)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run([sys.executable, str(script)], capture_output=True,
                       text=True, timeout=900, env=env,
                       cwd=os.path.join(_ROOT, "tests"))
    assert r.returncode == 0, f"stdout:{r.stdout}\nstderr:{r.stderr}"
    assert "ALL_OK" in r.stdout
