"""Planner tests: reproduce the paper's reported solutions exactly, and
property-test that every emitted plan satisfies its constraints.

The property-based section needs ``hypothesis`` (see requirements-dev.txt)
and degrades to a fixed-example smoke subset when it is absent.
"""
import dataclasses

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to the fixed-example smoke subset below
    HAVE_HYPOTHESIS = False

from repro.core.device_model import AIE_VC1902, TPU_V5E, AIEDevice, DTYPE_BYTES
from repro.core.planner import (
    ArrayConfig,
    XYZShardPlan,
    gather_wire_bytes_per_link,
    plan_tpu_block,
    plan_tpu_matmul,
    plan_tpu_shard,
    pnr_feasible,
    reduction_wire_bytes_per_link,
    solve_aie_array,
    solve_aie_kernel_tiles,
)


# ---------------------------------------------------------------------------
# Paper-solution reproduction (§V-A, §V-B)
# ---------------------------------------------------------------------------

def test_int8_single_kernel_unique_solution():
    tiles = solve_aie_kernel_tiles("int8")
    assert [t.as_tuple() for t in tiles] == [(32, 128, 32)]
    assert tiles[0].macs == 131072


def test_fp32_single_kernel_solutions_all_at_32768_macs():
    tiles = solve_aie_kernel_tiles("fp32")
    assert all(t.macs == 32768 for t in tiles)
    tups = {t.as_tuple() for t in tiles}
    # the examples listed in §V-A
    assert (32, 32, 32) in tups
    assert (16, 64, 32) in tups
    assert (64, 16, 32) in tups


def test_xyz_search_reproduces_paper_ranking():
    top = solve_aie_array(top=10)
    # MAC-maximal point: 10x4x8 = 320 kernels, 400 cores (§V-B1)
    assert (top[0].x, top[0].y, top[0].z) == (10, 4, 8)
    assert top[0].matmul_kernels == 320 and top[0].total_cores == 400
    # ...but it fails PnR (routing congestion); 13x4x6 is the best feasible.
    assert not pnr_feasible(top[0])
    feasible = [c for c in top if pnr_feasible(c)]
    assert (feasible[0].x, feasible[0].y, feasible[0].z) == (13, 4, 6)
    assert feasible[0].matmul_kernels == 312
    # the other reported configs all appear in the top set
    reported = {(13, 4, 6), (11, 4, 7), (10, 3, 10), (11, 3, 9), (12, 4, 6),
                (12, 3, 8)}
    found = {(c.x, c.y, c.z) for c in top}
    assert reported <= found


def test_paper_config_resources_match_tables():
    # Table II row 1: 13x4x6 -> 312 MatMuls, 390 cores, 154 PLIOs, 18 DMA.
    c = ArrayConfig(13, 4, 6)
    assert c.matmul_kernels == 312
    assert c.total_cores == 390
    assert c.plio_in + c.plio_out == 154
    assert c.pattern == "P1" and c.dma_banks == 18
    # Table II row 2: 10x3x10 -> 300 MatMuls, 400 cores, 160 PLIOs, 0 DMA.
    c = ArrayConfig(10, 3, 10)
    assert c.matmul_kernels == 300
    assert c.total_cores == 400
    assert c.plio_in + c.plio_out == 160
    assert c.pattern == "P2" and c.dma_banks == 0
    # Table II rows 5: 12x4x6 -> 16 DMA banks.
    assert ArrayConfig(12, 4, 6).dma_banks == 16


# ---------------------------------------------------------------------------
# Constraint-satisfaction properties — run under hypothesis when present,
# and on the fixed smoke examples below otherwise
# ---------------------------------------------------------------------------

def _check_xyz_constraints(n_cores, plio_in, plio_out):
    dev = dataclasses.replace(AIE_VC1902, n_cores=n_cores, plio_in=plio_in,
                              plio_out=plio_out)
    for cfg in solve_aie_array(dev, top=5):
        assert cfg.total_cores <= dev.n_cores
        assert cfg.plio_in <= dev.plio_in
        assert cfg.plio_out <= dev.plio_out


def _check_kernel_tile_constraints(eff_lb, precision, mem_kb):
    dev = dataclasses.replace(AIE_VC1902, usable_buffer_bytes=mem_kb * 1024)
    peak = dev.peak_macs[precision]
    sa = dev.sizeof_in(precision)
    sc = dev.sizeof_out(precision)
    for t in solve_aie_kernel_tiles(precision, dev, eff_lb=eff_lb):
        # eq. 3-5
        assert t.n >= eff_lb * peak * sa / dev.bw_io_bytes_per_cyc
        assert t.m >= eff_lb * peak * sa / dev.bw_io_bytes_per_cyc
        assert t.k >= eff_lb * peak * sc / dev.bw_io_bytes_per_cyc
        # eq. 6
        assert t.buffer_bytes <= dev.usable_buffer_bytes
        # powers of two
        for d in t.as_tuple():
            assert d & (d - 1) == 0


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(
        n_cores=st.integers(min_value=16, max_value=800),
        plio_in=st.integers(min_value=8, max_value=200),
        plio_out=st.integers(min_value=8, max_value=200),
    )
    def test_xyz_solutions_always_satisfy_constraints(n_cores, plio_in,
                                                      plio_out):
        _check_xyz_constraints(n_cores, plio_in, plio_out)

    @settings(max_examples=30, deadline=None)
    @given(
        eff_lb=st.sampled_from([0.5, 0.8, 0.9, 0.95]),
        precision=st.sampled_from(["int8", "fp32"]),
        mem_kb=st.integers(min_value=4, max_value=64),
    )
    def test_kernel_tiles_always_satisfy_constraints(eff_lb, precision,
                                                     mem_kb):
        _check_kernel_tile_constraints(eff_lb, precision, mem_kb)


@pytest.mark.parametrize("n_cores,plio_in,plio_out",
                         [(16, 8, 8), (400, 78, 117), (800, 200, 200),
                          (123, 17, 41)])
def test_xyz_constraints_smoke(n_cores, plio_in, plio_out):
    _check_xyz_constraints(n_cores, plio_in, plio_out)


@pytest.mark.parametrize("eff_lb", [0.5, 0.95])
@pytest.mark.parametrize("precision", ["int8", "fp32"])
@pytest.mark.parametrize("mem_kb", [4, 14, 64])
def test_kernel_tile_constraints_smoke(eff_lb, precision, mem_kb):
    _check_kernel_tile_constraints(eff_lb, precision, mem_kb)


# ---------------------------------------------------------------------------
# TPU-mode planner
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["bf16", "fp32", "int8"])
@pytest.mark.parametrize("mkn", [(4096, 4096, 4096), (8192, 512, 2048),
                                 (256, 16384, 1024)])
def test_tpu_block_plan_constraints(dtype, mkn):
    m, k, n = mkn
    b = plan_tpu_block(m, k, n, dtype)
    dev = TPU_V5E
    # MXU / sublane alignment (eq. 1 analog)
    assert b.bm % dev.sublane == 0
    assert b.bn % dev.mxu_dim == 0
    assert b.bk % dev.mxu_dim == 0
    # VMEM budget (eq. 6 analog)
    assert b.vmem_bytes <= dev.vmem_budget
    # I/O bound (eq. 2 analog): streaming each input block is not slower
    # than the MXU work on the block, unless dimension exhausted.
    ebytes = DTYPE_BYTES[dtype]
    io_min = dev.peak_flops[dtype] * ebytes / (2 * dev.hbm_bw)
    assert b.bn >= min(io_min, n) and b.bm >= min(io_min, m)


def test_tpu_shard_plan_megatron_duality():
    """For an activation-row GEMM with huge N (e.g. vocab projection) the
    planner should column-parallelize (Z=model, Y=1, no reduction); for a
    K-heavy GEMM with A already sharded on model (row-parallel down-proj),
    it should K-shard and reduce (the adder-tree analog)."""
    axes = {"data": 16, "model": 16}
    up = plan_tpu_shard(8192, 4096, 262144, "bf16", axes)
    assert up.z_shards == 16 and up.y_shards == 1 and up.schedule == "none"
    down = plan_tpu_shard(8192, 65536, 4096, "bf16", axes,
                          a_sharded_on_model=True)
    assert down.y_shards > 1  # contraction sharded -> on-array reduction


def test_tpu_matmul_plan_end_to_end():
    p = plan_tpu_matmul(16384, 4096, 14336, "bf16",
                        {"data": 16, "model": 16})
    assert p.shard.x_shards == 16
    assert p.shard.y_shards * p.shard.z_shards == 16
    assert p.block.vmem_bytes <= TPU_V5E.vmem_budget


# ---------------------------------------------------------------------------
# Per-link wire-byte model: bidirectional ring + overlapped gather
# ---------------------------------------------------------------------------

def test_bidir_ring_halves_per_link_bytes():
    """The acceptance invariant: for the same partial, 'bidir_ring' puts
    HALF the bytes of 'ring' on each (full-duplex) link; 'ring' matches
    'reduce_scatter'; 'allreduce' pays the RS+AG double."""
    c_bytes = 512 * 4096 * 4
    for y in (2, 4, 8, 16):
        ring = reduction_wire_bytes_per_link(c_bytes, y, "ring")
        bidir = reduction_wire_bytes_per_link(c_bytes, y, "bidir_ring")
        assert ring == pytest.approx((y - 1) / y * c_bytes)
        assert bidir == pytest.approx(ring / 2)
        assert reduction_wire_bytes_per_link(c_bytes, y, "reduce_scatter") \
            == pytest.approx(ring)
        assert reduction_wire_bytes_per_link(c_bytes, y, "allreduce") \
            == pytest.approx(2 * ring)
    # no reduction at Y == 1, whatever the schedule string says
    for sched in ("none", "ring", "bidir_ring"):
        assert reduction_wire_bytes_per_link(c_bytes, 1, sched) == 0.0
    with pytest.raises(ValueError):
        reduction_wire_bytes_per_link(c_bytes, 4, "ring ")  # typo'd name
    assert gather_wire_bytes_per_link(1000, 1) == 0.0
    assert gather_wire_bytes_per_link(1000, 4) == pytest.approx(750.0)


def test_overlap_model_gather_term():
    """Overlapped schedules hide the chunked gather + reduction behind the
    chunk GEMMs (max); Y == 1 keeps the serial barrier gather."""
    comp, hbm, coll, gather = 5e-4, 1e-4, 1e-4, 2e-4
    over = XYZShardPlan(1, 2, 2, "bidir_ring", coll, comp, hbm, gather)
    assert over.est_step_s == pytest.approx(comp)  # wire fully hidden
    serial = XYZShardPlan(1, 1, 4, "none", 0.0, comp, hbm, gather)
    assert serial.est_step_s == pytest.approx(comp + gather)
    # barrier reduction: gather rides the partial GEMMs, reduction doesn't
    barrier = XYZShardPlan(1, 2, 2, "reduce_scatter", coll, comp, hbm,
                           gather)
    assert barrier.est_step_s == pytest.approx(comp + coll)


def test_planner_picks_bidir_ring_for_wire_heavy_reduction():
    """The K-heavy row-parallel down-projection (A model-sharded) should
    now land on the bidirectional overlapped collective matmul, and its
    modeled step must beat (or tie) a forced 'ring' plan."""
    axes = {"data": 16, "model": 16}
    down = plan_tpu_shard(8192, 65536, 4096, "bf16", axes,
                          a_sharded_on_model=True)
    assert down.y_shards > 1
    assert down.schedule == "bidir_ring"
    forced = plan_tpu_shard(8192, 65536, 4096, "bf16", axes,
                            a_sharded_on_model=True,
                            prefer_schedule="ring")
    assert forced.schedule == "ring"
    assert down.est_step_s <= forced.est_step_s
    # same factorization, same partial: bidir halves the per-link time
    same_y = plan_tpu_shard(8192, 65536, 4096, "bf16", axes,
                            a_sharded_on_model=True,
                            prefer_schedule="bidir_ring")
    assert same_y.schedule == "bidir_ring"
    assert same_y.est_step_s <= forced.est_step_s


def test_perf_model_overlap_savings():
    from repro.core.perf_model import collective_overlap_savings
    sav = collective_overlap_savings(512, 4096, y=4, z=4,
                                     a_bytes=512 * 2048 * 2)
    assert sav["bidir_link_ratio"] == pytest.approx(0.5)
    assert sav["link_bytes_bidir_ring"] == pytest.approx(
        sav["link_bytes_ring"] / 2)
    assert sav["link_bytes_allreduce"] > sav["link_bytes_reduce_scatter"]
    assert sav["gather_s_serial"] > 0.0
    assert sav["wire_s_bidir_ring"] == pytest.approx(sav["wire_s_ring"] / 2)
