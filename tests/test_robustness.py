"""Fault-injection suite for the hardened serving stack.

Every fault class the ``repro.robust`` harness can inject — NaN/Inf
logits, int8 saturation, host stalls, transient whole-call failures,
truncated/bit-flipped checkpoint files — must be either recovered or
converted into a STRUCTURED per-request error.  The engine itself
survives every drill, and healthy lanes decode bitwise-unchanged next to
a poisoned one.

Also proves the zero-overhead contract: with no ``FaultPlan`` the decode
loop is on the exact pre-hardening compute path, the traced decode-step
HLO is byte-identical with guards on/off, and the PR 2-4 HLO invariants
(single packed-QKV GEMM dispatch, zero int8 bounces) still hold on the
guarded engine.
"""
import dataclasses
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointCorruptionError, CheckpointManager
from repro.configs import get_config
from repro.launch.hlo_analysis import gemm_dispatches, int8_bounce_count
from repro.launch.mesh import make_mesh
from repro.models.lm import Model
from repro.robust import (
    STATUS_DEGRADED,
    STATUS_NONFINITE,
    STATUS_OK,
    STATUS_SHED,
    STATUS_TIMEOUT,
    FaultPlan,
    LogitFault,
    NumericalHealthError,
    StallFault,
    TransientServeError,
    bitflip_leaf,
    generate_with_retry,
    truncate_leaf,
    truncate_manifest,
)
from repro.serve.engine import ServeConfig, ServeEngine

ARCH = "internlm2-1.8b"
PROMPT = 16
NEW = 6


@pytest.fixture(scope="module")
def mesh():
    return make_mesh(1, 1)


@pytest.fixture(scope="module")
def model(mesh):
    return Model(get_config(ARCH, smoke=True), mesh)


@pytest.fixture(scope="module")
def params(model):
    return model.init_params(0)


def _prompt(model, b=3):
    v = model.cfg.vocab
    return {"tokens": (jnp.arange(b * PROMPT, dtype=jnp.int32)
                       .reshape(b, PROMPT) % v)}


@pytest.fixture(scope="module")
def engine(model, params):
    return ServeEngine(model, params, ServeConfig(max_new_tokens=NEW))


# ---------------------------------------------------------------------------
# zero-overhead contract: guards change nothing on the healthy path
# ---------------------------------------------------------------------------

def test_guards_on_equals_guards_off_bitwise(model, params, engine):
    off = ServeEngine(model, params,
                      ServeConfig(max_new_tokens=NEW, guards=False))
    p = _prompt(model)
    np.testing.assert_array_equal(engine.generate(p), off.generate(p))


def test_disabled_fault_plan_is_inert(model, engine):
    """``FaultPlan(enabled=False)`` full of faults must be a bitwise
    no-op — the kill switch for a chaos drill left on by accident."""
    p = _prompt(model)
    plan = FaultPlan(enabled=False,
                     logit_faults=(LogitFault(step=1, lanes=(0,)),),
                     stalls=(StallFault(step=0, seconds=100.0),),
                     fail_first_generates=5)
    base = engine.generate_with_status(p)
    got = engine.generate_with_status(p, fault_plan=plan)
    np.testing.assert_array_equal(got.tokens, base.tokens)
    assert got.status == [STATUS_OK] * 3 and got.ok


def test_decode_hlo_identical_with_and_without_guards(model, params):
    """The guards live in the token-pick dispatch, never the model trace:
    the traced decode-step HLO must be byte-identical either way."""
    on = ServeEngine(model, params, ServeConfig(max_new_tokens=2))
    off = ServeEngine(model, params,
                      ServeConfig(max_new_tokens=2, guards=False))
    batch = _prompt(model, b=2)
    _, cache = jax.jit(lambda pr, b: model.prefill(pr, b, max_len=24))(
        params, batch)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.asarray(PROMPT, jnp.int32)
    hlo_on = on._decode.lower(params, cache, tok, pos).compile().as_text()
    hlo_off = off._decode.lower(params, cache, tok, pos).compile().as_text()
    assert hlo_on == hlo_off


def test_guarded_int8_decode_keeps_hlo_invariants(mesh):
    """PR 3/4 acceptance guards on the GUARDED engine's decode trace:
    single packed-QKV GEMM dispatch, zero int8 fp32 bounces."""
    cfg = dataclasses.replace(get_config(ARCH, smoke=True), d_ff=96)
    packed_cols = cfg.q_dim + 2 * cfg.kv_dim
    assert packed_cols not in (cfg.d_model, cfg.d_ff, cfg.padded_vocab())
    model = Model(cfg, mesh)
    eng = ServeEngine(model, model.init_params(0),
                      ServeConfig(max_new_tokens=2, int8=True))
    batch = {"tokens": jnp.zeros((2, PROMPT), jnp.int32)}
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=24))(
        eng.params, batch)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.asarray(PROMPT, jnp.int32)
    hlo = eng._decode.lower(eng.params, cache, tok, pos).compile().as_text()
    assert int8_bounce_count(hlo) == 0
    assert gemm_dispatches(hlo, packed_cols) == 1


# ---------------------------------------------------------------------------
# non-finite logits: per-lane quarantine, peers bitwise-unchanged
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["nan", "inf", "ninf"])
def test_nonfinite_lane_quarantined_peers_unchanged(model, engine, kind):
    p = _prompt(model)
    base = engine.generate_with_status(p)
    plan = FaultPlan(logit_faults=(
        LogitFault(step=2, lanes=(1,), kind=kind),))
    got = engine.generate_with_status(p, fault_plan=plan)

    assert got.status[1] == STATUS_NONFINITE
    assert got.fault_step[1] == 2
    assert list(got.lanes_with(STATUS_NONFINITE)) == [1]
    # the poisoned lane freezes at the fault step: its earlier tokens are
    # intact, everything from the fault on is pad
    np.testing.assert_array_equal(got.tokens[1, :2], base.tokens[1, :2])
    assert np.all(got.tokens[1, 2:] == engine.scfg.pad_id)
    # healthy lanes decode bitwise-unchanged next to the poisoned one
    np.testing.assert_array_equal(got.tokens[0], base.tokens[0])
    np.testing.assert_array_equal(got.tokens[2], base.tokens[2])
    assert got.status[0] == got.status[2] == STATUS_OK


def test_nonfinite_at_step_zero_hits_prefill_logits(model, engine):
    plan = FaultPlan(logit_faults=(LogitFault(step=0, lanes=(0,)),))
    got = engine.generate_with_status(_prompt(model), fault_plan=plan)
    assert got.status[0] == STATUS_NONFINITE and got.fault_step[0] == 0
    assert np.all(got.tokens[0] == engine.scfg.pad_id)
    assert got.status[1] == STATUS_OK


def test_on_nonfinite_raise_is_fail_stop(model, params):
    eng = ServeEngine(model, params,
                      ServeConfig(max_new_tokens=NEW, on_nonfinite="raise"))
    plan = FaultPlan(logit_faults=(LogitFault(step=1, lanes=(2,)),))
    with pytest.raises(NumericalHealthError, match=r"step 1.*\[2\]"):
        eng.generate_with_status(_prompt(model), fault_plan=plan)


def test_on_nonfinite_off_restores_prehardening_behavior(model, params):
    eng = ServeEngine(model, params,
                      ServeConfig(max_new_tokens=NEW, on_nonfinite="off"))
    plan = FaultPlan(logit_faults=(LogitFault(step=1, lanes=(0,)),))
    got = eng.generate_with_status(_prompt(model), fault_plan=plan)
    # no quarantine: the lane keeps "decoding" through the poison (the
    # pre-hardening failure mode, preserved behind an explicit opt-out)
    assert got.status == [STATUS_OK] * 3


# ---------------------------------------------------------------------------
# int8 saturation: graceful degradation to the fp32 fallback
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def int8_engine(model, params):
    return ServeEngine(model, params,
                       ServeConfig(max_new_tokens=NEW, int8=True,
                                   fp32_fallback=True))


def test_saturation_degrades_lane_to_fp32(model, params, int8_engine):
    p = _prompt(model, b=2)
    base = int8_engine.generate_with_status(p)
    assert base.ok
    plan = FaultPlan(logit_faults=(
        LogitFault(step=2, lanes=(0,), kind="scale", scale=100.0),))
    got = int8_engine.generate_with_status(p, fault_plan=plan)

    assert got.status[0] == STATUS_DEGRADED and got.fault_step[0] == 2
    assert got.status[1] == STATUS_OK
    # the degraded lane KEEPS decoding (tokens stay valid ids, no pad
    # freeze) — degradation is a precision downgrade, not a quarantine
    assert got.n_steps == NEW
    v = model.cfg.vocab
    assert np.all((got.tokens[0] >= 0) & (got.tokens[0] < v))
    # pre-fault tokens are untouched, and the fault-step token too: the
    # 'scale' fault multiplies the whole lane by a positive factor, which
    # greedy argmax is invariant to — the probe, not the pick, trips
    np.testing.assert_array_equal(got.tokens[0, :3], base.tokens[0, :3])
    # its fallback tokens come from the retained fp32 weights: from the
    # step after the trip they match the pure-fp32 engine's picks
    fp = ServeEngine(model, params, ServeConfig(max_new_tokens=NEW))
    fp_base = fp.generate_with_status(p)
    np.testing.assert_array_equal(got.tokens[0, 3:], fp_base.tokens[0, 3:])
    # the healthy lane is bitwise-unchanged vs the no-fault int8 run
    np.testing.assert_array_equal(got.tokens[1], base.tokens[1])


def test_saturation_without_fallback_still_reports(model, params):
    """Without ``fp32_fallback`` the engine has no fp weights to degrade
    to — the lane finishes on int8 but its status records the saturation
    so the caller can re-issue the request at full precision."""
    eng = ServeEngine(model, params,
                      ServeConfig(max_new_tokens=NEW, int8=True))
    plan = FaultPlan(logit_faults=(
        LogitFault(step=1, lanes=(1,), kind="scale", scale=100.0),))
    got = eng.generate_with_status(_prompt(model, b=2), fault_plan=plan)
    assert got.status[1] == STATUS_DEGRADED and got.fault_step[1] == 1
    assert got.status[0] == STATUS_OK and got.n_steps == NEW


# ---------------------------------------------------------------------------
# wall-clock budget + admission control
# ---------------------------------------------------------------------------

def test_stalled_host_step_becomes_structured_timeout(model, params):
    eng = ServeEngine(model, params,
                      ServeConfig(max_new_tokens=NEW,
                                  request_timeout_s=0.25))
    p = _prompt(model, b=2)
    eng.generate(p)  # warm the jit caches so the budget bounds DECODE
    plan = FaultPlan(stalls=(StallFault(step=2, seconds=0.4),))
    got = eng.generate_with_status(p, fault_plan=plan)
    assert got.timed_out
    assert got.status == [STATUS_TIMEOUT] * 2
    assert list(got.fault_step) == [2, 2]
    # partial tokens up to the stall are returned, and they match the
    # healthy run's prefix
    assert got.n_steps == 2
    base = eng.generate_with_status(p)
    np.testing.assert_array_equal(got.tokens, base.tokens[:, :2])


def test_admission_control_sheds_surplus_lanes(model, params):
    eng = ServeEngine(model, params,
                      ServeConfig(max_new_tokens=NEW, max_lanes=2))
    p = _prompt(model, b=4)
    got = eng.generate_with_status(p)
    assert got.admitted == 2
    assert got.status == [STATUS_OK, STATUS_OK, STATUS_SHED, STATUS_SHED]
    assert np.all(got.tokens[2:] == eng.scfg.pad_id)
    # admitted lanes decode exactly as if the surplus never arrived
    small = eng.generate_with_status({"tokens": p["tokens"][:2]})
    np.testing.assert_array_equal(got.tokens[:2], small.tokens)


# ---------------------------------------------------------------------------
# retry/backoff supervisor
# ---------------------------------------------------------------------------

def test_retry_absorbs_transients_with_exponential_backoff(model, engine):
    plan = FaultPlan(fail_first_generates=2)
    slept = []
    got = generate_with_retry(engine, _prompt(model), retries=2,
                              backoff_s=0.01, fault_plan=plan,
                              sleep=slept.append)
    assert got.ok and got.n_steps == NEW
    assert slept == [0.01, 0.02]


def test_retry_budget_exhausted_reraises(model, engine):
    plan = FaultPlan(fail_first_generates=3)
    slept = []
    with pytest.raises(TransientServeError):
        generate_with_retry(engine, _prompt(model), retries=1,
                            backoff_s=0.01, fault_plan=plan,
                            sleep=slept.append)
    assert slept == [0.01]


def test_retry_does_not_absorb_hard_failures(model, params):
    """A deterministic numerical fault is not transient: retrying it only
    burns the request's budget, so it must propagate immediately."""
    eng = ServeEngine(model, params,
                      ServeConfig(max_new_tokens=NEW, on_nonfinite="raise"))
    plan = FaultPlan(logit_faults=(LogitFault(step=0, lanes=(0,)),))
    slept = []
    with pytest.raises(NumericalHealthError):
        generate_with_retry(eng, _prompt(model), retries=5,
                            fault_plan=plan, sleep=slept.append)
    assert slept == []


def test_retry_parameter_validation(engine, model):
    with pytest.raises(ValueError, match="retries"):
        generate_with_retry(engine, _prompt(model), retries=-1)
    with pytest.raises(ValueError, match="backoff_s"):
        generate_with_retry(engine, _prompt(model), backoff_s=-0.1)


# ---------------------------------------------------------------------------
# config + fault-plan validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kwargs,match", [
    (dict(max_new_tokens=0), "max_new_tokens"),
    (dict(temperature=-0.5), "temperature"),
    (dict(temperature=float("nan")), "temperature"),
    (dict(eos_id=-1), "eos_id"),
    (dict(pad_id=-2), "pad_id"),
    (dict(on_nonfinite="explode"), "on_nonfinite"),
    (dict(logits_dtype="float999"), "logits_dtype"),
    (dict(logits_dtype="int8"), "float dtype"),
    (dict(max_lanes=0), "max_lanes"),
    (dict(request_timeout_s=0.0), "request_timeout_s"),
    (dict(saturation_threshold=0.0), "saturation_threshold"),
    (dict(saturation_threshold=1.5), "saturation_threshold"),
    (dict(fp32_fallback=True), "fp32_fallback"),
])
def test_serve_config_rejects_bad_values(kwargs, match):
    with pytest.raises(ValueError, match=match):
        ServeConfig(**kwargs)


def test_logit_fault_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown logit-fault kind"):
        LogitFault(step=0, lanes=(0,), kind="garbage")


def test_fault_plan_hooks_are_deterministic_and_cheap():
    plan = FaultPlan(stalls=(StallFault(step=3, seconds=7.5),))
    slept = []
    plan.maybe_stall(0, sleep=slept.append)
    plan.maybe_stall(3, sleep=slept.append)
    assert slept == [7.5]
    # perturb_logits on a miss returns the SAME object (copy-on-write)
    x = jnp.ones((2, 4))
    assert plan.perturb_logits(0, x) is x


# ---------------------------------------------------------------------------
# checkpoint durability: async failures surface at sync points
# ---------------------------------------------------------------------------

def _tree():
    return {"w": {"a": np.arange(16, dtype=np.float32).reshape(4, 4),
                  "b": np.ones((3,), np.float32)}}


def _fail_second_leaf(monkeypatch):
    import repro.checkpoint.manager as cm
    real = cm._write_leaf
    calls = {"n": 0}

    def flaky(path, arr):
        calls["n"] += 1
        if calls["n"] == 2:
            raise OSError("disk full mid-leaf (injected)")
        real(path, arr)
    monkeypatch.setattr(cm, "_write_leaf", flaky)


def test_async_writer_failure_reraised_at_wait(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path))
    _fail_second_leaf(monkeypatch)
    mgr.save(1, _tree())  # async: returns immediately, writer will die
    with pytest.raises(OSError, match="disk full mid-leaf"):
        mgr.wait()
    # raised ONCE, then cleared: the next sync point is clean
    mgr.wait()
    # the failed step never committed (only a cleaned-up .tmp at worst)
    assert mgr.all_steps() == []
    monkeypatch.undo()
    mgr.save(2, _tree())  # recovery: the next save succeeds
    mgr.wait()
    assert mgr.all_steps() == [2]


def test_async_writer_failure_reraised_at_next_save(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path))
    _fail_second_leaf(monkeypatch)
    mgr.save(1, _tree())
    mgr._thread.join()  # let the writer die (join alone never raises)
    monkeypatch.undo()
    with pytest.raises(OSError, match="disk full mid-leaf"):
        mgr.save(2, _tree())  # save()'s entry wait() re-raises
    mgr.save(2, _tree())
    mgr.wait()
    assert mgr.all_steps() == [2]


def test_blocking_save_failure_raises_inline(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path))
    _fail_second_leaf(monkeypatch)
    with pytest.raises(OSError, match="disk full mid-leaf"):
        mgr.save(1, _tree(), blocking=True)


def test_gc_never_deletes_inflight_step(tmp_path, monkeypatch):
    """Retention must skip a step whose save is still in flight: a slow
    writer paused right after its atomic rename (committed on disk,
    still pending) survives a concurrent ``_gc`` that would otherwise
    collect it, and becomes collectable the moment it retires."""
    import repro.checkpoint.manager as cm
    committed, release = threading.Event(), threading.Event()
    real_rename = os.rename

    def slow_rename(src, dst):
        real_rename(src, dst)
        if dst.endswith("step_00000001"):
            committed.set()
            assert release.wait(10)
    monkeypatch.setattr(cm.os, "rename", slow_rename)

    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(1, _tree())  # async; writer parks just past the commit
    assert committed.wait(10)

    # commit newer steps through a second manager (no shared pending set,
    # generous keep: it must not collect anything itself)
    other = CheckpointManager(str(tmp_path), keep=10)
    other.save(2, _tree(), blocking=True)
    other.save(3, _tree(), blocking=True)

    mgr._gc()  # keep=1 would collect steps 1 and 2 — but 1 is pending
    assert 1 in mgr.all_steps(), "gc deleted a step whose save is in flight"
    assert 2 not in mgr.all_steps()

    release.set()
    mgr.wait()  # writer retires step 1, then runs its own gc (keep=1)
    assert mgr.all_steps() == [3]


# ---------------------------------------------------------------------------
# checkpoint corruption: structured errors + previous-step fallback
# ---------------------------------------------------------------------------

def test_truncated_leaf_is_structured_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree, blocking=True)
    name = truncate_leaf(str(tmp_path), 1, leaf=0)
    with pytest.raises(CheckpointCorruptionError) as ei:
        mgr.restore(1, tree)
    # a torn leaf surfaces as OUR error naming the parameter, never a raw
    # numpy parser error
    assert ei.value.param == name and name in str(ei.value)
    assert ei.value.step == 1 and "unreadable leaf file" in ei.value.reason


def test_bitflipped_leaf_caught_by_checksum(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree, blocking=True)
    name = bitflip_leaf(str(tmp_path), 1, leaf=1, seed=7)
    with pytest.raises(CheckpointCorruptionError) as ei:
        mgr.restore(1, tree)
    assert ei.value.param == name
    assert "crc32 mismatch" in ei.value.reason


def test_truncated_manifest_is_structured_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree, blocking=True)
    truncate_manifest(str(tmp_path), 1)
    with pytest.raises(CheckpointCorruptionError) as ei:
        mgr.restore(1, tree)
    assert ei.value.param == "manifest.json"


def test_fallback_restores_newest_earlier_intact_step(tmp_path, capsys):
    mgr = CheckpointManager(str(tmp_path))
    t1, t2 = _tree(), _tree()
    t2["w"]["a"] = t2["w"]["a"] + 100.0
    mgr.save(1, t1, blocking=True)
    mgr.save(2, t2, blocking=True)
    bitflip_leaf(str(tmp_path), 2, leaf=0)

    step, got = mgr.restore(None, t1, fallback=True)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(got["w"]["a"]), t1["w"]["a"])
    assert "falling back" in capsys.readouterr().out
    # without fallback the same corruption is fail-stop
    with pytest.raises(CheckpointCorruptionError):
        mgr.restore(2, t1)


def test_fallback_exhausted_names_the_dead_end(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=True)
    name = truncate_leaf(str(tmp_path), 1)
    with pytest.raises(CheckpointCorruptionError,
                       match="no earlier intact step") as ei:
        mgr.restore(1, _tree(), fallback=True)
    assert ei.value.param == name


def test_serve_engine_falls_back_to_previous_intact_step(model, params,
                                                         tmp_path):
    """End-to-end: a serving restart pointed at a corrupted latest step
    comes up on the previous intact one and produces a WORKING engine."""
    mgr = CheckpointManager(str(tmp_path))
    bumped = jax.tree.map(lambda x: x * 1.01, params)
    mgr.save(1, params, blocking=True)
    mgr.save(2, bumped, blocking=True)
    bitflip_leaf(str(tmp_path), 2, leaf=0)

    eng = ServeEngine.from_checkpoint(model, str(tmp_path),
                                      scfg=ServeConfig(max_new_tokens=4))
    p = _prompt(model, b=2)
    want = ServeEngine(model, params,
                       ServeConfig(max_new_tokens=4)).generate(p)
    np.testing.assert_array_equal(eng.generate(p), want)

    # the same restart WITHOUT fallback is fail-stop on the bad step
    with pytest.raises(CheckpointCorruptionError):
        ServeEngine.from_checkpoint(model, str(tmp_path), step=2,
                                    scfg=ServeConfig(max_new_tokens=4),
                                    fallback=False)


# ---------------------------------------------------------------------------
# saturation-probe primitives (kernels/quantize helpers)
# ---------------------------------------------------------------------------

def test_quantize_fixed_scale_clips_at_127():
    from repro.kernels.quantize import quantize_fixed_scale
    x = jnp.asarray([[0.0, 1.0, -1.0, 10.0, -10.0]], jnp.float32)
    q = np.asarray(jax.jit(
        lambda a: quantize_fixed_scale(a, jnp.asarray(1.0 / 127.0)))(x))
    assert q.dtype == np.int8
    np.testing.assert_array_equal(q[0], [0, 127, -127, 127, -127])


def test_saturation_fraction_counts_clip_boundary():
    from repro.kernels.quantize import saturation_fraction
    q = jnp.asarray([[127, -127, 3, 0], [1, 2, 3, 4]], jnp.int8)
    frac = np.asarray(saturation_fraction(q))
    np.testing.assert_allclose(frac, [0.5, 0.0])


def test_absmax_quantization_saturates_under_fixed_scale():
    """The probe's physics: a tensor quantized at its own absmax scale
    barely saturates; the same tensor against a 64x-too-small calibrated
    scale saturates heavily — exactly the drift the serving guard trips
    on."""
    from repro.kernels.quantize import (quantize_fixed_scale,
                                        saturation_fraction)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 256), jnp.float32)
    own = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    calm = np.asarray(saturation_fraction(quantize_fixed_scale(x, own)))
    hot = np.asarray(saturation_fraction(
        quantize_fixed_scale(x * 64.0, own)))
    assert np.all(calm < 0.05)
    assert np.all(hot > 0.5)
